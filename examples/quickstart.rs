//! Quickstart: the SmallTalk LM public API in ~60 lines.
//!
//! Trains a 2-expert mixture of tiny models end to end (router EM ->
//! balanced sharding -> independent experts), then routes a few fresh
//! sequences and prints which expert each one went to.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use smalltalk::coordinator::{run_pipeline, PipelineConfig};
use smalltalk::data::corpus::{domain_name, Corpus};
use smalltalk::data::SequenceGen;
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;

fn main() -> anyhow::Result<()> {
    // 1. Runtime over the AOT artifacts (HLO text compiled by jax).
    let engine = Engine::new("artifacts")?;

    // 2. Tokenizer: byte-level BPE trained on the synthetic corpus.
    let corpus = Corpus::generate(80, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts())?;

    // 3. Algorithm 1: routers (EM) -> shard -> independent experts.
    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "router_micro".into(), // tiny experts: quick demo
        n_experts: 2,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 10,
        shard_sequences: 128,
        expert_steps: 15,
        prefix_len: 32,
        seed: 7,
        threads: 0,
    };
    println!("training a {}-expert mixture ...", cfg.n_experts);
    let result = run_pipeline(&engine, &bpe, &cfg)?;
    println!(
        "segment sizes {:?}, domain purity {:?}",
        result.segment_sizes, result.segment_purity
    );

    // 4. Inference: route fresh sequences by 32-token prefix likelihood.
    let mut gen = SequenceGen::new(&bpe, result.mixture.expert_meta.seq_len, 1001);
    let seqs = gen.batch(8);
    let routed = result.mixture.eval_routed(&engine, &seqs, cfg.prefix_len)?;
    println!("\n{:<10} {:>7} {:>10}", "domain", "expert", "NLL");
    for (s, (nll, e)) in seqs.iter().zip(&routed) {
        println!("{:<10} {:>7} {:>10.1}", domain_name(s.domain), e, nll);
    }

    // 5. The headline quantity: communication.
    println!(
        "\ntotal coordination traffic: {} bytes across {} all-gathers \
         (a DDP run of this model would move {} bytes per node per STEP)",
        result.ledger.total_bytes(),
        result
            .ledger
            .rounds(smalltalk::coordinator::CommKind::ScoreAllGather),
        smalltalk::coordinator::comm::ddp_bytes_per_step(
            result.mixture.expert_meta.param_count as u64
        ),
    );
    Ok(())
}
