//! Serving demo: closed waves vs continuous batching over one mixture.
//!
//! Shows the inference-side economics of SmallTalk LM: every request is
//! scored by E tiny routers (a few % of an expert forward), then exactly
//! ONE expert runs — the "fraction of the parameters" claim. Reports
//! per-request routing/execution latency and per-expert load, first for
//! the classic closed-wave loop, then for the continuous-batching server
//! fed the same requests as a staggered stream (admission waves, partial
//! dispatch on linger expiry, worker slots refilled as they free up).
//!
//! Run: `cargo run --release --example serve_mixture -- [--requests N]
//!       [--experts N] [--waves N] [--batch-size N] [--max-wait-us N]
//!       [--delay-us N]`

use smalltalk::coordinator::{
    run_pipeline, run_server, serve, MixtureBackend, PipelineConfig, Request, ServerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::flops::Arch;
use smalltalk::metrics::percentile;
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &raw,
        &["requests", "experts", "waves", "seed", "batch-size", "max-wait-us", "delay-us"],
    )?;
    let n_req = args.get_usize("requests", 64)?;
    let n_experts = args.get_usize("experts", 4)?;
    let waves = args.get_usize("waves", 3)?;
    let seed = args.get_u64("seed", 99)?;
    let max_wait_us = args.get_u64("max-wait-us", 2000)?;
    let delay_us = args.get_u64("delay-us", 100)?;

    let engine = Engine::new("artifacts")?;
    let corpus = Corpus::generate(80, 400, seed, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts())?;

    // Train a small mixture to serve.
    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts,
        em_rounds: 2,
        em_chunk: 128,
        em_steps_per_round: 16,
        shard_sequences: 256,
        expert_steps: 30,
        prefix_len: 32,
        seed,
        threads: 0,
    };
    eprintln!("[serve] training a {n_experts}-expert mixture to serve ...");
    let result = run_pipeline(&engine, &bpe, &cfg)?;
    let meta = &result.mixture.expert_meta;
    let rmeta = &result.mixture.router_meta;

    // FLOPs economics of one request (per Eq. 11).
    let expert_arch = Arch {
        layers: meta.n_layers as f64,
        hidden: meta.d_model as f64,
        d_ffw: meta.d_ffw as f64,
        vocab: meta.vocab as f64,
    };
    let router_arch = Arch {
        layers: rmeta.n_layers as f64,
        hidden: rmeta.d_model as f64,
        d_ffw: rmeta.d_ffw as f64,
        vocab: rmeta.vocab as f64,
    };
    let route_flops = n_experts as f64 * router_arch.forward_flops(1.0, 32.0);
    let expert_flops = expert_arch.inference_flops(meta.seq_len as f64);
    println!(
        "[serve] per-request FLOPs: routing {:.2}M ({}x routers) + expert {:.2}M = {:.1}% overhead",
        route_flops / 1e6,
        n_experts,
        expert_flops / 1e6,
        route_flops / expert_flops * 100.0
    );

    // Waves of batched requests.
    let mut gen = SequenceGen::new(&bpe, meta.seq_len, seed ^ 0x5EB);
    let mut total = 0usize;
    let t0 = std::time::Instant::now();
    for wave in 0..waves {
        let requests: Vec<Request> = gen
            .batch(n_req)
            .into_iter()
            .enumerate()
            .map(|(i, s)| Request {
                id: (wave * n_req + i) as u64,
                tokens: s.tokens,
            })
            .collect();
        let t1 = std::time::Instant::now();
        let responses = serve(&engine, &result.mixture, &requests, cfg.prefix_len)?;
        let dt = t1.elapsed();
        total += responses.len();

        let mut by_expert = vec![0usize; n_experts];
        let mut route_us = 0u128;
        let mut exec_us = 0u128;
        for r in &responses {
            by_expert[r.expert] += 1;
            route_us += r.route_micros;
            exec_us += r.exec_micros;
        }
        println!(
            "[wave {wave}] {} req in {:.2?} ({:.1} req/s) | load {:?} | mean route {}µs, exec {}µs",
            responses.len(),
            dt,
            responses.len() as f64 / dt.as_secs_f64(),
            by_expert,
            route_us / responses.len() as u128,
            exec_us / responses.len() as u128,
        );
    }
    let dt = t0.elapsed();
    println!(
        "\nserved {total} requests in {:.2?} — {:.1} req/s sustained (closed waves)",
        dt,
        total as f64 / dt.as_secs_f64()
    );

    // ---- continuous batching: the same request volume as one staggered
    // stream through the admission scheduler ----
    // same semantics as `smalltalk serve`: 0 = the compiled eval batch
    let batch_size = match args.get_usize("batch-size", meta.eval_batch)? {
        0 => meta.eval_batch,
        n => n,
    };
    let stream: Vec<Request> = gen
        .batch(total)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: 10_000 + i as u64,
            tokens: s.tokens,
        })
        .collect();
    let backend = MixtureBackend {
        engine: &engine,
        mixture: &result.mixture,
        prefix_len: cfg.prefix_len,
    };
    let scfg = ServerConfig::continuous(batch_size, max_wait_us, cfg.threads);
    let t0 = std::time::Instant::now();
    let (responses, stats, ()) = run_server(&backend, &scfg, |client| {
        for req in stream {
            if delay_us > 0 {
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            if !client.submit(req) {
                break; // server is failing: stop streaming doomed requests
            }
        }
    })?;
    let dt = t0.elapsed();
    let queue_us: Vec<f64> = responses.iter().map(|r| r.queue_micros as f64).collect();
    let total_us: Vec<f64> = responses.iter().map(|r| r.total_micros() as f64).collect();
    println!(
        "served {} requests in {:.2?} — {:.1} req/s continuous \
         (batch-size {batch_size}, max-wait {max_wait_us} µs, arrivals every {delay_us} µs)",
        responses.len(),
        dt,
        responses.len() as f64 / dt.as_secs_f64(),
    );
    println!(
        "  latency µs: queue p50 {:.0} / p95 {:.0}, total p50 {:.0} / p95 {:.0}",
        percentile(&queue_us, 50.0),
        percentile(&queue_us, 95.0),
        percentile(&total_us, 50.0),
        percentile(&total_us, 95.0),
    );
    println!(
        "  scheduler: {} admission waves, {} batches ({} full, {} linger, {} drain), \
         {} slots refilled, {} route-memo hits, mean queue depth {:.2}",
        stats.admission_waves,
        stats.batches_dispatched,
        stats.full_batches,
        stats.linger_batches,
        stats.drain_batches,
        stats.slots_refilled,
        stats.route_cache_hits,
        stats.mean_queue_depth(),
    );
    Ok(())
}
