//! End-to-end validation (DESIGN.md §5): the full system on a real small
//! workload, proving all three layers compose.
//!
//!   jax/pallas (build time) -> HLO artifacts -> rust PJRT runtime ->
//!   router EM -> balanced sharding -> E independent experts ->
//!   FLOPs-matched dense baseline -> held-out perplexity + downstream.
//!
//! Default scale: 4 x expert_sm (~0.9M params) for a few hundred steps on
//! one CPU core. `--scale md` uses expert_md (~5M params); the loss curve
//! and final comparison land in results/e2e_train.json and are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_train -- [--scale sm|md]
//!       [--experts N] [--steps N]`

use smalltalk::baselines::{train_dense, train_dense_batched};
use smalltalk::coordinator::{comm, dense_perplexity, run_pipeline, PipelineConfig};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::eval::downstream::macro_accuracy;
use smalltalk::eval::{build_tasks, mixture_accuracy, single_model_accuracy};
use smalltalk::metrics::{sparkline, RunLog};
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["scale", "experts", "steps", "seed"])?;
    let scale = args.get_or("scale", "sm");
    let expert_variant = match scale {
        "sm" => "expert_sm",
        "md" => "expert_md",
        "lg" => "expert_lg",
        other => anyhow::bail!("unknown --scale {other} (sm|md|lg)"),
    };
    let n_experts = args.get_usize("experts", 4)?;
    let expert_steps = args.get_usize("steps", 120)?;
    let seed = args.get_u64("seed", 1234)?;

    let t_start = std::time::Instant::now();
    let engine = Engine::new("artifacts")?;
    let corpus = Corpus::generate(120, 500, seed, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts())?;

    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: expert_variant.into(),
        n_experts,
        em_rounds: 3,
        em_chunk: 192,
        em_steps_per_round: 30,
        shard_sequences: (n_experts * expert_steps).min(640),
        expert_steps,
        prefix_len: 32,
        seed,
        threads: 0,
    };
    let meta = engine.variant(expert_variant)?.clone();
    println!(
        "[e2e] {} x {} ({} params each, {} total), {} steps/expert, seq {}",
        n_experts,
        expert_variant,
        meta.param_count,
        n_experts * meta.param_count,
        expert_steps,
        meta.seq_len
    );

    let result = run_pipeline(&engine, &bpe, &cfg)?;
    println!(
        "[e2e] segments: sizes {:?}, domain purity {:?}",
        result.segment_sizes,
        result
            .segment_purity
            .iter()
            .map(|p| (p * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    for e in 0..n_experts {
        if let Some(curve) = result.log.get(&format!("expert{e}/loss")) {
            println!(
                "[e2e] expert{e} loss {:.3} -> {:.3}  {}",
                curve.first().unwrap().y,
                curve.last().unwrap().y,
                sparkline(curve, 40)
            );
        }
    }

    // FLOPs-matched dense baseline: the paper's pairing is the SAME number
    // of steps at E x the expert batch (falls back to E x steps at native
    // batch when that shape isn't compiled).
    let dense_batch = n_experts * meta.train_batch;
    let batched_ok = dense_batch == meta.train_batch || meta.dense_batches.contains(&dense_batch);
    let mut dense_log = RunLog::new();
    let dense = if batched_ok {
        println!("[e2e] dense baseline: {expert_steps} steps @ batch {dense_batch} ...");
        train_dense_batched(&engine, &bpe, expert_variant, expert_steps, dense_batch, seed ^ 0xD, &mut dense_log)?
    } else {
        let dense_steps = n_experts * expert_steps;
        println!("[e2e] dense baseline: {dense_steps} steps @ native batch (no compiled batch {dense_batch}) ...");
        train_dense(&engine, &bpe, expert_variant, dense_steps, seed ^ 0xD, &mut dense_log)?
    };
    if let Some(curve) = dense_log.get("loss") {
        println!(
            "[e2e] dense   loss {:.3} -> {:.3}  {}",
            curve.first().unwrap().y,
            curve.last().unwrap().y,
            sparkline(curve, 40)
        );
    }

    // Held-out evaluation.
    let mut eval_gen = SequenceGen::new(&bpe, meta.seq_len, seed ^ 0xE7A1);
    let held_out = eval_gen.batch(96);
    let mix_ppl = result.mixture.perplexity(&engine, &held_out, cfg.prefix_len)?;
    let dense_ppl = dense_perplexity(&engine, &dense, &meta, &held_out)?;

    // Downstream.
    let tasks = build_tasks(&bpe, 10, 4, 32, seed ^ 0x7A5);
    let mix_acc = mixture_accuracy(&engine, &result.mixture, &tasks, cfg.prefix_len)?;
    let dense_acc = single_model_accuracy(&engine, &dense, &meta, &tasks)?;

    println!("\n=== e2e summary ({:.0?}) ===", t_start.elapsed());
    println!("held-out ppl : mixture {mix_ppl:.3}  dense {dense_ppl:.3}  ({:+.1}%)",
        (mix_ppl / dense_ppl - 1.0) * 100.0);
    println!(
        "downstream   : mixture {:.3}  dense {:.3} (macro accuracy, {} tasks)",
        macro_accuracy(&mix_acc),
        macro_accuracy(&dense_acc),
        tasks.tasks.len()
    );
    println!(
        "communication: {} all-gathers, {} total bytes (DDP equivalent: {} bytes/node/step)",
        result.ledger.rounds(comm::CommKind::ScoreAllGather),
        result.ledger.total_bytes(),
        comm::ddp_bytes_per_step(meta.param_count as u64)
    );
    let stats = engine.stats();
    println!(
        "engine       : {} compiles ({:.1}s), {} executions ({:.1}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );

    // Persist the run record.
    let mut log = result.log;
    log.merge_prefixed("dense", &dense_log);
    log.scalar("final/mixture_ppl", 0.0, mix_ppl);
    log.scalar("final/dense_ppl", 0.0, dense_ppl);
    log.scalar("final/mixture_acc", 0.0, macro_accuracy(&mix_acc));
    log.scalar("final/dense_acc", 0.0, macro_accuracy(&dense_acc));
    std::fs::create_dir_all("results").ok();
    log.save("results/e2e_train.json")?;
    println!("wrote results/e2e_train.json");
    Ok(())
}
