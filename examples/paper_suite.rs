//! Paper suite: regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §4) at the scaled budget.
//!
//!   cargo run --release --example paper_suite -- all
//!   cargo run --release --example paper_suite -- fig2 fig4b table3
//!   cargo run --release --example paper_suite -- all --budget smoke
//!
//! Each driver writes `results/<id>.json` and prints the paper-shaped
//! rows. EXPERIMENTS.md records paper-vs-measured for every id.

use anyhow::Result;

use smalltalk::data::corpus::Corpus;
use smalltalk::experiments::{
    comm_overhead, fig2, fig3_tables45, fig4a, fig4b, fig4c, fig6, table3, Budget, Suite,
};
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::cli::Args;
use smalltalk::util::json::Json;

fn save(id: &str, j: &Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{id}.json");
    std::fs::write(&path, j.to_string_pretty())?;
    println!("--- {id} -> {path}");
    Ok(())
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["budget", "seed", "steps", "experts"])?;
    let mut which: Vec<String> = args.positional.clone();
    if which.is_empty() || which.iter().any(|w| w == "all") {
        which = ["fig2", "fig3", "fig4a", "fig4b", "fig4c", "fig6", "table3", "comm"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    }
    let mut budget = match args.get_or("budget", "scaled") {
        "smoke" => Budget::smoke(),
        "scaled" => Budget::scaled(),
        other => anyhow::bail!("unknown --budget {other} (smoke|scaled)"),
    };
    budget.seed = args.get_u64("seed", budget.seed)?;
    budget.expert_steps = args.get_usize("steps", budget.expert_steps)?;
    if let Some(list) = args.get("experts") {
        budget.experts_sweep = list
            .split(',')
            .filter_map(|p| p.trim().parse().ok())
            .collect();
    }

    let engine = Engine::new("artifacts")?;
    let corpus = Corpus::generate(120, 500, budget.seed, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts())?;
    let suite = Suite::new(&engine, &bpe, budget);

    let t0 = std::time::Instant::now();
    let mut fig2_artifacts = None;

    for id in &which {
        let t = std::time::Instant::now();
        eprintln!("[suite] running {id} ...");
        match id.as_str() {
            "fig2" | "fig5" => {
                let a = fig2(&suite)?;
                print_fig2(&a.json);
                save("fig2_fig5", &a.json)?;
                fig2_artifacts = Some(a);
            }
            "fig3" | "table45" => {
                let j = fig3_tables45(&suite, fig2_artifacts.as_ref())?;
                print_fig3(&j);
                save("fig3_tables45", &j)?;
            }
            "fig4a" => {
                let j = fig4a(&suite)?;
                print_rows(&j, "rows", &["router", "router_params", "mixture_ppl"]);
                save("fig4a", &j)?;
            }
            "fig4b" => {
                let j = fig4b(&suite, fig2_artifacts.as_ref())?;
                print_rows(&j, "rows", &["prefix", "mixture_ppl"]);
                save("fig4b", &j)?;
            }
            "fig4c" => {
                let j = fig4c(&suite)?;
                print_rows(&j, "rows", &["prefix", "ours_ppl", "tfidf_ppl"]);
                save("fig4c", &j)?;
            }
            "fig6" => {
                let j = fig6(&suite)?;
                save("fig6", &j)?;
            }
            "table3" => {
                let j = table3(&suite, fig2_artifacts.as_ref().map(|a| &a.json))?;
                print_rows(
                    &j,
                    "paper_scale",
                    &["config", "train_e19", "train_overhead_e19", "infer_e12_mixture"],
                );
                save("table3", &j)?;
            }
            "comm" => {
                let j = comm_overhead(&suite)?;
                println!("{}", j.to_string_pretty());
                save("comm_overhead", &j)?;
            }
            other => eprintln!("[suite] unknown id {other}, skipping"),
        }
        eprintln!("[suite] {id} done in {:.1?}", t.elapsed());
    }
    eprintln!("[suite] total {:.1?}", t0.elapsed());
    Ok(())
}

fn print_rows(j: &Json, key: &str, cols: &[&str]) {
    let Some(rows) = j.get(key).and_then(Json::as_arr) else {
        return;
    };
    println!("{}", cols.join("\t"));
    for r in rows {
        let vals: Vec<String> = cols
            .iter()
            .map(|c| match r.get(c) {
                Some(Json::Num(n)) => format!("{n:.4}"),
                Some(Json::Str(s)) => s.clone(),
                Some(Json::Null) | None => "-".into(),
                Some(v) => v.to_string(),
            })
            .collect();
        println!("{}", vals.join("\t"));
    }
}

fn print_fig2(j: &Json) {
    println!("E\tmix_ppl\tdense_ppl\ttrainPF_mix\ttrainPF_dense");
    for r in j.get("rows").and_then(Json::as_arr).unwrap_or(&[]) {
        println!(
            "{}\t{:.3}\t{:.3}\t{:.3}\t{:.3}",
            r.get("experts").and_then(Json::as_f64).unwrap_or(0.0),
            r.get("mixture_ppl").and_then(Json::as_f64).unwrap_or(0.0),
            r.get("dense_ppl").and_then(Json::as_f64).unwrap_or(0.0),
            r.get("train_pflops_mixture").and_then(Json::as_f64).unwrap_or(0.0),
            r.get("train_pflops_dense").and_then(Json::as_f64).unwrap_or(0.0),
        );
    }
}

fn print_fig3(j: &Json) {
    println!(
        "downstream macro: mixture {:.3} vs dense {:.3} (win rate {:.0}%)",
        j.get("mixture_macro").and_then(Json::as_f64).unwrap_or(0.0),
        j.get("dense_macro").and_then(Json::as_f64).unwrap_or(0.0),
        j.get("win_fraction").and_then(Json::as_f64).unwrap_or(0.0) * 100.0
    );
    print_rows(j, "per_task", &["task", "mixture", "dense"]);
}
