# SmallTalk LM — repo-root entry points (tier-1 verify runs from here).
#
#   make build             cargo build --release (workspace: rust/ + vendored deps)
#   make test              cargo test -q  (XLA-backed tests self-skip without artifacts)
#   make test-concurrency  the engine thread-safety suite, at 1 and 8 test threads
#   make test-serve        the continuous-batching scheduler suite, serial + interleaved
#   make test-replica      the replica-fleet dispatch suite (placement, hot-expert
#                          balance, sync-byte audit), serial + interleaved
#   make test-net          the TCP/JSONL front-end suite (loopback e2e, shedding,
#                          connection limits, adversarial lexer properties),
#                          serial + interleaved
#   make test-fused        the fused all-routers scoring + stacked-cache suite,
#                          serial + interleaved
#   make test-fused-eval   the bucket-ladder fused expert eval suite (wave
#                          planner properties, fused-vs-fanout bit-identity,
#                          launch accounting), serial + interleaved
#   make test-async        the trainer-orchestrator suite (staged bit-identity,
#                          kill-and-resume, stale snapshots), serial + interleaved
#   make test-chaos        the elastic-trainer chaos suite (seeded fault plans:
#                          kills/adoption, leave/rejoin merges, joins, delayed
#                          publishes), serial + interleaved
#   make test-shard        the fleet-shard suite (shard-level chaos, whole-shard
#                          re-adoption, cross-shard byte audit, JSON replay,
#                          checkpoint namespacing), serial + interleaved
#   make artifacts         AOT-lower every model variant to artifacts/ (needs jax;
#                          exports the fused prefix_nll_all entries at width 4)
#   make bench-smoke       tiny-budget routing+serve+train_step+trainer benches
#                          -> BENCH_routing.json + BENCH_serve.json + BENCH_train.json

.PHONY: build test test-concurrency test-serve test-replica test-net test-fused test-fused-eval test-async test-chaos test-shard artifacts bench-smoke clean

build:
	cargo build --release

test:
	cargo test -q

# Run the concurrency & determinism suite under both serial and heavily
# interleaved test scheduling (the suite itself also sweeps worker counts
# internally).
test-concurrency:
	RUST_TEST_THREADS=1 cargo test -q --test concurrency
	RUST_TEST_THREADS=8 cargo test -q --test concurrency

# Continuous-batching scheduler suite (queue accounting on the stub
# backend runs everywhere; determinism vs closed-wave needs artifacts),
# under both serial and heavily interleaved test scheduling.
test-serve:
	RUST_TEST_THREADS=1 cargo test -q --test server
	RUST_TEST_THREADS=8 cargo test -q --test server

# Replica-fleet dispatch suite: triple-set determinism across fleet
# shapes, ≤2x per-replica balance under hot-expert skew, and the
# closed-form replica-sync byte audit — all tier-1 (stub backend, no
# artifacts), under both serial and heavily interleaved test scheduling.
test-replica:
	RUST_TEST_THREADS=1 cargo test -q --test replica
	RUST_TEST_THREADS=8 cargo test -q --test replica

# TCP/JSONL front-end suite: loopback end-to-end serving against the
# in-process reference, structured shedding and connection limits, and
# the adversarial zero-copy-lexer properties — all tier-1 (stub backend,
# no artifacts), under both serial and heavily interleaved test
# scheduling.
test-net:
	RUST_TEST_THREADS=1 cargo test -q --test net
	RUST_TEST_THREADS=8 cargo test -q --test net

# Fused all-routers scoring + stacked-parameter cache suite (stacked-cache
# accounting on the stub backend runs everywhere; fused-vs-fanout
# bit-equality needs fused artifacts), under both serial and heavily
# interleaved test scheduling.
test-fused:
	RUST_TEST_THREADS=1 cargo test -q --test fused_scoring
	RUST_TEST_THREADS=8 cargo test -q --test fused_scoring

# Bucket-ladder fused expert eval suite (planner properties and manifest
# back-compat run tier-1 on the stub backend; fused-vs-fanout bit-equality
# and the E=4 launch-accounting acceptance need fused artifacts), under
# both serial and heavily interleaved test scheduling.
test-fused-eval:
	RUST_TEST_THREADS=1 cargo test -q --test fused_eval
	RUST_TEST_THREADS=8 cargo test -q --test fused_eval

# Trainer-orchestrator suite (node machinery, checkpoint/resume, and the
# snapshot store run tier-1 on a stub backend; the staged-vs-classic
# bit-identity and engine-backed async smoke need artifacts), under both
# serial and heavily interleaved test scheduling.
test-async:
	RUST_TEST_THREADS=1 cargo test -q --test async_train
	RUST_TEST_THREADS=8 cargo test -q --test async_train

# Elastic-trainer chaos suite: three fixed fault seeds on the stub
# backend (kill+adopt, leave/rejoin merge, mid-run join, gated publish),
# boundary-kill bit-identity, JSON replay determinism and the
# degradation contract — all deterministic, so it runs under both serial
# and heavily interleaved test scheduling.
test-chaos:
	RUST_TEST_THREADS=1 cargo test -q --test chaos_train
	RUST_TEST_THREADS=8 cargo test -q --test chaos_train

# Fleet-shard suite: multi-shard fault domains on the stub backend
# (shard partitions, leader losses, whole-shard kills vs a clean fleet's
# bit-identical reference; the exact intra/inter-shard byte audit; JSON
# spec replay; namespaced checkpoints + legacy flat resume) — all
# deterministic, so it runs under both serial and heavily interleaved
# test scheduling.
test-shard:
	RUST_TEST_THREADS=1 cargo test -q --test shard_train
	RUST_TEST_THREADS=8 cargo test -q --test shard_train

# --fused 4 matches the routing-bench/e2e expert count E=4; omit it to
# reproduce a pre-fused manifest (the runtime then fans out per router).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts --fused 4

bench-smoke:
	scripts/bench_smoke.sh

clean:
	cargo clean
	rm -rf results BENCH_routing.json BENCH_serve.json BENCH_train_step.json BENCH_train.json
