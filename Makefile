# SmallTalk LM — repo-root entry points (tier-1 verify runs from here).
#
#   make build             cargo build --release (workspace: rust/ + vendored deps)
#   make test              cargo test -q  (XLA-backed tests self-skip without artifacts)
#   make test-concurrency  the engine thread-safety suite, at 1 and 8 test threads
#   make artifacts         AOT-lower every model variant to artifacts/ (needs jax)
#   make bench-smoke       tiny-budget routing+train_step benches -> BENCH_routing.json

.PHONY: build test test-concurrency artifacts bench-smoke clean

build:
	cargo build --release

test:
	cargo test -q

# Run the concurrency & determinism suite under both serial and heavily
# interleaved test scheduling (the suite itself also sweeps worker counts
# internally).
test-concurrency:
	RUST_TEST_THREADS=1 cargo test -q --test concurrency
	RUST_TEST_THREADS=8 cargo test -q --test concurrency

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

bench-smoke:
	scripts/bench_smoke.sh

clean:
	cargo clean
	rm -rf results BENCH_routing.json BENCH_train_step.json
