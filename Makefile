# SmallTalk LM — repo-root entry points (tier-1 verify runs from here).
#
#   make build             cargo build --release (workspace: rust/ + vendored deps)
#   make test              cargo test -q  (XLA-backed tests self-skip without artifacts)
#   make test-concurrency  the engine thread-safety suite, at 1 and 8 test threads
#   make test-serve        the continuous-batching scheduler suite, serial + interleaved
#   make artifacts         AOT-lower every model variant to artifacts/ (needs jax)
#   make bench-smoke       tiny-budget routing+serve+train_step benches
#                          -> BENCH_routing.json + BENCH_serve.json

.PHONY: build test test-concurrency test-serve artifacts bench-smoke clean

build:
	cargo build --release

test:
	cargo test -q

# Run the concurrency & determinism suite under both serial and heavily
# interleaved test scheduling (the suite itself also sweeps worker counts
# internally).
test-concurrency:
	RUST_TEST_THREADS=1 cargo test -q --test concurrency
	RUST_TEST_THREADS=8 cargo test -q --test concurrency

# Continuous-batching scheduler suite (queue accounting on the stub
# backend runs everywhere; determinism vs closed-wave needs artifacts),
# under both serial and heavily interleaved test scheduling.
test-serve:
	RUST_TEST_THREADS=1 cargo test -q --test server
	RUST_TEST_THREADS=8 cargo test -q --test server

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

bench-smoke:
	scripts/bench_smoke.sh

clean:
	cargo clean
	rm -rf results BENCH_routing.json BENCH_serve.json BENCH_train_step.json
