# SmallTalk LM — repo-root entry points (tier-1 verify runs from here).
#
#   make build        cargo build --release (workspace: rust/ + vendored deps)
#   make test         cargo test -q  (XLA-backed tests self-skip without artifacts)
#   make artifacts    AOT-lower every model variant to artifacts/ (needs jax)
#   make bench-smoke  tiny-budget routing+train_step benches -> BENCH_routing.json

.PHONY: build test artifacts bench-smoke clean

build:
	cargo build --release

test:
	cargo test -q

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

bench-smoke:
	scripts/bench_smoke.sh

clean:
	cargo clean
	rm -rf results BENCH_routing.json BENCH_train_step.json
