//! Integration: the full Algorithm-1 pipeline at miniature scale, plus
//! serving, checkpointing, and the TF-IDF baseline comparison.
//!
//! Budgets are kept tiny (seconds per test on one CPU core); the paper's
//! *relative* claims at real budgets are exercised by the benches.

use smalltalk::baselines::{balanced_kmeans, truncated_svd, TfIdf};
use smalltalk::coordinator::{
    run_pipeline, serve, CommKind, PipelineConfig, Request,
};
use smalltalk::data::corpus::{Corpus, DOMAINS};
use smalltalk::data::SequenceGen;
use smalltalk::model::{load_checkpoint, save_checkpoint};
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::{Bpe, BpeTrainer};

/// XLA-backed tests skip (rather than fail) when no compiled artifacts are
/// present, so `cargo test` stays green on machines that haven't run
/// `make artifacts`.
fn engine() -> Option<Engine> {
    let dir = smalltalk::runtime::locate_artifacts()?;
    Some(Engine::new(dir).expect("loading artifacts"))
}

fn bpe() -> Bpe {
    let corpus = Corpus::generate(60, 400, 42, None);
    BpeTrainer::new(512).train(corpus.texts()).unwrap()
}

fn tiny_pipeline() -> PipelineConfig {
    PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "router_micro".into(), // tiny expert: fast test
        n_experts: 2,
        em_rounds: 2,
        em_chunk: 64,
        em_steps_per_round: 6,
        shard_sequences: 96,
        expert_steps: 10,
        prefix_len: 32,
        seed: 7,
        threads: 0,
    }
}

#[test]
fn pipeline_runs_and_specializes() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let cfg = tiny_pipeline();
    let result = run_pipeline(&eng, &b, &cfg).unwrap();

    // all sequences sharded, capacities respected. The pipeline enforces
    // single-epoch data: the corpus is grown to cover every expert's step
    // budget (n_experts * expert_steps * train_batch) when the configured
    // shard count is smaller.
    let meta = eng.variant(&cfg.expert_variant).unwrap().clone();
    let expected = cfg
        .shard_sequences
        .max(cfg.n_experts * cfg.expert_steps * meta.train_batch);
    let total: usize = result.segment_sizes.iter().sum();
    assert_eq!(total, expected);
    let cap = expected.div_ceil(cfg.n_experts);
    assert!(result.segment_sizes.iter().all(|&s| s <= cap));

    // comm: exactly em_rounds-1 (round 0 is random) + 1 sharding all-gather
    assert_eq!(
        result.ledger.rounds(CommKind::ScoreAllGather),
        cfg.em_rounds - 1 + 1
    );

    // experts trained: loss series present and decreasing
    for e in 0..cfg.n_experts {
        let series = result.log.get(&format!("expert{e}/loss")).unwrap();
        assert!(series.len() >= 2);
        assert!(series.last().unwrap().y < series.first().unwrap().y + 0.1);
    }

    // routing a fresh batch uses both experts (balance at inference is
    // emergent, not enforced — but with 2 experts both must appear)
    let mut gen = SequenceGen::new(&b, result.mixture.expert_meta.seq_len, 99);
    let seqs = gen.batch(64);
    let routes = result.mixture.route(&eng, &seqs, cfg.prefix_len).unwrap();
    let c0 = routes.iter().filter(|&&e| e == 0).count();
    assert!(c0 > 0 && c0 < 64, "all sequences routed to one expert");
}

#[test]
fn serve_returns_all_responses_in_order() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let cfg = tiny_pipeline();
    let result = run_pipeline(&eng, &b, &cfg).unwrap();
    let mut gen = SequenceGen::new(&b, result.mixture.expert_meta.seq_len, 123);
    let requests: Vec<Request> = gen
        .batch(10)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: 1000 + i as u64,
            tokens: s.tokens,
        })
        .collect();
    let responses = serve(&eng, &result.mixture, &requests, cfg.prefix_len).unwrap();
    assert_eq!(responses.len(), 10);
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, 1000 + i as u64);
        assert!(r.nll > 0.0 && r.nll.is_finite());
        assert!(r.expert < cfg.n_experts);
    }
}

#[test]
fn checkpoint_roundtrip_through_real_state() {
    let Some(eng) = engine() else { return };
    let st = smalltalk::runtime::TrainState::init(&eng, "router_micro", 31).unwrap();
    let dir = std::env::temp_dir().join("smalltalk_integration_ckpt");
    let path = dir.join("r.ckpt");
    save_checkpoint(&st, &path).unwrap();
    let st2 = load_checkpoint(&path).unwrap();
    assert_eq!(st.params, st2.params);
    assert_eq!(st2.variant, "router_micro");
}

/// The Fig. 4c comparator at miniature scale: cluster purity of prefix
/// TF-IDF features must be clearly worse than full-document TF-IDF —
/// the paper's core argument for why content clustering fails on short
/// prefixes while likelihood routing keeps working.
#[test]
fn tfidf_short_prefix_loses_information() {
    let b = bpe();
    let mut gen = SequenceGen::new(&b, 128, 5);
    let seqs = gen.batch(160);
    let full_docs: Vec<&[u32]> = seqs.iter().map(|s| &s.tokens[..]).collect();
    let prefix_docs: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(8)).collect();

    let purity = |docs: &[&[u32]]| -> f64 {
        let tfidf = TfIdf::fit(docs, b.vocab_size());
        let enc = tfidf.encode_all(docs);
        let proj = truncated_svd(&enc, 16, 3, 11);
        let km = balanced_kmeans(&proj, DOMAINS, 12, 13);
        // majority-domain purity per cluster
        let mut hit = 0usize;
        for c in 0..DOMAINS {
            let members: Vec<usize> = (0..seqs.len())
                .filter(|&i| km.assignment[i] == c)
                .collect();
            if members.is_empty() {
                continue;
            }
            let mut counts = std::collections::HashMap::new();
            for &i in &members {
                *counts.entry(seqs[i].domain).or_insert(0usize) += 1;
            }
            hit += counts.values().copied().max().unwrap_or(0);
        }
        hit as f64 / seqs.len() as f64
    };

    let full = purity(&full_docs);
    let prefix = purity(&prefix_docs);
    assert!(
        full > prefix + 0.1,
        "full-doc purity {full} should beat 8-token prefix purity {prefix}"
    );
    assert!(full > 0.6, "full-document tf-idf should cluster well: {full}");
}
