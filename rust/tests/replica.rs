//! Replica-fleet serving suite (tier-1, no artifacts needed): the
//! multi-replica dispatch layer of `coordinator/replica.rs` driven
//! through the public [`run_server`] scheduler on the deterministic stub
//! backend from `rust/tests/server.rs`.
//!
//! Three properties, matching the ISSUE acceptance bar:
//!
//! * **Determinism** — the `(id, expert, nll)` triple set is identical to
//!   `replicas = 1` for every replica count, replication factor, and
//!   rebalance cadence (NLL is a pure function of `(expert, tokens)`;
//!   replica choice only moves work between engines);
//! * **Balance** — on a ≥70%-skewed workload with replicas=4 /
//!   replication=2, per-replica executed-row counts differ by ≤2×
//!   (hot-expert demand escalates past the replication floor and
//!   equal-load ties rotate across holders);
//! * **Audit** — the comm ledger's replica-sync bytes reconcile in
//!   closed form: `sync_bytes == moves * expert_param_bytes`, all of it
//!   intra-shard.

use std::sync::Mutex;

use anyhow::Result;
use smalltalk::coordinator::{
    response_triples as triples, run_server, CommKind, Request, Response, SchedStats,
    ServeBackend, ServerConfig,
};

// ---------------------------------------------------------------------
// deterministic stub backend (mirrors rust/tests/server.rs)
// ---------------------------------------------------------------------

/// Routing and NLL are pure functions of the tokens (route by first
/// token, NLL = expert * 1000 + token sum), so triples are comparable
/// bit-for-bit across replica counts. `param_bytes` is the per-expert
/// parameter size the sync audit must account each placement move at;
/// the per-replica execution log proves every row ran on the lane the
/// dispatcher picked.
struct StubBackend {
    n: usize,
    param_bytes: u64,
    /// (replica, expert, rows) per executed batch.
    executions: Mutex<Vec<(usize, usize, usize)>>,
}

impl StubBackend {
    fn new(n: usize) -> Self {
        StubBackend {
            n,
            param_bytes: 4096,
            executions: Mutex::new(Vec::new()),
        }
    }
}

impl ServeBackend for StubBackend {
    fn n_experts(&self) -> usize {
        self.n
    }

    fn route(&self, rows: &[&[u32]], _threads: usize) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
            .collect())
    }

    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
            .collect())
    }

    fn exec_nll_replica(&self, replica: usize, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        self.executions
            .lock()
            .unwrap()
            .push((replica, expert, rows.len()));
        self.exec_nll(expert, rows)
    }

    fn expert_param_bytes(&self) -> u64 {
        self.param_bytes
    }
}

/// A request whose first token pins its route: expert = `first % n`.
fn req(id: u64, first: u32) -> Request {
    Request {
        id,
        tokens: vec![first, id as u32 + 1, 3],
    }
}

/// ≥70%-skewed arrivals over 4 experts: `hot` of every 10 requests hit
/// expert 0, the rest spread over experts 1..=3.
fn skewed_requests(total: usize, hot_per_10: usize) -> Vec<Request> {
    (0..total)
        .map(|i| {
            let first = if i % 10 < hot_per_10 {
                0
            } else {
                (1 + i % 3) as u32
            };
            req(i as u64, first)
        })
        .collect()
}

fn run(
    backend: &StubBackend,
    cfg: &ServerConfig,
    reqs: &[Request],
) -> (Vec<Response>, SchedStats) {
    let (out, stats, ()) = run_server(backend, cfg, |c| {
        for r in reqs {
            c.submit(r.clone());
        }
    })
    .expect("serve run failed");
    (out, stats)
}

// ---------------------------------------------------------------------
// determinism across placement/rebalance permutations
// ---------------------------------------------------------------------

/// The triple set is identical to the replicas=1 reference for every
/// (replicas, replication, rebalance_every) permutation — replica choice
/// cannot change an answer, only where it was computed.
#[test]
fn triples_match_the_single_replica_reference_across_fleet_shapes() {
    let reqs = skewed_requests(120, 7);
    let reference = {
        let backend = StubBackend::new(4);
        let (out, stats) = run(&backend, &ServerConfig::continuous(4, 500, 2), &reqs);
        assert!(stats.replica.is_none(), "replicas=1 must not build a fleet");
        triples(&out)
    };
    for (replicas, replication, rebalance_every) in
        [(2, 1, 0), (2, 2, 1), (4, 2, 1), (4, 4, 3), (3, 2, 2)]
    {
        let backend = StubBackend::new(4);
        let cfg = ServerConfig::continuous(4, 500, 2).with_replicas(
            replicas,
            replication,
            rebalance_every,
        );
        let (out, stats) = run(&backend, &cfg, &reqs);
        assert_eq!(
            triples(&out),
            reference,
            "fleet ({replicas},{replication},{rebalance_every}) changed a triple"
        );
        let rep = stats
            .replica
            .expect("replicated run must report fleet stats");
        assert_eq!(rep.replicas, replicas);
        assert_eq!(rep.replication, replication);
        // every completed row ran on exactly one lane
        assert_eq!(rep.executed_rows.iter().sum::<usize>(), stats.completed);
        // the backend's own execution log agrees with the lane counters
        let mut by_replica = vec![0usize; replicas];
        for &(r, _, rows) in backend.executions.lock().unwrap().iter() {
            by_replica[r] += rows;
        }
        assert_eq!(by_replica, rep.executed_rows);
    }
}

/// Zero requests through a fleet: clean drain, empty report.
#[test]
fn empty_replicated_run_drains_cleanly() {
    let backend = StubBackend::new(4);
    let cfg = ServerConfig::continuous(4, 500, 2).with_replicas(4, 2, 1);
    let (out, stats) = run(&backend, &cfg, &[]);
    assert!(out.is_empty());
    let rep = stats.replica.expect("fleet stats even on an empty run");
    assert_eq!(rep.executed_rows.iter().sum::<usize>(), 0);
    assert_eq!(rep.moves, 0, "nothing routed, nothing to move");
    assert_eq!(rep.sync_bytes, 0);
}

// ---------------------------------------------------------------------
// balance under hot-expert skew
// ---------------------------------------------------------------------

/// The acceptance bar: ≥70% of traffic on one expert, replicas=4,
/// replication=2 — per-replica executed-row counts differ by ≤2×
/// (vs ~4× for a placement that pins the hot expert to one replica).
#[test]
fn skewed_load_balances_within_two_x_across_replicas() {
    let backend = StubBackend::new(4);
    // 420 requests, 70% to expert 0; rebalance every admission wave so
    // the histogram drives placement almost immediately
    let reqs = skewed_requests(420, 7);
    let cfg = ServerConfig::continuous(4, 500, 2).with_replicas(4, 2, 1);
    let (out, stats) = run(&backend, &cfg, &reqs);
    assert_eq!(out.len(), reqs.len());
    let rep = stats.replica.expect("fleet stats");
    let rows = &rep.executed_rows;
    assert_eq!(rows.iter().sum::<usize>(), stats.completed);
    let (min, max) = (
        *rows.iter().min().unwrap(),
        *rows.iter().max().unwrap(),
    );
    assert!(min > 0, "a replica sat idle through a skewed run: {rows:?}");
    assert!(
        max <= 2 * min,
        "per-replica executed rows differ by more than 2x: {rows:?}"
    );
    // the histogram the rebalance ran from saw the skew
    assert_eq!(stats.route_histogram.iter().sum::<usize>(), stats.admitted);
    assert!(
        stats.route_histogram[0] * 10 >= stats.admitted * 7,
        "expected >=70% of routes on expert 0: {:?}",
        stats.route_histogram
    );
}

// ---------------------------------------------------------------------
// sync-byte audit
// ---------------------------------------------------------------------

/// The ledger reconciles in closed form: replica-sync bytes are exactly
/// `moves * expert_param_bytes`, every event is intra-shard, and a
/// skewed run that rebalances must actually move something.
#[test]
fn replica_sync_bytes_reconcile_against_moves() {
    let backend = StubBackend::new(4);
    let reqs = skewed_requests(200, 8); // 80% hot: rebalance must escalate
    let cfg = ServerConfig::continuous(4, 500, 2).with_replicas(4, 2, 1);
    let (_, stats) = run(&backend, &cfg, &reqs);
    let rep = stats.replica.expect("fleet stats");
    assert!(rep.rebalances >= 1, "rebalance_every=1 never fired");
    assert!(
        rep.moves >= 1,
        "an 80%-hot histogram must escalate the hot expert's copies"
    );
    assert_eq!(
        rep.sync_bytes,
        rep.moves as u64 * backend.param_bytes,
        "sync bytes must equal moves x expert_param_bytes"
    );
    assert_eq!(
        rep.ledger.kind_bytes(CommKind::ReplicaSync),
        rep.sync_bytes,
        "report and ledger disagree"
    );
    assert_eq!(
        rep.ledger.inter_shard_bytes(),
        0,
        "replica syncs never cross a shard boundary"
    );
    assert_eq!(rep.ledger.intra_shard_bytes(), rep.sync_bytes);
}

/// A steady histogram converges: after the first rebalances settle the
/// placement, re-running the same workload at the same cadence does not
/// thrash — the move count stays far below one move per rebalance.
#[test]
fn rebalance_does_not_thrash_on_a_steady_workload() {
    let backend = StubBackend::new(4);
    let reqs = skewed_requests(400, 7);
    let cfg = ServerConfig::continuous(4, 500, 2).with_replicas(4, 2, 1);
    let (_, stats) = run(&backend, &cfg, &reqs);
    let rep = stats.replica.expect("fleet stats");
    assert!(rep.rebalances >= 10, "expected many rebalance epochs");
    // the greedy prefers incumbent holders on ties, so once the skew is
    // reflected in the map the remaining epochs are no-ops
    assert!(
        rep.moves <= rep.rebalances / 2 + 4,
        "placement thrashing: {} moves over {} rebalances",
        rep.moves,
        rep.rebalances
    );
}
