//! TCP/JSONL front-end suite (tier-1, no artifacts needed): loopback
//! end-to-end serving on the deterministic stub backend from
//! `rust/tests/server.rs`, wire-protocol conformance (shed / bad-request /
//! connection-limit lines), and the adversarial input properties backing
//! the zero-copy lexer:
//!
//! * socket-served `(id, expert, nll)` triples equal in-process
//!   [`run_server`] on the same requests — the determinism contract
//!   survives the wire;
//! * requests split across arbitrary read boundaries reassemble
//!   identically ([`LineBuf`]);
//! * random bytes never panic any parser (tree, lexer, extractor), and
//!   the tree parser and lexer agree on every valid document;
//! * overload answers with structured 429 lines, never a hang or a
//!   dropped connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;
use smalltalk::coordinator::{
    response_triples as triples, run_server, serve_net, FairMux, NetConfig, Request, ServeBackend,
    ServerConfig,
};
use smalltalk::util::json::Json;
use smalltalk::util::lex::{parse_request_line, Lexer, LineBuf, Token};
use smalltalk::util::prop;
use smalltalk::util::Rng;

// ---------------------------------------------------------------------
// deterministic stub backend (mirrors rust/tests/server.rs)
// ---------------------------------------------------------------------

/// Routing and NLL are pure functions of the tokens (route by first
/// token, NLL = expert * 1000 + token sum), so socket-served triples are
/// comparable bit-for-bit against in-process serving. `route_delay`
/// slows the admission loop down so arrivals can pile past high water.
struct StubBackend {
    n: usize,
    route_delay: Duration,
}

impl StubBackend {
    fn new(n: usize) -> Self {
        StubBackend {
            n,
            route_delay: Duration::ZERO,
        }
    }

    fn with_route_delay(mut self, d: Duration) -> Self {
        self.route_delay = d;
        self
    }
}

impl ServeBackend for StubBackend {
    fn n_experts(&self) -> usize {
        self.n
    }

    fn route(&self, rows: &[&[u32]], _threads: usize) -> Result<Vec<usize>> {
        if !self.route_delay.is_zero() {
            std::thread::sleep(self.route_delay);
        }
        Ok(rows
            .iter()
            .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
            .collect())
    }

    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
            .collect())
    }
}

fn net_cfg(server: ServerConfig) -> NetConfig {
    NetConfig {
        listen: "127.0.0.1:0".to_string(),
        max_conns: 0,
        high_water: 10_000,
        want_tokens: None,
        server,
    }
}

fn request_line(r: &Request) -> String {
    format!("{{\"id\":{},\"tokens\":{:?}}}\n", r.id, r.tokens)
}

/// Parse an ok response line into the `(id, expert, nll_bits)` triple the
/// in-process suite compares on. Panics on an error line.
fn parse_ok(line: &str) -> (u64, usize, u32) {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("unparseable response {line:?}: {e}"));
    assert!(
        j.get("code").is_none(),
        "expected an ok line, got an error line: {line}"
    );
    let id = j.get("id").and_then(Json::as_f64).expect("id") as u64;
    let expert = j.get("expert").and_then(Json::as_usize).expect("expert");
    // stub NLLs are small integers: exact through f64 and back
    let nll = j.get("nll").and_then(Json::as_f64).expect("nll") as f32;
    (id, expert, nll.to_bits())
}

fn read_line(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("reading response line");
    assert!(n > 0, "connection closed before a response arrived");
    line.trim_end().to_string()
}

// ---------------------------------------------------------------------
// loopback end-to-end
// ---------------------------------------------------------------------

/// N clients stream interleaved JSONL over real sockets (some lines split
/// across multiple writes); every request gets exactly one ok line, and
/// the full `(id, expert, nll)` set equals in-process serving of the same
/// requests through the same scheduler config.
#[test]
fn loopback_streaming_matches_in_process_serving() {
    let backend = StubBackend::new(3);
    let cfg = net_cfg(ServerConfig::continuous(4, 500, 2));
    let requests: Vec<Vec<Request>> = (0..3)
        .map(|c| {
            (0..10)
                .map(|i| {
                    let id = (c * 100 + i) as u64;
                    Request {
                        id,
                        tokens: vec![(c * 7 + i) as u32, id as u32, 7],
                    }
                })
                .collect()
        })
        .collect();

    // in-process reference through the identical scheduler config
    let flat: Vec<Request> = requests.iter().flatten().cloned().collect();
    let (ref_out, _, ()) = run_server(&backend, &cfg.server, |cl| {
        for r in &flat {
            cl.submit(r.clone());
        }
    })
    .unwrap();
    let mut want = triples(&ref_out);
    want.sort_unstable();

    let mut got: Vec<(u64, usize, u32)> = std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel();
        let (b, c) = (&backend, &cfg);
        let server = s.spawn(move || serve_net(b, c, None, move |h| drop(tx.send(h))));
        let h = rx.recv().expect("server never became ready");
        let addr = h.addr();

        let clients: Vec<_> = requests
            .iter()
            .map(|reqs| {
                s.spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    let mut reader = BufReader::new(conn.try_clone().unwrap());
                    for (k, r) in reqs.iter().enumerate() {
                        let line = request_line(r);
                        if k % 3 == 0 {
                            // split mid-line across two writes: the server
                            // must reassemble across read boundaries
                            let bytes = line.as_bytes();
                            let mid = bytes.len() / 2;
                            conn.write_all(&bytes[..mid]).unwrap();
                            conn.flush().unwrap();
                            std::thread::sleep(Duration::from_micros(300));
                            conn.write_all(&bytes[mid..]).unwrap();
                        } else {
                            conn.write_all(line.as_bytes()).unwrap();
                        }
                    }
                    // exactly one response per request, streamed as each
                    // completes — no EOF needed to flush them
                    (0..reqs.len())
                        .map(|_| parse_ok(&read_line(&mut reader)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();

        let mut got = Vec::new();
        for c in clients {
            got.extend(c.join().unwrap());
        }
        h.shutdown();
        let (stats, report) = server.join().unwrap().unwrap();
        assert_eq!(report.connections, 3);
        assert_eq!(report.conns_refused, 0);
        assert_eq!(report.ok_lines, 30);
        assert_eq!(report.bad_lines, 0);
        assert_eq!(report.shed_lines, 0);
        assert_eq!(stats.completed, 30);
        got
    });
    got.sort_unstable();
    assert_eq!(
        got, want,
        "socket-served triples diverged from in-process serving"
    );
}

/// Worst-case fragmentation: a client that writes one byte per syscall
/// still gets every request answered correctly.
#[test]
fn one_byte_writes_reassemble_into_requests() {
    let backend = StubBackend::new(2);
    let cfg = net_cfg(ServerConfig::continuous(2, 200, 1));
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel();
        let (b, c) = (&backend, &cfg);
        let server = s.spawn(move || serve_net(b, c, None, move |h| drop(tx.send(h))));
        let h = rx.recv().unwrap();

        let mut conn = TcpStream::connect(h.addr()).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let lines = "{\"id\":1,\"tokens\":[3,1,7]}\n{\"id\":2,\"tokens\":[4,2,7]}\n";
        for byte in lines.as_bytes() {
            conn.write_all(&[*byte]).unwrap();
        }
        let mut got: Vec<_> = (0..2).map(|_| parse_ok(&read_line(&mut reader))).collect();
        got.sort_unstable();
        assert_eq!(
            got,
            vec![
                (1, 1, 1011f32.to_bits()), // 3 % 2 = expert 1, 1000 + 3+1+7
                (2, 0, 13f32.to_bits()),   // 4 % 2 = expert 0, 4+2+7
            ]
        );
        drop((conn, reader));
        h.shutdown();
        server.join().unwrap().unwrap();
    });
}

// ---------------------------------------------------------------------
// overload and limits
// ---------------------------------------------------------------------

/// Flooding past the high-water mark sheds with structured 429 lines —
/// every request still gets exactly one response (ok or shed), the
/// connection stays up, and the wire counters reconcile with the
/// scheduler's.
#[test]
fn queue_past_high_water_sheds_structured_lines() {
    // slow routing stalls the admission loop, so a burst piles up in the
    // arrival queue no matter how fast the workers are
    let backend = StubBackend::new(2).with_route_delay(Duration::from_millis(5));
    let mut cfg = net_cfg(ServerConfig::continuous(4, 0, 1));
    cfg.high_water = 2;
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel();
        let (b, c) = (&backend, &cfg);
        let server = s.spawn(move || serve_net(b, c, None, move |h| drop(tx.send(h))));
        let h = rx.recv().unwrap();

        let mut conn = TcpStream::connect(h.addr()).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let n = 40usize;
        for i in 0..n {
            conn.write_all(format!("{{\"id\":{i},\"tokens\":[{},{i},7]}}\n", i % 2).as_bytes())
                .unwrap();
        }
        let (mut ok, mut shed) = (0usize, 0usize);
        for _ in 0..n {
            let line = read_line(&mut reader);
            let j = Json::parse(&line).unwrap();
            match j.get("code").and_then(Json::as_f64) {
                None => ok += 1,
                Some(code) if code == 429.0 => {
                    assert_eq!(j.get("error").and_then(Json::as_str), Some("shed"));
                    assert!(j.get("id").and_then(Json::as_f64).is_some(), "{line}");
                    shed += 1;
                }
                Some(code) => panic!("unexpected error code {code} in {line}"),
            }
        }
        assert_eq!(ok + shed, n, "exactly one response line per request");
        assert!(ok >= 1, "the first request must be admitted");
        assert!(shed >= 1, "a 40-request burst over high-water 2 must shed");

        drop((conn, reader));
        h.shutdown();
        let (stats, report) = server.join().unwrap().unwrap();
        assert_eq!(report.ok_lines, ok);
        assert_eq!(report.shed_lines, shed);
        assert_eq!(stats.shed, shed, "wire sheds must match scheduler sheds");
        assert_eq!(stats.completed, ok);
    });
}

/// Past `max_conns`, a new connection gets the structured 503 line and a
/// clean close — while the connection already inside keeps being served.
#[test]
fn connection_limit_refuses_with_structured_line() {
    let backend = StubBackend::new(2);
    let mut cfg = net_cfg(ServerConfig::continuous(1, 0, 1));
    cfg.max_conns = 1;
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel();
        let (b, c) = (&backend, &cfg);
        let server = s.spawn(move || serve_net(b, c, None, move |h| drop(tx.send(h))));
        let h = rx.recv().unwrap();

        let mut first = TcpStream::connect(h.addr()).unwrap();
        let mut r1 = BufReader::new(first.try_clone().unwrap());
        // serving one request proves the connection is registered before
        // the second connect below
        first.write_all(b"{\"id\":1,\"tokens\":[3,1,7]}\n").unwrap();
        assert_eq!(parse_ok(&read_line(&mut r1)), (1, 1, 1011f32.to_bits()));

        let second = TcpStream::connect(h.addr()).unwrap();
        let mut r2 = BufReader::new(second);
        let refusal = read_line(&mut r2);
        let j = Json::parse(&refusal).unwrap();
        assert_eq!(j.get("code").and_then(Json::as_f64), Some(503.0));
        assert_eq!(
            j.get("error").and_then(Json::as_str),
            Some("too_many_connections")
        );
        let mut rest = String::new();
        assert_eq!(r2.read_line(&mut rest).unwrap(), 0, "refused conn must close");

        // the surviving connection is unaffected
        first.write_all(b"{\"id\":2,\"tokens\":[4,2,7]}\n").unwrap();
        assert_eq!(parse_ok(&read_line(&mut r1)), (2, 0, 13f32.to_bits()));

        drop((first, r1, r2));
        h.shutdown();
        let (_, report) = server.join().unwrap().unwrap();
        assert_eq!(report.connections, 1);
        assert_eq!(report.conns_refused, 1);
    });
}

/// Malformed lines over the socket: each gets exactly one 400 line with a
/// detail message, the connection survives all of them, and a valid
/// request afterwards is served normally.
#[test]
fn malformed_lines_get_one_400_each_and_the_connection_survives() {
    let backend = StubBackend::new(3);
    let cfg = net_cfg(ServerConfig::continuous(2, 0, 1));
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel();
        let (b, c) = (&backend, &cfg);
        let server = s.spawn(move || serve_net(b, c, None, move |h| drop(tx.send(h))));
        let h = rx.recv().unwrap();

        let mut conn = TcpStream::connect(h.addr()).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let deep = format!(
            "{{\"id\":1,\"junk\":{}{},\"tokens\":[0,1,7]}}",
            "[".repeat(300),
            "]".repeat(300)
        );
        let bad: Vec<Vec<u8>> = vec![
            b"this is not json".to_vec(),
            b"{\"id\":1,\"text\":\"\\uD83D\"}".to_vec(), // unpaired surrogate
            b"{\"id\":2,\"text\":\"truncated".to_vec(),  // unterminated string
            deep.into_bytes(),                           // past MAX_DEPTH
            b"{\"id\":99999999999999999999999,\"tokens\":[1]}".to_vec(), // id > u64
            b"{\"id\":3,\"tokens\":[99999999999]}".to_vec(), // token > u32
            b"{\"id\":4,\"text\":\"\xff\xfe\"}".to_vec(), // invalid utf-8
            b"{\"id\":5}".to_vec(),                      // no body field
        ];
        for line in &bad {
            conn.write_all(line).unwrap();
            conn.write_all(b"\n").unwrap();
        }
        conn.write_all(b"   \r\n").unwrap(); // blank: ignored, no response
        conn.write_all(b"{\"id\":7,\"tokens\":[2,7,7]}\n").unwrap();

        for line in &bad {
            let resp = read_line(&mut reader);
            let j = Json::parse(&resp).unwrap();
            assert_eq!(
                j.get("code").and_then(Json::as_f64),
                Some(400.0),
                "for {:?} got {resp}",
                String::from_utf8_lossy(line)
            );
            assert_eq!(j.get("error").and_then(Json::as_str), Some("bad_request"));
            let detail = j.get("detail").and_then(Json::as_str).unwrap();
            assert!(!detail.is_empty(), "400 lines must say what was wrong");
        }
        assert_eq!(parse_ok(&read_line(&mut reader)), (7, 2, 2016f32.to_bits()));

        drop((conn, reader));
        h.shutdown();
        let (stats, report) = server.join().unwrap().unwrap();
        assert_eq!(report.bad_lines, bad.len());
        assert_eq!(report.ok_lines, 1, "the blank line must produce nothing");
        assert_eq!(stats.completed, 1);
    });
}

// ---------------------------------------------------------------------
// adversarial input properties (no sockets)
// ---------------------------------------------------------------------

/// Random bytes through every parsing layer: the tree parser, the pull
/// lexer, the request extractor, and the line splitter must return
/// structured errors, never panic.
#[test]
fn random_bytes_never_panic_any_parser() {
    prop::check(
        "parsers-never-panic",
        400,
        |r| {
            let n = r.usize_below(80);
            (0..n).map(|_| r.below(256) as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            let _ = parse_request_line(bytes);
            let mut lex = Lexer::new(bytes);
            while let Ok(Some(_)) = lex.next_token() {}
            let _ = Json::parse(&String::from_utf8_lossy(bytes));
            let mut buf = LineBuf::new();
            buf.feed(bytes);
            while let Some(line) = buf.next_line() {
                let _ = parse_request_line(line);
            }
            Ok(())
        },
    );
}

/// The targeted adversarial corpus: truncated escapes, lone surrogates,
/// pathological nesting, numbers past every width, raw garbage. All
/// structured errors (the pre-hardening parser panicked on several).
#[test]
fn adversarial_corpus_yields_structured_errors() {
    let cases: Vec<Vec<u8>> = vec![
        b"{\"id\":1,\"text\":\"\\u".to_vec(),
        b"{\"id\":1,\"text\":\"\\u00".to_vec(),
        b"{\"id\":1,\"text\":\"\\u+fff\"}".to_vec(),
        b"{\"id\":1,\"text\":\"\\u000\xc3\xa9\"}".to_vec(), // multibyte in hex window
        b"{\"id\":1,\"text\":\"\\uD800\"}".to_vec(),        // lone high surrogate
        b"{\"id\":1,\"text\":\"\\uDC00\"}".to_vec(),        // lone low surrogate
        b"{\"id\":1,\"text\":\"\\uD83D\\u0041\"}".to_vec(), // high + non-low
        "[".repeat(100_000).into_bytes(),                   // deep nesting
        vec![0xff; 64],
        b"\"unterminated".to_vec(),
    ];
    for case in &cases {
        assert!(
            parse_request_line(case).is_err(),
            "extractor accepted {:?}",
            String::from_utf8_lossy(case)
        );
        // the tree parser must agree that these are syntax errors (valid
        // UTF-8 cases only — its input type already rules out the rest)
        if let Ok(text) = std::str::from_utf8(case) {
            assert!(Json::parse(text).is_err(), "tree parser accepted {text:?}");
        }
    }
    // syntactically valid JSON the extractor still refuses: numbers past
    // the width the wire contract demands (the f64 tree path would round
    // them — exactly why ids go through the raw-slice lexer)
    for case in [
        &br#"{"id":18446744073709551616,"tokens":[]}"#[..], // u64::MAX + 1
        br#"{"id":1,"tokens":[4294967296]}"#,               // u32::MAX + 1
        br#"{"id":1e999,"tokens":[]}"#,
    ] {
        assert!(
            parse_request_line(case).is_err(),
            "extractor accepted {:?}",
            String::from_utf8_lossy(case)
        );
        Json::parse(std::str::from_utf8(case).unwrap())
            .expect("these are valid JSON for the f64 tree path");
    }
}

/// Rebuild a `Json` value from the pull lexer's token stream — the test
/// oracle for tree/lexer agreement.
fn lex_build(lex: &mut Lexer<'_>) -> Result<Json, String> {
    let t = next_tok(lex)?;
    lex_build_from(lex, t)
}

fn next_tok<'a>(lex: &mut Lexer<'a>) -> Result<Token<'a>, String> {
    lex.next_token()
        .map_err(|e| e.to_string())?
        .ok_or_else(|| "unexpected end of input".to_string())
}

fn lex_build_from(lex: &mut Lexer<'_>, t: Token<'_>) -> Result<Json, String> {
    match t {
        Token::Null => Ok(Json::Null),
        Token::Bool(b) => Ok(Json::Bool(b)),
        Token::Num(raw) => raw.parse::<f64>().map(Json::Num).map_err(|e| e.to_string()),
        Token::Str(s) => Ok(Json::Str(s.into_owned())),
        Token::ArrOpen => {
            let mut items = Vec::new();
            let mut t = next_tok(lex)?;
            if matches!(t, Token::ArrClose) {
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(lex_build_from(lex, t)?);
                match next_tok(lex)? {
                    Token::ArrClose => return Ok(Json::Arr(items)),
                    Token::Comma => t = next_tok(lex)?,
                    other => return Err(format!("expected ',' or ']', got {other:?}")),
                }
            }
        }
        Token::ObjOpen => {
            let mut m = std::collections::BTreeMap::new();
            loop {
                match next_tok(lex)? {
                    Token::ObjClose => return Ok(Json::Obj(m)),
                    Token::Str(k) => {
                        match next_tok(lex)? {
                            Token::Colon => {}
                            other => return Err(format!("expected ':', got {other:?}")),
                        }
                        m.insert(k.into_owned(), lex_build(lex)?);
                        match next_tok(lex)? {
                            Token::Comma => {}
                            Token::ObjClose => return Ok(Json::Obj(m)),
                            other => return Err(format!("expected ',' or '}}', got {other:?}")),
                        }
                    }
                    other => return Err(format!("expected a key, got {other:?}")),
                }
            }
        }
        other => Err(format!("unexpected {other:?}")),
    }
}

fn gen_string(r: &mut Rng) -> String {
    // escape-heavy pool: quotes, backslashes, controls, multibyte,
    // astral (surrogate-pair territory when escaped)
    let pool: &[&str] = &["a", "z9 ", "é", "汉", "😀", "\"", "\\", "\n", "\t", "\u{7}"];
    (0..r.usize_below(8))
        .map(|_| pool[r.usize_below(pool.len())])
        .collect()
}

fn gen_json(r: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { r.below(4) } else { r.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(r.below(2) == 1),
        // halves: exact in f64, stable through Display and reparse
        2 => Json::Num(r.below(2_000_000) as f64 / 2.0 - 1000.0),
        3 => Json::Str(gen_string(r)),
        4 => Json::Arr((0..r.usize_below(4)).map(|_| gen_json(r, depth - 1)).collect()),
        _ => Json::Obj(
            (0..r.usize_below(4))
                .map(|k| (format!("k{k}"), gen_json(r, depth - 1)))
                .collect(),
        ),
    }
}

/// For any valid document, the zero-copy lexer and the tree parser
/// produce the same value — so hardening fixes in one cannot silently
/// diverge from the other.
#[test]
fn tree_parser_and_lexer_agree_on_valid_documents() {
    prop::check(
        "tree-lexer-agreement",
        250,
        |r| gen_json(r, 3).to_string(),
        |doc| {
            let tree = Json::parse(doc).map_err(|e| format!("tree rejected {doc:?}: {e}"))?;
            let mut lex = Lexer::new(doc.as_bytes());
            let lexed = lex_build(&mut lex).map_err(|e| format!("lexer rejected {doc:?}: {e}"))?;
            if lex.next_token().map_err(|e| e.to_string())?.is_some() {
                return Err(format!("lexer left trailing tokens in {doc:?}"));
            }
            if tree != lexed {
                return Err(format!("parsers disagree on {doc:?}: {tree:?} vs {lexed:?}"));
            }
            Ok(())
        },
    );
    // fixed escape-heavy documents, surrogate pairs included
    for doc in [
        r#"{"s":"\uD83D\uDE00 \u0041\t\"x\""}"#,
        r#"["\u00e9","\\","\/","\b\f\r\n"]"#,
        r#"{"deep":{"a":[1,-2.5,3e2,{"b":"\uD834\uDD1E"}]}}"#,
    ] {
        let tree = Json::parse(doc).unwrap();
        let mut lex = Lexer::new(doc.as_bytes());
        assert_eq!(tree, lex_build(&mut lex).unwrap(), "on {doc}");
    }
}

/// Splitting a byte stream at any set of points yields the same line
/// sequence as feeding it whole — the invariant the socket reader relies
/// on for requests fragmented across reads.
#[test]
fn line_splitting_is_invariant_to_read_chunking() {
    fn lines_of(chunks: &[&[u8]]) -> Vec<Vec<u8>> {
        let mut buf = LineBuf::new();
        let mut out = Vec::new();
        for chunk in chunks {
            buf.feed(chunk);
            while let Some(line) = buf.next_line() {
                out.push(line.to_vec());
            }
        }
        out
    }

    prop::check(
        "linebuf-chunking",
        250,
        |r| {
            let mut text = Vec::new();
            for i in 0..1 + r.usize_below(5) {
                text.extend_from_slice(format!("{{\"id\":{i},\"tokens\":[{}]}}", i % 7).as_bytes());
                if r.below(3) == 0 {
                    text.push(b'\r');
                }
                text.push(b'\n');
            }
            let mut cuts: Vec<usize> =
                (0..r.usize_below(6)).map(|_| r.usize_below(text.len() + 1)).collect();
            cuts.sort_unstable();
            (text, cuts)
        },
        |(text, cuts)| {
            let whole = lines_of(&[&text[..]]);
            let mut chunks = Vec::new();
            let mut prev = 0;
            for &cut in cuts {
                chunks.push(&text[prev..cut]);
                prev = cut;
            }
            chunks.push(&text[prev..]);
            let split = lines_of(&chunks);
            if whole == split {
                Ok(())
            } else {
                Err(format!("chunking changed the lines: {whole:?} vs {split:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------
// FairMux fairness
// ---------------------------------------------------------------------

/// One firehose lane with a deep backlog, one trickle lane with a single
/// item: the rotating scan must pump the trickle item within one full
/// rotation (here: within 2 pops), no matter how deep the firehose
/// backlog is.
#[test]
fn fairmux_trickle_item_is_served_within_one_rotation() {
    let mux: FairMux<u64> = FairMux::new();
    let firehose = mux.register();
    let trickle = mux.register();
    // the firehose piles up 100 items before the trickle client speaks
    for i in 0..100 {
        mux.push(firehose, i);
    }
    mux.push(trickle, 1_000);
    // pop twice: one rotation over 2 lanes must include the trickle lane
    let first_two = [mux.next().unwrap(), mux.next().unwrap()];
    assert!(
        first_two.contains(&1_000),
        "trickle item waited past a full rotation: {first_two:?}"
    );
}

/// Under sustained pressure from the firehose, the pump alternates: each
/// rotation serves at most one firehose item before the trickle lane gets
/// its turn, so neither lane starves — the trickle lane's k-th item is
/// pumped within k rotations, and the firehose still drains completely.
#[test]
fn fairmux_neither_lane_starves_under_full_queues() {
    let mux: FairMux<(usize, u64)> = FairMux::new();
    let firehose = mux.register();
    let trickle = mux.register();
    for i in 0..50 {
        mux.push(firehose, (firehose, i));
    }
    for i in 0..5 {
        mux.push(trickle, (trickle, i));
    }
    mux.drain();
    let order: Vec<(usize, u64)> = std::iter::from_fn(|| mux.next()).collect();
    assert_eq!(order.len(), 55, "drain must pump every queued item");
    // every trickle item appears within a bounded number of rounds: item
    // k sits behind at most k firehose items (strict alternation while
    // both lanes are non-empty)
    for (k, pos) in order
        .iter()
        .enumerate()
        .filter(|(_, &(lane, _))| lane == trickle)
        .map(|(pos, &(_, k))| (k, pos))
    {
        assert!(
            pos <= 2 * (k as usize) + 1,
            "trickle item {k} starved until position {pos}: {order:?}"
        );
    }
    // the firehose is not starved either: it drains in FIFO order
    let fire: Vec<u64> = order
        .iter()
        .filter(|&&(lane, _)| lane == firehose)
        .map(|&(_, i)| i)
        .collect();
    assert_eq!(fire, (0..50).collect::<Vec<u64>>());
}

/// `next` blocks while every lane is empty; `drain` releases it. A pump
/// thread must see an item pushed *after* it started waiting.
#[test]
fn fairmux_next_wakes_on_late_push_and_drain() {
    let mux: std::sync::Arc<FairMux<u32>> = std::sync::Arc::new(FairMux::new());
    let lane = mux.register();
    let pump = {
        let mux = std::sync::Arc::clone(&mux);
        std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = mux.next() {
                got.push(v);
            }
            got
        })
    };
    std::thread::sleep(Duration::from_millis(20));
    mux.push(lane, 7);
    std::thread::sleep(Duration::from_millis(20));
    mux.drain();
    let got = pump.join().expect("pump thread panicked");
    assert_eq!(got, vec![7]);
}
