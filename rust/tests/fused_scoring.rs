//! Fused all-routers scoring suite: the stacked-parameter device cache
//! and the `prefix_nll_all_{m}` scoring path.
//!
//! Two tiers, following `rust/tests/concurrency.rs`:
//!
//! * **Stub backend (tier-1, no artifacts):** the vendored xla stub keeps
//!   host-side uploads real, so a handwritten temp-dir manifest gives a
//!   live [`Engine`] whose stacked cache is fully exercisable — exactly
//!   one stack build + upload per router-set version under an 8-thread
//!   race, eviction when any *single* member's version bumps, and exact
//!   byte accounting.
//! * **Artifacts-gated (standard self-skip):** with compiled artifacts
//!   that carry fused entries (`aot.py --fused`), the fused score matrix
//!   is bit-identical to the per-router fan-out at worker counts {1, E},
//!   executes exactly `ceil(B / prefix_batch)` kernels per B-sequence
//!   matrix (vs `E ×` that on the fan-out path, asserted via
//!   [`EngineStats`]), and re-stacks parameters only when a router's
//!   version bumps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use smalltalk::coordinator::scoring::{
    score_matrix_rows_fanout, score_matrix_rows_fused, score_matrix_rows_threaded,
};
use smalltalk::coordinator::{run_pipeline, PipelineConfig};
use smalltalk::data::SequenceGen;
use smalltalk::runtime::engine::f32_literal;
use smalltalk::runtime::{locate_artifacts, stacked_params_buffer, Engine, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};

// ---------------------------------------------------------------------
// stub-backend engine (tier-1): real uploads, no execution
// ---------------------------------------------------------------------

const STUB_MANIFEST: &str = r#"{
  "fingerprint": "fused-scoring-test-stub",
  "variants": [{
    "name": "stub", "role": "router", "vocab": 512, "seq_len": 64,
    "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ffw": 16,
    "param_count": 32, "train_batch": 4, "eval_batch": 4,
    "prefix_batch": 4, "prefix_len": 8, "prefix_lens": [8],
    "fused_experts": 4,
    "opt": {"peak_lr": 0.001, "warmup_steps": 10, "total_steps": 100,
            "schedule": "constant", "weight_decay": 0.1, "clip_norm": 1.0},
    "entry_points": ["init", "train_step", "eval_nll", "prefix_nll_8",
                     "prefix_nll_all_8"]
  }]
}"#;

fn stub_engine() -> Engine {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "smalltalk_fused_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("creating stub manifest dir");
    std::fs::write(dir.join("manifest.json"), STUB_MANIFEST).expect("writing stub manifest");
    Engine::new(&dir).expect("stub engine must construct without artifacts")
}

/// Stub router with `n` distinguishable parameters.
fn stub_state(fill: f32, n: usize) -> TrainState {
    TrainState::from_params("stub", vec![fill; n], vec![0.0; n], vec![0.0; n], 0)
}

// ---------------------------------------------------------------------
// the stacked cache under contention (tier-1)
// ---------------------------------------------------------------------

/// Many threads hammer `stacked_buffer` for the same ordered member list
/// behind a barrier, across several version rounds: the stack literal
/// must be built + uploaded exactly once per router-set version — not
/// "roughly once" — with every byte accounted for, and each later round
/// must evict the previous stack exactly once.
#[test]
fn stacked_cache_builds_once_per_version_under_contention() {
    const THREADS: usize = 8;
    const ROUNDS: u64 = 5;
    const CALLS_PER_ROUND: usize = 4;
    const E: usize = 3;
    const FLOATS: usize = 16; // per-member literal share: 64 B

    let eng = stub_engine();
    let made = AtomicUsize::new(0);
    let barrier = Barrier::new(THREADS);
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for version in 0..ROUNDS {
                    // enter the round together: every miss is contended
                    barrier.wait();
                    for _ in 0..CALLS_PER_ROUND {
                        let members: Vec<(u64, u64)> =
                            (1..=E as u64).map(|id| (id, version)).collect();
                        let buf = eng
                            .stacked_buffer(&members, || {
                                made.fetch_add(1, Ordering::SeqCst);
                                Ok(f32_literal(&[version as f32; E * FLOATS]))
                            })
                            .expect("stub uploads cannot fail");
                        assert_eq!(buf.bytes(), (E * FLOATS * 4) as u64);
                    }
                }
            });
        }
    });

    let stats = eng.stats();
    assert_eq!(
        made.load(Ordering::SeqCst),
        ROUNDS as usize,
        "the stack builder must run exactly once per router-set version"
    );
    assert_eq!(stats.stack_rebuilds, ROUNDS as usize);
    assert_eq!(stats.uploads, ROUNDS as usize);
    assert_eq!(stats.h2d_bytes, ROUNDS * (E * FLOATS * 4) as u64);
    // every version round after the first replaces the resident stack
    assert_eq!(stats.cache_evictions, ROUNDS as usize - 1);
    // one ordered member list -> one live entry
    assert_eq!(eng.stacked_cache_entries(), 1);
    assert_eq!(stats.param_uploads, 0, "stacked uploads are not per-state uploads");
}

/// Any single member's version bump evicts the stack; member order is
/// part of the identity (a permutation is a different stack).
#[test]
fn single_member_version_bump_evicts_the_stack() {
    let eng = stub_engine();
    let build = |eng: &Engine, members: &[(u64, u64)]| {
        eng.stacked_buffer(members, || Ok(f32_literal(&[1.0; 8])))
            .unwrap()
    };

    build(&eng, &[(1, 0), (2, 0), (3, 0)]);
    assert_eq!(eng.stats().stack_rebuilds, 1);

    // same members, same versions: resident, nothing rebuilt
    build(&eng, &[(1, 0), (2, 0), (3, 0)]);
    assert_eq!(eng.stats().stack_rebuilds, 1);
    assert_eq!(eng.stats().cache_evictions, 0);

    // ONE member bumps: rebuild + evict exactly once
    build(&eng, &[(1, 0), (2, 1), (3, 0)]);
    assert_eq!(eng.stats().stack_rebuilds, 2);
    assert_eq!(eng.stats().cache_evictions, 1);

    // a permutation is its own ordered set (fresh entry, no eviction)
    build(&eng, &[(3, 0), (2, 1), (1, 0)]);
    assert_eq!(eng.stats().stack_rebuilds, 3);
    assert_eq!(eng.stats().cache_evictions, 1);
    assert_eq!(eng.stacked_cache_entries(), 2);

    // clear_device_cache drops stacked buffers too
    eng.clear_device_cache();
    assert_eq!(eng.stacked_cache_entries(), 0);
    build(&eng, &[(1, 0), (2, 1), (3, 0)]);
    assert_eq!(eng.stats().stack_rebuilds, 4);
}

/// `stacked_params_buffer` stacks real `TrainState`s: the upload is the
/// concatenated `[E, P]` tensor (bytes exact), repeat calls are free, and
/// a member's parameter change (version bump) re-stacks automatically.
#[test]
fn stacked_params_buffer_tracks_member_versions() {
    let eng = stub_engine();
    const P: usize = 32;
    let mut states = vec![stub_state(1.0, P), stub_state(2.0, P), stub_state(3.0, P)];

    {
        let refs: Vec<&TrainState> = states.iter().collect();
        let buf = stacked_params_buffer(&eng, &refs).unwrap();
        assert_eq!(buf.bytes(), (3 * P * 4) as u64, "stack is the full [E, P] tensor");
    }
    let s = eng.stats();
    assert_eq!((s.stack_rebuilds, s.uploads), (1, 1));
    assert_eq!(s.h2d_bytes, (3 * P * 4) as u64);

    // unchanged members: served resident
    {
        let refs: Vec<&TrainState> = states.iter().collect();
        stacked_params_buffer(&eng, &refs).unwrap();
    }
    assert_eq!(eng.stats().stack_rebuilds, 1);

    // one member's params change out-of-band -> version bump -> re-stack
    states[1].params[0] = 99.0;
    states[1].invalidate_device_cache();
    {
        let refs: Vec<&TrainState> = states.iter().collect();
        stacked_params_buffer(&eng, &refs).unwrap();
    }
    let s = eng.stats();
    assert_eq!(s.stack_rebuilds, 2);
    assert_eq!(s.cache_evictions, 1);
    assert_eq!(s.h2d_bytes, 2 * (3 * P * 4) as u64);

    // a padded chunk (repeated member) is a distinct, valid ordered set
    {
        let refs: Vec<&TrainState> = vec![&states[0], &states[1], &states[1], &states[1]];
        let buf = stacked_params_buffer(&eng, &refs).unwrap();
        assert_eq!(buf.bytes(), (4 * P * 4) as u64);
    }
    assert_eq!(eng.stats().stack_rebuilds, 3);
    assert_eq!(eng.stacked_cache_entries(), 2);
}

/// Stacking mismatched parameter vectors (or nothing) is a structured
/// error, not a bad reshape or a panic.
#[test]
fn stacked_params_buffer_rejects_bad_sets() {
    let eng = stub_engine();
    let a = stub_state(1.0, 32);
    let b = stub_state(2.0, 16);
    let err = stacked_params_buffer(&eng, &[&a, &b]).unwrap_err().to_string();
    assert!(err.contains("mismatched parameter vectors"), "{err}");
    assert!(stacked_params_buffer(&eng, &[]).is_err());
    // the failed builds left no live entry and no accounting residue
    assert_eq!(eng.stacked_cache_entries(), 0);
    assert_eq!(eng.stats().stack_rebuilds, 0);
    assert_eq!(eng.stats().uploads, 0);
}

// ---------------------------------------------------------------------
// XLA-backed tests (self-skip without artifacts; the fused tests also
// self-skip on pre-fused manifests, which lack prefix_nll_all entries)
// ---------------------------------------------------------------------

struct Setup {
    engine: Engine,
    bpe: Bpe,
    mixture: smalltalk::coordinator::Mixture,
}

static SETUP: std::sync::OnceLock<Option<Setup>> = std::sync::OnceLock::new();

/// One trained E=4 mixture shared by the execution tests (the pattern of
/// `rust/tests/concurrency.rs`). Tests that assert on engine stats build
/// their own private engine instead of touching this shared one.
fn setup() -> Option<&'static Setup> {
    SETUP
        .get_or_init(|| {
            let dir = locate_artifacts()?;
            let engine = Engine::new(dir).expect("loading artifacts");
            let corpus = smalltalk::data::corpus::Corpus::generate(60, 400, 42, None);
            let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
            let cfg = PipelineConfig {
                router_variant: "router_micro".into(),
                expert_variant: "expert_sm".into(),
                n_experts: 4,
                em_rounds: 2,
                em_chunk: 96,
                em_steps_per_round: 8,
                shard_sequences: 128,
                expert_steps: 10,
                prefix_len: 32,
                seed: 3,
                threads: 0,
            };
            let mixture = run_pipeline(&engine, &bpe, &cfg)
                .expect("training the shared test mixture")
                .mixture;
            Some(Setup { engine, bpe, mixture })
        })
        .as_ref()
}

/// Fused and fan-out score matrices are bit-identical — misaligned tail
/// batch included — at worker counts {1, E}, and the auto-dispatch entry
/// agrees with both.
#[test]
fn fused_matches_fanout_bit_for_bit() {
    let Some(setup) = setup() else { return };
    let meta = &setup.mixture.router_meta;
    let m = 32usize;
    if meta.fused_prefix_entry(m).is_none() {
        eprintln!("[fused_scoring] manifest has no prefix_nll_all_{m} — re-run `make artifacts`; skipping");
        return;
    }
    let routers = &setup.mixture.routers;
    let e = routers.len();
    let pool: Vec<Vec<u32>> = SequenceGen::new(&setup.bpe, meta.seq_len, 23)
        .batch(meta.prefix_batch + 3) // misaligned: full batch + short tail
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    let rows: Vec<&[u32]> = pool.iter().map(|r| &r[..m]).collect();

    let reference =
        score_matrix_rows_fanout(&setup.engine, routers, meta, &rows, m, 1).unwrap();
    assert_eq!(reference.len(), rows.len());
    for threads in [1usize, e] {
        let fused =
            score_matrix_rows_fused(&setup.engine, routers, meta, &rows, m, threads).unwrap();
        let auto =
            score_matrix_rows_threaded(&setup.engine, routers, meta, &rows, m, threads).unwrap();
        for (i, (f, r)) in fused.iter().zip(&reference).enumerate() {
            assert_eq!(f.len(), r.len());
            for j in 0..e {
                assert_eq!(
                    f[j].to_bits(),
                    r[j].to_bits(),
                    "threads={threads}: fused [{i}][{j}] diverged from fan-out"
                );
            }
        }
        assert_eq!(auto, fused, "threads={threads}: auto-dispatch must take the fused path");
    }
}

/// Launch accounting (the acceptance criterion): a B-sequence matrix
/// costs `ceil(B / prefix_batch)` fused executions — vs `E ×` that many
/// on the fan-out path — and the stacked parameters upload exactly once
/// per router-set version across repeated calls.
#[test]
fn fused_launch_and_stack_accounting() {
    let Some(setup) = setup() else { return };
    let Some(dir) = locate_artifacts() else { return };
    let meta = &setup.mixture.router_meta;
    let m = 32usize;
    if meta.fused_prefix_entry(m).is_none() {
        eprintln!("[fused_scoring] manifest has no prefix_nll_all_{m} — re-run `make artifacts`; skipping");
        return;
    }
    // private engine: isolate counters from concurrently running tests
    let eng = Engine::new(dir).expect("loading artifacts");
    let mut routers = setup.mixture.routers.clone();
    let e = routers.len();
    let bs = meta.prefix_batch;
    let b = 2 * bs + 3; // 3 spans
    let spans = b.div_ceil(bs);
    let pool: Vec<Vec<u32>> = SequenceGen::new(&setup.bpe, meta.seq_len, 29)
        .batch(b)
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    let rows: Vec<&[u32]> = pool.iter().map(|r| &r[..m]).collect();

    // warm the compile cache so executions, not compiles, are measured
    score_matrix_rows_fanout(&eng, &routers, meta, &rows, m, 1).unwrap();
    score_matrix_rows_fused(&eng, &routers, meta, &rows, m, 1).unwrap();

    let s0 = eng.stats();
    score_matrix_rows_fanout(&eng, &routers, meta, &rows, m, 1).unwrap();
    let fanout = eng.stats().since(&s0);
    assert_eq!(fanout.executions, e * spans, "fan-out: one launch per (router, batch)");
    assert_eq!(fanout.fused_executions, 0);

    let s0 = eng.stats();
    score_matrix_rows_fused(&eng, &routers, meta, &rows, m, 1).unwrap();
    let fused = eng.stats().since(&s0);
    assert_eq!(fused.executions, spans, "fused: one launch per batch, not per router");
    assert_eq!(fused.fused_executions, spans);
    assert_eq!(
        fused.router_execs_avoided,
        (e - 1) * spans,
        "each fused launch replaces E per-router launches"
    );
    assert_eq!(fused.stack_rebuilds, 0, "the warm-up call already stacked this version");

    // stacked params upload once per router-set version: a member's bump
    // re-stacks exactly once, then stays resident again
    routers[1].invalidate_device_cache();
    let s0 = eng.stats();
    score_matrix_rows_fused(&eng, &routers, meta, &rows, m, 1).unwrap();
    score_matrix_rows_fused(&eng, &routers, meta, &rows, m, 1).unwrap();
    let d = eng.stats().since(&s0);
    assert_eq!(d.stack_rebuilds, 1, "one re-stack per router-set version, not per call");
}

/// Router sets away from the compiled fused width still score correctly:
/// a narrower set pads its only chunk, a wider set scores in fused
/// chunks — both bit-identical to the fan-out columns.
#[test]
fn fused_pads_and_chunks_off_width_router_sets() {
    let Some(setup) = setup() else { return };
    let meta = &setup.mixture.router_meta;
    let m = 32usize;
    if meta.fused_prefix_entry(m).is_none() {
        eprintln!("[fused_scoring] manifest has no prefix_nll_all_{m} — re-run `make artifacts`; skipping");
        return;
    }
    let pool: Vec<Vec<u32>> = SequenceGen::new(&setup.bpe, meta.seq_len, 31)
        .batch(meta.prefix_batch + 1)
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    let rows: Vec<&[u32]> = pool.iter().map(|r| &r[..m]).collect();

    // narrower than the compiled width (padded chunk) and wider (2 chunks)
    let narrow: Vec<TrainState> = setup.mixture.routers[..2].to_vec();
    let mut wide: Vec<TrainState> = setup.mixture.routers.clone();
    wide.push(setup.mixture.routers[0].clone());

    for (label, set) in [("narrow", &narrow), ("wide", &wide)] {
        let reference = score_matrix_rows_fanout(&setup.engine, set, meta, &rows, m, 1).unwrap();
        for threads in [1usize, set.len()] {
            let fused =
                score_matrix_rows_fused(&setup.engine, set, meta, &rows, m, threads).unwrap();
            assert_eq!(fused.len(), reference.len(), "{label}");
            for (i, (f, r)) in fused.iter().zip(&reference).enumerate() {
                assert_eq!(f.len(), set.len(), "{label} row {i} width");
                for j in 0..set.len() {
                    assert_eq!(
                        f[j].to_bits(),
                        r[j].to_bits(),
                        "{label} threads={threads}: [{i}][{j}] diverged"
                    );
                }
            }
        }
    }
}
