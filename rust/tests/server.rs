//! Continuous-batching server suite: queue accounting and scheduler edge
//! cases on a deterministic stub backend (tier-1, no artifacts needed),
//! plus artifacts-gated determinism tests proving the continuous server
//! answers every request identically to the closed-wave reference for any
//! arrival order, worker count, batch size, and linger setting.
//!
//! Two tiers, following `rust/tests/concurrency.rs`:
//! * the stub tests exercise [`run_server`]'s admission/dispatch state
//!   machine through the public [`ServeBackend`] trait — routing and NLL
//!   are pure functions of the tokens, so `(id, expert, nll)` triples are
//!   comparable across any batching without compiled artifacts;
//! * the XLA-backed tests train a small mixture and hold the real
//!   [`MixtureBackend`] to the same bar (standard self-skip without
//!   `artifacts/manifest.json`).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use smalltalk::coordinator::{
    response_triples as triples, run_pipeline, run_server, serve_threaded, MixtureBackend,
    PipelineConfig, Request, ServeBackend, ServerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine};
use smalltalk::tokenizer::BpeTrainer;

// ---------------------------------------------------------------------
// deterministic stub backend (tier-1)
// ---------------------------------------------------------------------

/// Routing and NLL are pure functions of the tokens: route by first
/// token, NLL = expert * 1000 + token sum. Any batching of any arrival
/// order must therefore produce the same `(id, expert, nll)` triples.
struct StubBackend {
    n: usize,
    /// Per-expert execution delay (straggler simulation).
    delay: Vec<Duration>,
    /// Log of dispatched batch sizes per expert, for batching assertions.
    batches: Mutex<Vec<(usize, usize)>>,
}

impl StubBackend {
    fn new(n: usize) -> Self {
        StubBackend {
            n,
            delay: vec![Duration::ZERO; n],
            batches: Mutex::new(Vec::new()),
        }
    }

    fn with_delay(mut self, expert: usize, delay: Duration) -> Self {
        self.delay[expert] = delay;
        self
    }

    fn expected(&self, req: &Request) -> (u64, usize, u32) {
        let e = req.tokens.first().copied().unwrap_or(0) as usize % self.n;
        let nll = e as f32 * 1000.0 + req.tokens.iter().sum::<u32>() as f32;
        (req.id, e, nll.to_bits())
    }
}

impl ServeBackend for StubBackend {
    fn n_experts(&self) -> usize {
        self.n
    }

    fn route(&self, rows: &[&[u32]], _threads: usize) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
            .collect())
    }

    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        self.batches.lock().unwrap().push((expert, rows.len()));
        if !self.delay[expert].is_zero() {
            std::thread::sleep(self.delay[expert]);
        }
        Ok(rows
            .iter()
            .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
            .collect())
    }
}

fn req(id: u64, first_token: u32) -> Request {
    // three tokens so the NLL separates requests with the same route
    Request {
        id,
        tokens: vec![first_token, id as u32, 7],
    }
}

// ---------------------------------------------------------------------
// scheduler edge cases (tier-1)
// ---------------------------------------------------------------------

/// Empty queue: a driver that submits nothing gets an empty response set
/// and an untouched scheduler.
#[test]
fn empty_queue_serves_nothing() {
    let backend = StubBackend::new(3);
    let (out, stats, ()) = run_server(&backend, &ServerConfig::continuous(4, 1000, 2), |_c| {})
        .unwrap();
    assert!(out.is_empty());
    assert_eq!(stats.submitted, 0);
    assert_eq!(stats.admitted, 0);
    assert_eq!(stats.admission_waves, 0);
    assert_eq!(stats.batches_dispatched, 0);
    assert_eq!(stats.completed, 0);
    assert!(backend.batches.lock().unwrap().is_empty(), "no batch may execute");
}

/// A single request flows through admission, linger/drain dispatch, and
/// completion — one wave, one batch.
#[test]
fn single_request_single_batch() {
    let backend = StubBackend::new(3);
    let r = req(5, 1);
    let want = backend.expected(&r);
    let (out, stats, ()) = run_server(&backend, &ServerConfig::continuous(8, 1000, 1), |c| {
        assert!(c.submit(r.clone()));
    })
    .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(triples(&out), vec![want]);
    assert_eq!(stats.submitted, 1);
    assert_eq!(stats.admitted, 1);
    assert_eq!(stats.admission_waves, 1);
    assert_eq!(stats.batches_dispatched, 1);
    assert_eq!(stats.completed, 1);
}

/// Duplicate request ids are independent requests: both are answered,
/// each with its own tokens' NLL.
#[test]
fn duplicate_request_ids_both_answered() {
    let backend = StubBackend::new(2);
    let a = Request { id: 9, tokens: vec![0, 1, 1] };
    let b = Request { id: 9, tokens: vec![1, 2, 2] };
    let mut want = vec![backend.expected(&a), backend.expected(&b)];
    want.sort_unstable();
    let (out, stats, ()) = run_server(&backend, &ServerConfig::continuous(4, 500, 2), |c| {
        c.submit(a.clone());
        c.submit(b.clone());
    })
    .unwrap();
    assert_eq!(out.len(), 2, "both duplicates answered");
    assert_eq!(triples(&out), want);
    assert_eq!(stats.completed, 2);
}

/// Everything routes to one expert: batches fill and dispatch at exactly
/// `batch_size`, the remainder leaves at drain, nothing touches the other
/// experts.
#[test]
fn all_requests_to_one_expert_batches_exactly() {
    let backend = StubBackend::new(4);
    // first token 0 mod 4 -> expert 0, for all ten requests
    let reqs: Vec<Request> = (0..10).map(|i| req(i, 0)).collect();
    let cfg = ServerConfig {
        batch_size: 4,
        max_wait_us: u64::MAX, // no linger: dispatch boundaries are exact
        admission_max: 0,
        threads: 2,
        replicas: 1,
        replication: 1,
        rebalance_every: 0,
    };
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        c.submit_wave(reqs.clone());
    })
    .unwrap();
    assert_eq!(out.len(), 10);
    for r in &out {
        assert_eq!(r.expert, 0);
    }
    assert_eq!(stats.batches_dispatched, 3, "4 + 4 + drain(2)");
    assert_eq!(stats.full_batches, 2);
    assert_eq!(stats.linger_batches, 0);
    assert_eq!(stats.drain_batches, 1);
    let mut batches = backend.batches.lock().unwrap().clone();
    batches.sort_unstable();
    assert_eq!(batches, vec![(0, 2), (0, 4), (0, 4)]);
}

/// Arrival-order permutations produce identical `(id, expert, nll)`
/// triples across worker counts, batch sizes, and linger settings —
/// and responses always come back in submission order.
#[test]
fn arrival_permutations_yield_identical_triples() {
    let backend = StubBackend::new(3);
    let base: Vec<Request> = (0..12).map(|i| req(i, i as u32)).collect();
    let mut want: Vec<(u64, usize, u32)> = base.iter().map(|r| backend.expected(r)).collect();
    want.sort_unstable();

    let mut orders: Vec<Vec<Request>> = vec![base.clone()];
    let mut rev = base.clone();
    rev.reverse();
    orders.push(rev);
    // interleave: evens then odds
    let mut inter: Vec<Request> = base.iter().step_by(2).cloned().collect();
    inter.extend(base.iter().skip(1).step_by(2).cloned());
    orders.push(inter);

    for (threads, batch_size, max_wait_us) in
        [(1, 1, 0), (2, 3, 200), (4, 5, u64::MAX), (2, 0, 100)]
    {
        for (o, order) in orders.iter().enumerate() {
            let cfg = ServerConfig::continuous(batch_size, max_wait_us, threads);
            // submit one by one (individual admission races) and as one
            // atomic wave — both must agree
            let (out, stats, ()) = run_server(&backend, &cfg, |c| {
                for r in order {
                    c.submit(r.clone());
                }
            })
            .unwrap();
            assert_eq!(
                triples(&out),
                want,
                "order {o}, threads {threads}, batch {batch_size}, wait {max_wait_us}"
            );
            // submission order is preserved slot-for-slot
            for (slot, r) in order.iter().zip(&out) {
                assert_eq!(slot.id, r.id, "order {o}: submission slot broken");
            }
            assert_eq!(stats.submitted, 12);
            assert_eq!(stats.completed, 12);

            let (out2, _, ()) = run_server(&backend, &cfg, |c| {
                c.submit_wave(order.clone());
            })
            .unwrap();
            assert_eq!(triples(&out2), want, "order {o} (atomic wave)");
        }
    }
}

/// A partial batch must not wait forever: once its oldest member has
/// lingered past `max_wait`, it is dispatched even though the batch never
/// filled (the driver is still alive and submitting afterwards, so this
/// is not drain).
#[test]
fn linger_expiry_dispatches_partial_batches() {
    let backend = StubBackend::new(2);
    let cfg = ServerConfig::continuous(100, 5_000, 2); // fill is unreachable
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        for i in 0..3 {
            c.submit(req(i, 0));
        }
        // far longer than max_wait: the scheduler must flush without us
        std::thread::sleep(Duration::from_millis(120));
        for i in 3..6 {
            c.submit(req(i, 0));
        }
    })
    .unwrap();
    assert_eq!(out.len(), 6);
    assert!(
        stats.linger_batches >= 1,
        "first batch must leave on linger expiry, not drain: {stats:?}"
    );
    assert_eq!(stats.completed, 6);
    // the lingered requests really waited: their queue time is >= max_wait
    let lingered = out.iter().filter(|r| r.queue_micros >= 5_000).count();
    assert!(lingered >= 1, "queue_micros must record the linger wait");
}

/// After a full batch dispatches mid-wave, the surviving partial batch's
/// linger deadline stays anchored at the survivor's own admission time:
/// no request may wait ~2x `max_wait` because a sibling batch filled.
/// (The sharp unit check for the deadline-restart bug lives next to the
/// `linger_deadline` helper in `coordinator::server`; this is the
/// end-to-end bound.)
#[test]
fn survivors_after_full_dispatch_keep_their_linger_anchor() {
    let backend = StubBackend::new(2);
    let wait_us: u64 = 100_000;
    let cfg = ServerConfig::continuous(2, wait_us, 1);
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        // one wave, one expert: [0,1] fills a batch immediately, request 2
        // survives and must leave on its own linger — the driver stays
        // alive well past it so drain cannot be what flushes it
        c.submit_wave(vec![req(0, 0), req(1, 0), req(2, 0)]);
        std::thread::sleep(Duration::from_millis(400));
    })
    .unwrap();
    assert_eq!(out.len(), 3);
    assert!(stats.full_batches >= 1, "{stats:?}");
    assert!(stats.linger_batches >= 1, "survivor must leave on linger: {stats:?}");
    let survivor = out.iter().find(|r| r.id == 2).unwrap();
    // 1.9x: below the ~2x the restart bug allowed, with scheduling-jitter
    // headroom above the exact 1x budget
    assert!(
        survivor.queue_micros < (wait_us as u128) * 19 / 10,
        "survivor lingered {} µs against a {wait_us} µs budget",
        survivor.queue_micros
    );
    assert!(
        survivor.queue_micros >= wait_us as u128,
        "the survivor really lingered (queue {} µs)",
        survivor.queue_micros
    );
}

/// Freed worker slots are refilled from the dispatch queue without
/// blocking: with more batches than workers, at least one pull must find
/// work already waiting.
#[test]
fn freed_slots_are_refilled_under_backlog() {
    let backend = StubBackend::new(2)
        .with_delay(0, Duration::from_millis(2))
        .with_delay(1, Duration::from_millis(2));
    let cfg = ServerConfig::continuous(1, u64::MAX, 2); // every request = one batch
    let reqs: Vec<Request> = (0..16).map(|i| req(i, i as u32)).collect();
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        c.submit_wave(reqs.clone());
    })
    .unwrap();
    assert_eq!(out.len(), 16);
    assert_eq!(stats.batches_dispatched, 16);
    assert!(
        stats.slots_refilled >= 1,
        "a backlog of 16 single-request batches over 2 workers must refill \
         freed slots without blocking: {stats:?}"
    );
    assert!(stats.mean_queue_depth() > 0.0, "dispatch queue was never observed non-empty");
}

/// Regression: a run that never dispatches a batch (no requests at all)
/// has zero depth samples — `mean_queue_depth` must report 0, not divide
/// by zero.
#[test]
fn mean_queue_depth_is_zero_on_a_zero_dispatch_run() {
    let backend = StubBackend::new(2);
    let cfg = ServerConfig::continuous(4, 1000, 2);
    let (out, stats, ()) = run_server(&backend, &cfg, |_c| {
        // submit nothing: drain fires with every pending batch empty
    })
    .unwrap();
    assert!(out.is_empty());
    assert_eq!(stats.batches_dispatched, 0);
    let depth = stats.mean_queue_depth();
    assert_eq!(depth, 0.0, "zero-dispatch run must report 0, got {depth}");
    assert!(depth.is_finite());
}

/// The straggler property the closed wave lacks: one slow expert batch
/// delays only its own worker — the fast expert's batches keep flowing
/// through the freed slots, so total wall time stays near the single
/// straggler's cost, not the sum.
#[test]
fn straggler_batch_does_not_stall_other_experts() {
    let slow = Duration::from_millis(60);
    let backend = StubBackend::new(2).with_delay(1, slow);
    let cfg = ServerConfig::continuous(2, u64::MAX, 2);
    // 2 slow-expert requests (one batch) + 6 fast ones (three batches)
    let mut reqs: Vec<Request> = (0..2).map(|i| req(i, 1)).collect();
    reqs.extend((2..8).map(|i| req(i, 0)));
    let t0 = Instant::now();
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        c.submit_wave(reqs.clone());
    })
    .unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(out.len(), 8);
    assert_eq!(stats.batches_dispatched, 4);
    // the sharp per-request property: no fast-expert request queued for
    // the straggler's full duration — its batches ran on the free worker
    // while the slow batch was still executing. Same 3x scheduling
    // margin as the wall-clock assert below: under RUST_TEST_THREADS=8
    // on a small machine a worker can be descheduled for tens of ms
    // without any product bug.
    let stalled = out
        .iter()
        .filter(|r| r.expert == 0 && r.queue_micros >= (slow * 3).as_micros())
        .count();
    assert_eq!(
        stalled, 0,
        "fast batches queued behind the straggler (queue times: {:?})",
        out.iter().map(|r| (r.expert, r.queue_micros)).collect::<Vec<_>>()
    );
    // wall clock reflects the overlap; generous 3x margin because the
    // suite also runs under RUST_TEST_THREADS=8 on small machines
    assert!(
        elapsed < slow * 3,
        "serving took {elapsed:?} against a single {slow:?} straggler"
    );
}

/// Structured error (not a panic) when the router emits an out-of-range
/// expert index, and clean propagation of execution failures.
#[test]
fn backend_failures_propagate_as_errors() {
    struct BadRoute;
    impl ServeBackend for BadRoute {
        fn n_experts(&self) -> usize {
            3
        }
        fn route(&self, rows: &[&[u32]], _t: usize) -> Result<Vec<usize>> {
            Ok(vec![3; rows.len()]) // == n_experts: first invalid index
        }
        fn exec_nll(&self, _e: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
            Ok(vec![0.0; rows.len()])
        }
    }
    let err = run_server(&BadRoute, &ServerConfig::continuous(2, 100, 2), |c| {
        c.submit(req(1, 0));
    })
    .unwrap_err();
    assert!(err.to_string().contains("route index 3"), "{err}");

    struct BrokenExec;
    impl ServeBackend for BrokenExec {
        fn n_experts(&self) -> usize {
            2
        }
        fn route(&self, rows: &[&[u32]], _t: usize) -> Result<Vec<usize>> {
            Ok(vec![0; rows.len()])
        }
        fn exec_nll(&self, _e: usize, _rows: &[&[u32]]) -> Result<Vec<f32>> {
            bail!("executor exploded")
        }
    }
    let err = run_server(&BrokenExec, &ServerConfig::continuous(1, 100, 3), |c| {
        for i in 0..5 {
            c.submit(req(i, 0));
        }
    })
    .unwrap_err();
    assert!(err.to_string().contains("executor exploded"), "{err}");
}

/// Queue accounting is exact on a clean run: submitted == admitted ==
/// completed == responses, and dispatch-kind counters partition
/// batches_dispatched.
#[test]
fn queue_accounting_is_exact() {
    let backend = StubBackend::new(3);
    let reqs: Vec<Request> = (0..23).map(|i| req(i, i as u32)).collect();
    let cfg = ServerConfig::continuous(4, 300, 3);
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        for chunk in reqs.chunks(5) {
            c.submit_wave(chunk.to_vec());
            std::thread::sleep(Duration::from_micros(200));
        }
    })
    .unwrap();
    assert_eq!(out.len(), 23);
    assert_eq!(stats.submitted, 23);
    assert_eq!(stats.admitted, 23);
    assert_eq!(stats.completed, 23);
    assert_eq!(
        stats.full_batches + stats.linger_batches + stats.drain_batches,
        stats.batches_dispatched,
        "dispatch kinds must partition the total: {stats:?}"
    );
    let executed: usize = backend.batches.lock().unwrap().iter().map(|&(_, n)| n).sum();
    assert_eq!(executed, 23, "every request executes exactly once");
    assert!(stats.admission_waves >= 1 && stats.admission_waves <= 23);
}

// ---------------------------------------------------------------------
// XLA-backed determinism tests (self-skip without compiled artifacts)
// ---------------------------------------------------------------------

fn setup() -> Option<(Engine, smalltalk::coordinator::Mixture, Vec<Request>)> {
    let dir = locate_artifacts()?;
    let engine = Engine::new(dir).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts: 4,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 8,
        shard_sequences: 128,
        expert_steps: 10,
        prefix_len: 32,
        seed: 3,
        threads: 0,
    };
    let mixture = run_pipeline(&engine, &bpe, &cfg)
        .expect("training the test mixture")
        .mixture;
    let requests: Vec<Request> = SequenceGen::new(&bpe, mixture.expert_meta.seq_len, 23)
        .batch(26)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: 500 + i as u64,
            tokens: s.tokens,
        })
        .collect();
    Some((engine, mixture, requests))
}

/// For any arrival order and any `threads`/`batch-size`/`max-wait`
/// setting, the continuous server returns the same `(id, expert, nll)`
/// set as closed-wave `serve_threaded` on the same requests.
#[test]
fn continuous_matches_closed_wave_for_any_arrival_order_and_config() {
    let Some((engine, mixture, requests)) = setup() else { return };
    let m = 32usize;
    let e = mixture.n_experts();
    let reference = serve_threaded(&engine, &mixture, &requests, m, 1).unwrap();
    let want = triples(&reference);
    let backend = MixtureBackend {
        engine: &engine,
        mixture: &mixture,
        prefix_len: m,
    };

    let mut orders: Vec<Vec<Request>> = vec![requests.clone()];
    let mut rev = requests.clone();
    rev.reverse();
    orders.push(rev);
    let mut inter: Vec<Request> = requests.iter().step_by(2).cloned().collect();
    inter.extend(requests.iter().skip(1).step_by(2).cloned());
    orders.push(inter);

    let eval_batch = mixture.expert_meta.eval_batch;
    for (threads, batch_size, max_wait_us) in [
        (1usize, 1usize, u64::MAX),
        (2, 3, 200),
        (e, eval_batch, 500),
        (e + 3, 0, 0),
    ] {
        for (o, order) in orders.iter().enumerate() {
            let cfg = ServerConfig::continuous(batch_size, max_wait_us, threads);
            let (out, stats, ()) = run_server(&backend, &cfg, |c| {
                for r in order {
                    c.submit(r.clone());
                }
            })
            .unwrap();
            assert_eq!(
                triples(&out),
                want,
                "order {o}, threads {threads}, batch {batch_size}, wait {max_wait_us}: \
                 continuous diverged from closed-wave serve_threaded"
            );
            assert_eq!(stats.completed, requests.len());
        }
    }

    // and the closed-wave wrapper itself (threads > 1 now runs through
    // the scheduler) stays bit-identical to sequential, order included
    for threads in [2usize, e, e + 3] {
        let parallel = serve_threaded(&engine, &mixture, &requests, m, threads).unwrap();
        assert_eq!(parallel.len(), reference.len());
        for (p, s) in parallel.iter().zip(&reference) {
            assert_eq!(
                (p.id, p.expert, p.nll.to_bits()),
                (s.id, s.expert, s.nll.to_bits()),
                "threads={threads}: closed-wave wrapper diverged"
            );
        }
    }
}

/// Staggered arrivals: requests injected mid-flight are admitted into
/// later waves, partial expert batches leave on `max_wait` expiry, and
/// the answer set still matches the closed-wave reference.
#[test]
fn staggered_arrivals_dispatch_on_linger_and_match_reference() {
    let Some((engine, mixture, requests)) = setup() else { return };
    let m = 32usize;
    let reference = serve_threaded(&engine, &mixture, &requests, m, 1).unwrap();
    let want = triples(&reference);
    let backend = MixtureBackend {
        engine: &engine,
        mixture: &mixture,
        prefix_len: m,
    };
    // tiny linger, big batch: with arrivals trickling in, partial batches
    // must leave on expiry
    let cfg = ServerConfig::continuous(1000, 300, 2);
    let (out, stats, ()) = run_server(&backend, &cfg, |c| {
        for chunk in requests.chunks(4) {
            c.submit_wave(chunk.to_vec());
            std::thread::sleep(Duration::from_millis(2));
        }
    })
    .unwrap();
    assert_eq!(triples(&out), want, "staggered arrivals diverged");
    assert!(
        stats.linger_batches >= 1,
        "a 300 µs linger under 2 ms arrival gaps must dispatch partial batches: {stats:?}"
    );
    assert!(stats.admission_waves > 1, "mid-flight arrivals must form later admission waves");
}

// ---------------------------------------------------------------------
// prefix-routing memo (tier-1)
// ---------------------------------------------------------------------

/// Memo-enabled stub: like [`StubBackend`] but exposing a routing key
/// (the raw token row) and a driver-controlled router fingerprint, plus
/// exact accounting of how many rows actually reached the router.
struct MemoStub {
    n: usize,
    fingerprint: std::sync::atomic::AtomicU64,
    rows_scored: std::sync::atomic::AtomicUsize,
}

impl MemoStub {
    fn new(n: usize) -> Self {
        MemoStub {
            n,
            fingerprint: std::sync::atomic::AtomicU64::new(1),
            rows_scored: std::sync::atomic::AtomicUsize::new(0),
        }
    }
}

impl ServeBackend for MemoStub {
    fn n_experts(&self) -> usize {
        self.n
    }

    fn route(&self, rows: &[&[u32]], _threads: usize) -> Result<Vec<usize>> {
        self.rows_scored
            .fetch_add(rows.len(), std::sync::atomic::Ordering::SeqCst);
        Ok(rows
            .iter()
            .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
            .collect())
    }

    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
            .collect())
    }

    fn route_memo_key(&self, row: &[u32]) -> Option<Vec<u32>> {
        Some(row.to_vec())
    }

    fn router_fingerprint(&self) -> u64 {
        self.fingerprint.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// One-request admission waves so memo behavior is deterministic: each
/// request routes in its own wave, so a repeated prefix is always a
/// cross-wave hit, never a same-wave double miss.
fn one_by_one(threads: usize) -> ServerConfig {
    ServerConfig {
        batch_size: 1,
        max_wait_us: u64::MAX,
        admission_max: 1,
        threads,
        replicas: 1,
        replication: 1,
        rebalance_every: 0,
    }
}

/// A repeated prefix is scored once and replayed from the memo on every
/// later wave — the router sees exactly the distinct rows, and the
/// replayed requests still get correct (bit-identical) answers.
#[test]
fn repeated_prefixes_hit_the_route_memo() {
    let backend = MemoStub::new(3);
    let same = |id: u64, t: u32| Request { id, tokens: vec![t, t + 1, t + 2] };
    // tokens [5,..] twice, [1,..] twice, [2,..] once — 3 distinct rows
    let reqs = vec![same(0, 5), same(1, 5), same(2, 1), same(3, 5), same(4, 2), same(5, 1)];
    let (out, stats, ()) = run_server(&backend, &one_by_one(2), |c| {
        for r in &reqs {
            c.submit(r.clone());
        }
    })
    .unwrap();
    assert_eq!(out.len(), 6);
    for (r, resp) in reqs.iter().zip(&out) {
        let t = r.tokens[0];
        assert_eq!(resp.id, r.id);
        assert_eq!(resp.expert, t as usize % 3, "memoized route must match scored route");
        let nll = (t as usize % 3) as f32 * 1000.0 + r.tokens.iter().sum::<u32>() as f32;
        assert_eq!(resp.nll.to_bits(), nll.to_bits());
    }
    assert_eq!(
        backend.rows_scored.load(std::sync::atomic::Ordering::SeqCst),
        3,
        "only the distinct prefixes may reach the router"
    );
    assert_eq!(stats.route_cache_hits, 3, "each repeat is a memo hit");
    assert_eq!(stats.admission_waves, 6, "one-request waves");
    assert_eq!(stats.admitted, 6);
}

/// A router fingerprint change (any router version bump) drops the memo:
/// the same prefix is re-scored afterwards instead of replayed stale.
#[test]
fn fingerprint_bump_invalidates_the_route_memo() {
    let backend = MemoStub::new(2);
    let r0 = Request { id: 0, tokens: vec![4, 4, 4] };
    let r1 = Request { id: 1, tokens: vec![4, 4, 4] };
    let (out, stats, ()) = run_server(&backend, &one_by_one(1), |c| {
        c.submit(r0.clone());
        // wait until wave 1 has actually reached the router (the
        // scheduler reads the fingerprint before scoring, so once the
        // row is scored the bump below is strictly after wave 1's read
        // — deterministic, no sleep-length guessing)
        let t0 = Instant::now();
        while backend.rows_scored.load(std::sync::atomic::Ordering::SeqCst) < 1 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "wave 1 never routed"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        backend
            .fingerprint
            .store(2, std::sync::atomic::Ordering::SeqCst);
        c.submit(r1.clone());
    })
    .unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].expert, out[1].expert, "routing itself did not change");
    assert_eq!(
        backend.rows_scored.load(std::sync::atomic::Ordering::SeqCst),
        2,
        "the invalidated prefix must be re-scored, not replayed"
    );
    assert_eq!(stats.route_cache_hits, 0);
}

/// Backends that do not opt in (the default trait methods) never memoize:
/// every row reaches the router and `route_cache_hits` stays zero.
#[test]
fn memoization_is_off_by_default() {
    let backend = StubBackend::new(2);
    let same = |id: u64| Request { id, tokens: vec![3, 3, 3] };
    let (out, stats, ()) = run_server(&backend, &one_by_one(2), |c| {
        for id in 0..5 {
            c.submit(same(id));
        }
    })
    .unwrap();
    assert_eq!(out.len(), 5);
    assert_eq!(stats.route_cache_hits, 0, "no memo without a key");
    assert_eq!(stats.admitted, 5);
}

/// Memoized serving is burst-safe: duplicates inside one admission wave
/// are simply scored together (double miss, no hit), and the triples
/// still match the per-request expectation.
#[test]
fn same_wave_duplicates_score_together_without_hits() {
    let backend = MemoStub::new(3);
    let reqs: Vec<Request> = (0..6)
        .map(|id| Request { id, tokens: vec![(id % 2) as u32, 9, 9] })
        .collect();
    // one atomic wave: everything admitted (and scored) together
    let (out, stats, ()) = run_server(&backend, &ServerConfig::closed_wave(2), |c| {
        c.submit_wave(reqs.clone());
    })
    .unwrap();
    assert_eq!(out.len(), 6);
    assert_eq!(stats.admission_waves, 1);
    assert_eq!(stats.route_cache_hits, 0, "nothing memoized before the only wave");
    assert_eq!(
        backend.rows_scored.load(std::sync::atomic::Ordering::SeqCst),
        6,
        "a single wave scores all its rows in one batched call"
    );
}
