//! Integration: AOT artifacts -> PJRT engine -> train/eval/score.
//!
//! These tests exercise the real HLO artifacts (run `make artifacts`
//! first); they are the Rust-side counterpart of the python kernel/model
//! tests and the ground truth that the three layers compose.

use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};

/// The XLA-backed tests need compiled artifacts; without them (or without
/// the real xla backend) they skip rather than fail, so `cargo test` stays
/// green on machines that haven't run `make artifacts`.
fn engine() -> Option<Engine> {
    let dir = locate_artifacts()?;
    Some(Engine::new(dir).expect("loading artifacts"))
}

fn bpe() -> Bpe {
    let corpus = Corpus::generate(60, 400, 42, None);
    BpeTrainer::new(512).train(corpus.texts()).unwrap()
}

#[test]
fn init_produces_manifest_sized_params() {
    let Some(eng) = engine() else { return };
    let st = TrainState::init(&eng, "router_micro", 7).unwrap();
    let meta = eng.variant("router_micro").unwrap();
    assert_eq!(st.param_count(), meta.param_count);
    // deterministic in seed
    let st2 = TrainState::init(&eng, "router_micro", 7).unwrap();
    assert_eq!(st.params, st2.params);
    let st3 = TrainState::init(&eng, "router_micro", 8).unwrap();
    assert_ne!(st.params, st3.params);
}

#[test]
fn train_step_decreases_loss_on_fixed_batch() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let mut st = TrainState::init(&eng, "router_micro", 1).unwrap();
    let mut gen = SequenceGen::new(&b, meta.seq_len, 5);
    let batch: Vec<Vec<u32>> = gen
        .batch(meta.train_batch)
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    let first = st.train_step(&eng, &batch, &meta).unwrap();
    // near-uniform init: loss ~ ln(512) = 6.24
    assert!((first - 6.24).abs() < 0.8, "initial loss {first}");
    // router schedule: 20 warmup steps to a constant 1e-4 with 0.1 grad
    // clip — progress is steady but deliberately slow (App. A.1), so give
    // it a few dozen steps.
    let mut last = first;
    for _ in 0..50 {
        last = st.train_step(&eng, &batch, &meta).unwrap();
    }
    assert!(
        last < first - 0.1,
        "loss did not drop: {first} -> {last}"
    );
    assert_eq!(st.step, 51);
}

#[test]
fn eval_nll_matches_scale_and_shape() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let st = TrainState::init(&eng, "router_micro", 2).unwrap();
    let mut gen = SequenceGen::new(&b, meta.seq_len, 9);
    let batch: Vec<Vec<u32>> = gen
        .batch(meta.eval_batch)
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    let nll = st.eval_nll(&eng, &batch, &meta).unwrap();
    assert_eq!(nll.len(), meta.eval_batch);
    // per-token NLL at init ~ ln(512)
    for &n in &nll {
        let per_tok = n / meta.seq_len as f32;
        assert!((per_tok - 6.24).abs() < 1.0, "per-token NLL {per_tok}");
    }
}

#[test]
fn prefix_nll_all_compiled_lengths() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let st = TrainState::init(&eng, "router_micro", 3).unwrap();
    let mut gen = SequenceGen::new(&b, meta.seq_len, 11);
    let seqs = gen.batch(meta.prefix_batch);
    for &m in &meta.prefix_lens {
        let batch: Vec<Vec<u32>> = seqs.iter().map(|s| s.prefix(m).to_vec()).collect();
        let nll = st.prefix_nll(&eng, &batch, &meta, m).unwrap();
        assert_eq!(nll.len(), meta.prefix_batch);
        assert!(nll.iter().all(|&x| x.is_finite() && x > 0.0));
        // longer prefixes accumulate more NLL
        let mean: f32 = nll.iter().sum::<f32>() / nll.len() as f32;
        let expected = (m as f32 - 1.0) * 6.24;
        assert!(
            (mean - expected).abs() / expected < 0.3,
            "m={m} mean={mean} expected~{expected}"
        );
    }
}

#[test]
fn prefix_nll_rejects_uncompiled_length() {
    let Some(eng) = engine() else { return };
    let meta = eng.variant("router_micro").unwrap().clone();
    let st = TrainState::init(&eng, "router_micro", 4).unwrap();
    let batch = vec![vec![0u32; 13]; meta.prefix_batch];
    assert!(st.prefix_nll(&eng, &batch, &meta, 13).is_err());
}

#[test]
fn executables_are_cached() {
    let Some(eng) = engine() else { return };
    let _ = eng.executable("router_micro", "init").unwrap();
    let c1 = eng.stats().compiles;
    let _ = eng.executable("router_micro", "init").unwrap();
    assert_eq!(eng.stats().compiles, c1);
}

#[test]
fn trained_router_prefers_its_domain() {
    // Mini specialization check: train one router on domain 1 ("code")
    // only; its prefix NLL on code must become lower than on recipes.
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let mut st = TrainState::init(&eng, "router_micro", 5).unwrap();

    let mut w_code = vec![0.0; smalltalk::data::corpus::DOMAINS];
    w_code[1] = 1.0;
    let mut gen_code = SequenceGen::new(&b, meta.seq_len, 21).with_weights(w_code.clone());
    for _ in 0..60 {
        let batch: Vec<Vec<u32>> = gen_code
            .batch(meta.train_batch)
            .into_iter()
            .map(|s| s.tokens)
            .collect();
        st.train_step(&eng, &batch, &meta).unwrap();
    }

    let mut w_rec = vec![0.0; smalltalk::data::corpus::DOMAINS];
    w_rec[2] = 1.0;
    let mut gen_code_eval = SequenceGen::new(&b, meta.seq_len, 77).with_weights(w_code);
    let mut gen_rec_eval = SequenceGen::new(&b, meta.seq_len, 78).with_weights(w_rec);
    let m = 32;
    let code_batch: Vec<Vec<u32>> = gen_code_eval
        .batch(meta.prefix_batch)
        .iter()
        .map(|s| s.prefix(m).to_vec())
        .collect();
    let rec_batch: Vec<Vec<u32>> = gen_rec_eval
        .batch(meta.prefix_batch)
        .iter()
        .map(|s| s.prefix(m).to_vec())
        .collect();
    let nll_code = st.prefix_nll(&eng, &code_batch, &meta, m).unwrap();
    let nll_rec = st.prefix_nll(&eng, &rec_batch, &meta, m).unwrap();
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    assert!(
        mean(&nll_code) + 2.0 < mean(&nll_rec),
        "code {} vs recipes {}",
        mean(&nll_code),
        mean(&nll_rec)
    );
}
