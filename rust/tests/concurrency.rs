//! Concurrency & determinism suite for the thread-safe engine refactor.
//!
//! The paper's serving-time property — experts never talk — makes expert
//! groups embarrassingly parallel, and this suite is the proof that the
//! `Rc`→`Arc` engine refactor exploits that safely:
//!
//! * `Engine` (and everything a serving wave shares) is `Send + Sync`,
//!   asserted at compile time;
//! * parallel `serve` output is **bit-identical** to sequential across
//!   thread counts {1, 2, E, E+3} — same ids, same experts, same NLL
//!   bits, same input order, every request answered exactly once;
//! * `EngineStats` totals are identical whether E groups run on 1 thread
//!   or E threads (only wall-clock floats may differ);
//! * the `(state_id, version)` device cache never double-uploads under
//!   concurrent `state_buffer` calls from many threads.
//!
//! Two tiers of tests: the stub xla backend keeps host-side uploads real
//! (only compile/execute need the native runtime), so the cache/stats
//! contention tests build an `Engine` over a minimal handwritten manifest
//! and run everywhere — including tier-1 with no artifacts. Tests that
//! must *execute* models follow the standard self-skip pattern
//! (`locate_artifacts()` → skip when absent).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Barrier, OnceLock};

use smalltalk::coordinator::inference::eval_nll_all;
use smalltalk::coordinator::{
    run_pipeline, score_matrix, score_matrix_rows_threaded, serve, serve_threaded, Mixture,
    PipelineConfig, Request, Response,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::{Sequence, SequenceGen};
use smalltalk::runtime::engine::f32_literal;
use smalltalk::runtime::{locate_artifacts, DeviceBuffer, Engine, EngineStats, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};

// ---------------------------------------------------------------------
// (a) compile-time thread-safety contract
// ---------------------------------------------------------------------

#[test]
fn engine_and_serving_types_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Engine>();
    assert_send_sync::<EngineStats>();
    assert_send_sync::<DeviceBuffer>();
    assert_send_sync::<TrainState>();
    assert_send_sync::<Mixture>();
    assert_send_sync::<Request>();
    assert_send_sync::<Response>();
}

// ---------------------------------------------------------------------
// stub-backend engine: real manifest parsing + real uploads, no execution
// ---------------------------------------------------------------------

/// A minimal one-variant manifest so `Engine::new` succeeds without
/// compiled artifacts. Uploads and the device cache are fully functional
/// on the stub backend; only compile/execute would fail.
const STUB_MANIFEST: &str = r#"{
  "fingerprint": "concurrency-test-stub",
  "variants": [{
    "name": "stub", "role": "router", "vocab": 512, "seq_len": 64,
    "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ffw": 16,
    "param_count": 32, "train_batch": 4, "eval_batch": 4,
    "prefix_batch": 4, "prefix_len": 8, "prefix_lens": [8],
    "opt": {"peak_lr": 0.001, "warmup_steps": 10, "total_steps": 100,
            "schedule": "constant", "weight_decay": 0.1, "clip_norm": 1.0},
    "entry_points": ["init", "train_step", "eval_nll", "prefix_nll_8"]
  }]
}"#;

/// Engine over a throwaway manifest dir (unique per call, so concurrent
/// tests never share stats).
fn stub_engine() -> Engine {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "smalltalk_concurrency_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("creating stub manifest dir");
    std::fs::write(dir.join("manifest.json"), STUB_MANIFEST).expect("writing stub manifest");
    Engine::new(&dir).expect("stub engine must construct without artifacts")
}

fn dummy_state() -> TrainState {
    TrainState::from_params("stub", vec![0.0; 32], vec![0.0; 32], vec![0.0; 32], 0)
}

// ---------------------------------------------------------------------
// (d) the versioned device cache under contention
// ---------------------------------------------------------------------

/// Many threads hammer `state_buffer` for the same `(state_id, version)`
/// pairs behind a barrier: each pair must be built + uploaded exactly
/// once, version bumps must evict exactly once, and the final totals must
/// be deterministic — not "roughly one upload", exactly one.
#[test]
fn device_cache_never_double_uploads_under_contention() {
    const THREADS: usize = 8;
    const ROUNDS: u64 = 6;
    const CALLS_PER_ROUND: usize = 4;
    const IDS: [u64; 2] = [1, 2];
    const FLOATS: usize = 16; // 64 bytes per parameter literal

    let eng = stub_engine();
    let made = AtomicUsize::new(0);
    let barrier = Barrier::new(THREADS);
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for version in 0..ROUNDS {
                    // all threads enter the round together so every
                    // (id, version) miss is genuinely contended
                    barrier.wait();
                    for _ in 0..CALLS_PER_ROUND {
                        for id in IDS {
                            let buf = eng
                                .state_buffer(id, version, || {
                                    made.fetch_add(1, Ordering::SeqCst);
                                    f32_literal(&[id as f32; FLOATS])
                                })
                                .expect("stub uploads cannot fail");
                            assert_eq!(buf.bytes(), (FLOATS * 4) as u64);
                        }
                    }
                }
            });
        }
    });

    let pairs = IDS.len() * ROUNDS as usize;
    let stats = eng.stats();
    assert_eq!(
        made.load(Ordering::SeqCst),
        pairs,
        "the literal builder must run exactly once per (state, version)"
    );
    assert_eq!(stats.param_uploads, pairs, "one upload per (state, version)");
    assert_eq!(stats.uploads, pairs);
    assert_eq!(stats.h2d_bytes, (pairs * FLOATS * 4) as u64);
    // every version bump after the first evicts the previous entry, once
    assert_eq!(stats.cache_evictions, IDS.len() * (ROUNDS as usize - 1));
    // at most one live entry per owner
    assert_eq!(eng.device_cache_entries(), IDS.len());
}

/// Transfer accounting is exact (not merely monotonic) when many threads
/// upload concurrently — the stats mutex must not lose increments.
#[test]
fn upload_accounting_is_exact_under_concurrency() {
    const THREADS: usize = 8;
    const UPLOADS: usize = 25;

    let eng = stub_engine();
    let barrier = Barrier::new(THREADS);
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let barrier = &barrier;
            let eng = &eng;
            s.spawn(move || {
                barrier.wait();
                for k in 0..UPLOADS {
                    let n = 8 + (k % 3); // vary sizes so byte totals are a real checksum
                    let lit = f32_literal(&vec![t as f32; n]);
                    let buf = eng.upload(&lit).expect("stub uploads cannot fail");
                    assert_eq!(buf.bytes(), (n * 4) as u64);
                }
            });
        }
    });

    let per_thread_bytes: u64 = (0..UPLOADS).map(|k| (8 + (k % 3)) as u64 * 4).sum();
    let stats = eng.stats();
    assert_eq!(stats.uploads, THREADS * UPLOADS);
    assert_eq!(stats.h2d_bytes, THREADS as u64 * per_thread_bytes);
    assert_eq!(stats.param_uploads, 0, "plain uploads bypass the device cache");
}

// ---------------------------------------------------------------------
// satellite: the serve empty-request edge
// ---------------------------------------------------------------------

/// `serve` with no queued requests must return an empty wave without
/// routing a zero-row batch — no uploads, no executions, at any worker
/// count (and the same for `eval_routed` on an empty sequence set).
#[test]
fn serve_empty_requests_returns_empty_and_touches_nothing() {
    let eng = stub_engine();
    let meta = eng.variant("stub").unwrap().clone();
    let mixture = Mixture {
        routers: vec![dummy_state(), dummy_state()],
        router_meta: meta.clone(),
        experts: vec![dummy_state(), dummy_state()],
        expert_meta: meta,
    };

    let before = eng.stats();
    for threads in [1usize, 2, 4] {
        let out = serve_threaded(&eng, &mixture, &[], 8, threads).unwrap();
        assert!(out.is_empty(), "threads={threads}");
    }
    assert!(serve(&eng, &mixture, &[], 8).unwrap().is_empty());
    assert!(mixture.eval_routed_threaded(&eng, &[], 8, 2).unwrap().is_empty());
    let after = eng.stats();
    assert_eq!(after.uploads, before.uploads, "empty wave must not upload");
    assert_eq!(after.executions, before.executions, "empty wave must not execute");
    assert_eq!(after.compiles, before.compiles, "empty wave must not compile");
}

// ---------------------------------------------------------------------
// XLA-backed tests (self-skip without compiled artifacts)
// ---------------------------------------------------------------------

/// One trained mixture shared by the execution tests below (training it
/// once keeps the suite's artifact-mode runtime close to the routing
/// bench's). The engine here is shared too — tests that assert on stats
/// construct their own private engine instead.
struct Setup {
    engine: Engine,
    bpe: Bpe,
    mixture: Mixture,
}

static SETUP: OnceLock<Option<Setup>> = OnceLock::new();

fn setup() -> Option<&'static Setup> {
    SETUP
        .get_or_init(|| {
            let dir = locate_artifacts()?;
            let engine = Engine::new(dir).expect("loading artifacts");
            let corpus = Corpus::generate(60, 400, 42, None);
            let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
            let cfg = PipelineConfig {
                router_variant: "router_micro".into(),
                expert_variant: "expert_sm".into(),
                n_experts: 4,
                em_rounds: 2,
                em_chunk: 96,
                em_steps_per_round: 8,
                shard_sequences: 128,
                expert_steps: 10,
                prefix_len: 32,
                seed: 3,
                threads: 0,
            };
            let mixture = run_pipeline(&engine, &bpe, &cfg)
                .expect("training the shared test mixture")
                .mixture;
            Some(Setup {
                engine,
                bpe,
                mixture,
            })
        })
        .as_ref()
}

fn requests_from(bpe: &Bpe, seq_len: usize, n: usize, seed: u64) -> Vec<Request> {
    SequenceGen::new(bpe, seq_len, seed)
        .batch(n)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: 1000 + i as u64,
            tokens: s.tokens,
        })
        .collect()
}

/// (b) Parallel `serve` is bit-identical to sequential across thread
/// counts {1, 2, E, E+3}: same input order, every request answered
/// exactly once, identical expert choices and NLL *bits*.
#[test]
fn parallel_serve_is_bit_identical_to_sequential() {
    let Some(setup) = setup() else { return };
    let eng = &setup.engine;
    let mixture = &setup.mixture;
    let e = mixture.n_experts();
    let m = 32usize;
    let requests = requests_from(&setup.bpe, mixture.expert_meta.seq_len, 26, 17);

    let sequential = serve_threaded(eng, mixture, &requests, m, 1).unwrap();
    assert_eq!(sequential.len(), requests.len());
    for (req, resp) in requests.iter().zip(&sequential) {
        assert_eq!(req.id, resp.id, "sequential serve must keep input order");
    }

    for threads in [2usize, e, e + 3] {
        let parallel = serve_threaded(eng, mixture, &requests, m, threads).unwrap();
        assert_eq!(
            parallel.len(),
            sequential.len(),
            "threads={threads}: every request answered exactly once"
        );
        for (p, s) in parallel.iter().zip(&sequential) {
            assert_eq!(p.id, s.id, "threads={threads}: input order broken");
            assert_eq!(p.expert, s.expert, "threads={threads}: routing diverged");
            assert_eq!(
                p.nll.to_bits(),
                s.nll.to_bits(),
                "threads={threads}: NLL not bit-identical for request {}",
                p.id
            );
        }
    }

    // the eval path fans the same expert groups — hold it to the same bar
    let seqs = SequenceGen::new(&setup.bpe, mixture.expert_meta.seq_len, 19).batch(13);
    let reference = mixture.eval_routed_threaded(eng, &seqs, m, 1).unwrap();
    for threads in [2usize, e + 3] {
        let got = mixture.eval_routed_threaded(eng, &seqs, m, threads).unwrap();
        assert_eq!(got.len(), reference.len());
        for (i, ((n1, e1), (n2, e2))) in got.iter().zip(&reference).enumerate() {
            assert_eq!(e1, e2, "threads={threads}: eval routing diverged at {i}");
            assert_eq!(
                n1.to_bits(),
                n2.to_bits(),
                "threads={threads}: eval NLL not bit-identical at {i}"
            );
        }
    }
}

/// (c) `EngineStats` totals are identical whether the E groups of a wave
/// run on 1 thread or E threads. A private engine isolates the counters
/// from concurrently running tests; the compile cache is warmed first so
/// both measured waves start from the same resident state.
#[test]
fn engine_stats_totals_match_across_thread_counts() {
    let Some(setup) = setup() else { return };
    let Some(dir) = locate_artifacts() else { return };
    let eng = Engine::new(dir).expect("loading artifacts");
    let mixture = &setup.mixture;
    let e = mixture.n_experts();
    let m = 32usize;
    let requests = requests_from(&setup.bpe, mixture.expert_meta.seq_len, 26, 29);

    // warm the compile cache so neither measured wave pays compilation
    serve_threaded(&eng, mixture, &requests, m, 1).unwrap();

    let mut deltas: Vec<EngineStats> = Vec::new();
    for threads in [1usize, e] {
        eng.clear_device_cache(); // both waves re-upload params identically
        let s0 = eng.stats();
        serve_threaded(&eng, mixture, &requests, m, threads).unwrap();
        deltas.push(eng.stats().since(&s0));
    }
    let (a, b) = (&deltas[0], &deltas[1]);
    assert_eq!(a.compiles, b.compiles, "compiles");
    assert_eq!(a.executions, b.executions, "executions");
    assert_eq!(a.uploads, b.uploads, "uploads");
    assert_eq!(a.param_uploads, b.param_uploads, "param_uploads");
    assert_eq!(a.h2d_bytes, b.h2d_bytes, "h2d_bytes");
    assert_eq!(a.d2h_bytes, b.d2h_bytes, "d2h_bytes");
    assert_eq!(a.uploads_avoided, b.uploads_avoided, "uploads_avoided");
    assert_eq!(a.h2d_bytes_avoided, b.h2d_bytes_avoided, "h2d_bytes_avoided");
    assert_eq!(a.cache_evictions, b.cache_evictions, "cache_evictions");
    // sanity: the wave did real work
    assert!(a.executions > 0 && a.param_uploads > 0);
}

/// satellite: `route_rows` with rows shorter than `m` scores padded
/// prefixes that agree with `route` on equivalent `Sequence`s — covering
/// `len < m`, `len == m`, `len > m`, a single token, an empty row, and
/// the mixed batch of all of them, at 1 and E worker threads.
#[test]
fn route_rows_short_prefixes_agree_with_route() {
    let Some(setup) = setup() else { return };
    let eng = &setup.engine;
    let mixture = &setup.mixture;
    let m = 32usize;
    let pool: Vec<Vec<u32>> = SequenceGen::new(&setup.bpe, mixture.router_meta.seq_len, 31)
        .batch(6)
        .into_iter()
        .map(|s| s.tokens)
        .collect();
    // mixed batch: every length class in one wave
    let rows: Vec<&[u32]> = vec![
        &pool[0][..m / 2], // len < m
        &pool[1][..m],     // len == m
        &pool[2][..],      // len > m (full sequence)
        &pool[3][..1],     // single token
        &pool[4][..0],     // empty request
        &pool[5][..m - 1], // one short of the boundary
    ];
    // equivalent Sequences: the same prefix padded to m by repeating the
    // last token (token 0 for an empty row) — the documented
    // normalization route_rows applies internally
    let seqs: Vec<Sequence> = rows
        .iter()
        .map(|r| {
            let mut tokens = r.to_vec();
            let fill = tokens.last().copied().unwrap_or(0);
            tokens.resize(m.max(tokens.len()), fill);
            Sequence { tokens, domain: 0 }
        })
        .collect();

    let via_route = mixture.route(eng, &seqs, m).unwrap();
    let via_rows = mixture.route_rows(eng, &rows, m).unwrap();
    assert_eq!(via_route, via_rows, "route_rows diverged from route");

    // the underlying score matrices agree bit-for-bit at any worker count
    let reference = score_matrix(eng, &mixture.routers, &mixture.router_meta, &seqs, m).unwrap();
    for threads in [1usize, mixture.routers.len()] {
        let got = score_matrix_rows_threaded(
            eng,
            &mixture.routers,
            &mixture.router_meta,
            &rows,
            m,
            threads,
        )
        .unwrap();
        assert_eq!(reference, got, "threads={threads}: score matrix skewed");
    }
}

/// satellite: `eval_nll_all` over any `rows.len()` vs `eval_batch`
/// evaluates every row exactly once and discards tail padding — each
/// row's score in a multi-span call matches the row scored alone.
#[test]
fn eval_nll_all_covers_every_row_exactly_once_across_spans() {
    let Some(setup) = setup() else { return };
    let eng = &setup.engine;
    let state = &setup.mixture.experts[0];
    let meta = &setup.mixture.expert_meta;
    let bs = meta.eval_batch;
    let pool: Vec<Vec<u32>> = SequenceGen::new(&setup.bpe, meta.seq_len, 37)
        .batch(2 * bs + 3)
        .into_iter()
        .map(|s| s.tokens)
        .collect();

    // per-row reference: each row scored alone (its batch is all padding)
    let reference: Vec<f32> = pool
        .iter()
        .map(|r| eval_nll_all(eng, state, meta, std::slice::from_ref(r)).unwrap()[0])
        .collect();

    // aligned, misaligned, sub-batch, and multi-span row counts — plus
    // the empty set, which must produce no spans at all
    for n in [0usize, 1, bs - 1, bs, bs + 1, 2 * bs + 3] {
        let rows = &pool[..n];
        let got = eval_nll_all(eng, state, meta, rows).unwrap();
        assert_eq!(got.len(), n, "n={n}: every row scored exactly once");
        for i in 0..n {
            assert_eq!(
                got[i].to_bits(),
                reference[i].to_bits(),
                "n={n}: row {i} skewed by batching/padding"
            );
        }
    }
}
