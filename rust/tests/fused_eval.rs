//! Fused stacked-expert eval suite: the bucket-ladder wave planner and
//! the `eval_nll_all_{b}` execution path.
//!
//! Two tiers, following `rust/tests/fused_scoring.rs`:
//!
//! * **Stub backend (tier-1, no artifacts):** handwritten temp-dir
//!   manifests prove the back-compat gate — a pre-fused manifest and a
//!   fused-routers-only manifest (the PR-4-era export, `fused_experts`
//!   set but no `eval_nll_all_{b}` entries) both parse, expose an empty
//!   bucket ladder, and plan every wave as pure per-expert fan-out.
//!   [`plan_wave`] itself is pure, so the ladder properties (bucket
//!   edges, chunking, counter reconciliation, exact coverage) run on
//!   group-size grids without any device.
//! * **Artifacts-gated (standard self-skip):** with compiled artifacts
//!   carrying fused eval entries (`aot.py --fused`), a wave's fused
//!   `(group, row)` NLLs are bit-identical to the fan-out fallback at
//!   worker counts {1, E} over group sizes straddling every bucket edge,
//!   dead padding rows never leak, an E=4 straddle wave drops from 5
//!   expert launches to 2 bucketed launches (the acceptance criterion,
//!   asserted via [`EngineStats`]), and the pad/avoided counters
//!   reconcile exactly against the planner's arithmetic.

use std::sync::atomic::{AtomicUsize, Ordering};

use smalltalk::coordinator::inference::{eval_nll_groups, plan_wave, EvalLaunch};
use smalltalk::coordinator::{response_triples, run_pipeline, serve_threaded, PipelineConfig};
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine, TrainState, VariantMeta};
use smalltalk::tokenizer::{Bpe, BpeTrainer};

// ---------------------------------------------------------------------
// stub-backend manifests (tier-1): parse + plan, no execution
// ---------------------------------------------------------------------

/// A stub manifest with the given fused field fragment and entry list.
fn stub_engine(fused_fragment: &str, entries: &str) -> Engine {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let manifest = format!(
        r#"{{
  "fingerprint": "fused-eval-test-stub",
  "variants": [{{
    "name": "stub", "role": "expert", "vocab": 512, "seq_len": 64,
    "d_model": 8, "n_layers": 1, "n_heads": 1, "d_ffw": 16,
    "param_count": 32, "train_batch": 4, "eval_batch": 4,
    "prefix_batch": 4, "prefix_len": 8, "prefix_lens": [8],
    {fused_fragment}
    "opt": {{"peak_lr": 0.001, "warmup_steps": 10, "total_steps": 100,
            "schedule": "constant", "weight_decay": 0.1, "clip_norm": 1.0}},
    "entry_points": [{entries}]
  }}]
}}"#
    );
    let dir = std::env::temp_dir().join(format!(
        "smalltalk_fused_eval_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("creating stub manifest dir");
    std::fs::write(dir.join("manifest.json"), manifest).expect("writing stub manifest");
    Engine::new(&dir).expect("stub engine must construct without artifacts")
}

/// Satellite: back-compat — a manifest with `fused_experts` set but no
/// `eval_nll_all_{b}` entries (the PR-4-era fused-routers export) and a
/// fully pre-fused manifest both parse, expose an empty bucket ladder,
/// and plan pure fan-out; old manifests stay valid unchanged.
#[test]
fn backcompat_manifests_parse_and_plan_pure_fanout() {
    // PR-4-era: fused routers, no fused eval
    let pr4 = stub_engine(
        r#""fused_experts": 4,"#,
        r#""init", "train_step", "eval_nll", "prefix_nll_8", "prefix_nll_all_8""#,
    );
    let v = pr4.variant("stub").unwrap();
    assert_eq!(v.fused_experts, 4);
    assert!(v.fused_prefix_entry(8).is_some(), "router fusion untouched");
    assert!(v.fused_eval_buckets().is_empty());
    assert_eq!(v.fused_eval_entry(4), None);

    // pre-fused: no fused field at all
    let prefused = stub_engine("", r#""init", "train_step", "eval_nll", "prefix_nll_8""#);
    let v2 = prefused.variant("stub").unwrap();
    assert_eq!(v2.fused_experts, 0);
    assert!(v2.fused_eval_buckets().is_empty());

    // either way the planner degrades every wave to per-expert fan-out
    for meta in [v, v2] {
        let plan = plan_wave(
            &[1, 3, 4, 9],
            meta.eval_batch,
            &meta.fused_eval_buckets(),
            meta.fused_experts,
        );
        assert!(
            plan.launches
                .iter()
                .all(|l| matches!(l, EvalLaunch::Single(_))),
            "empty ladder must never fuse"
        );
        assert_eq!(plan.execs_avoided, 0);
        assert_eq!(plan.pad_rows, 0);
        // spans: 1 + 1 + 1 + 3 at eval_batch 4
        assert_eq!(plan.launches.len(), 6);
    }
}

/// A fused-eval manifest parses its ladder from the entry points — no
/// separate manifest field to drift out of sync.
#[test]
fn fused_eval_manifest_parses_ladder_from_entries() {
    let eng = stub_engine(
        r#""fused_experts": 4,"#,
        r#""init", "eval_nll", "eval_nll_all_1", "eval_nll_all_2", "eval_nll_all_4""#,
    );
    let v = eng.variant("stub").unwrap();
    assert_eq!(v.fused_eval_buckets(), vec![1, 2, 4]);
    assert_eq!(v.fused_eval_entry(2).as_deref(), Some("eval_nll_all_2"));
    assert_eq!(v.fused_eval_entry(3), None, "only compiled buckets dispatch");
}

/// A mismatched experts/groups wave is a structured error before any
/// device work.
#[test]
fn eval_nll_groups_rejects_mismatched_wave() {
    let eng = stub_engine("", r#""init", "eval_nll""#);
    let meta = eng.variant("stub").unwrap().clone();
    let state = TrainState::from_params("stub", vec![0.0; 32], vec![0.0; 32], vec![0.0; 32], 0);
    let groups: Vec<Vec<&[u32]>> = vec![Vec::new(), Vec::new()];
    let err = eval_nll_groups(&eng, &[&state], &meta, &groups, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("2 expert groups for 1 experts"), "{err}");
}

// ---------------------------------------------------------------------
// planner properties on group-size grids (tier-1, pure)
// ---------------------------------------------------------------------

const LADDER: &[usize] = &[1, 2, 4, 8, 16];

/// Every (group, row) index is covered by exactly one launch unit.
fn assert_covers_exactly_once(launches: &[EvalLaunch], sizes: &[usize]) {
    let mut seen: Vec<Vec<bool>> = sizes.iter().map(|&n| vec![false; n]).collect();
    let units = launches.iter().flat_map(|l| match l {
        EvalLaunch::Fused { units, .. } => units.as_slice(),
        EvalLaunch::Single(u) => std::slice::from_ref(u),
    });
    for u in units {
        for i in u.start..u.start + u.real {
            assert!(!seen[u.group][i], "row ({}, {i}) covered twice", u.group);
            seen[u.group][i] = true;
        }
    }
    for (g, rows) in seen.iter().enumerate() {
        assert!(rows.iter().all(|&s| s), "group {g} not fully covered");
    }
}

/// Satellite: bucket-edge property grid — for every group-size mix
/// straddling every bucket edge and every stack width, the plan covers
/// each row exactly once, never overfills a stack, never mixes buckets
/// in one launch, picks the smallest bucket that fits each unit, and its
/// counters reconcile exactly: `launches == fanout - avoided` and
/// `pad_rows` matches per-launch arithmetic.
#[test]
fn plan_wave_properties_across_bucket_edges() {
    let bs = 16usize;
    let edge_sizes: Vec<Vec<usize>> = vec![
        vec![1, 15, 16, 17],          // straddling the top bucket edge
        vec![1, 1, 2, 3],             // all tiny buckets
        vec![2 * bs + 3, 0, 0, 0],    // skewed: one expert takes the wave
        vec![0, 0, 0, 0],             // empty wave
        vec![5, 8, 9, 16],            // mid-ladder edges (4|8, 8, 16, 16)
        vec![bs + 1, bs + 1, 1, bs],  // repeated straddles
        vec![3 * bs + 5],             // single group, multi-span
        vec![7; 9],                   // wider than any stack
    ];
    for sizes in &edge_sizes {
        for &width in &[2usize, 3, 4, 8] {
            let plan = plan_wave(sizes, bs, LADDER, width);
            assert_covers_exactly_once(&plan.launches, sizes);
            let mut fused_launches = 0usize;
            let mut avoided = 0usize;
            let mut pad = 0u64;
            for l in &plan.launches {
                if let EvalLaunch::Fused { bucket, units } = l {
                    fused_launches += 1;
                    assert!(units.len() >= 2, "one-unit stacks must go single");
                    assert!(units.len() <= width, "stack overfilled");
                    avoided += units.len() - 1;
                    for u in units {
                        assert_eq!(u.bucket, *bucket, "launch mixes buckets");
                        assert!(u.real <= *bucket, "unit overflows its bucket");
                        // smallest bucket that fits
                        let best = LADDER.iter().find(|&&b| b >= u.real).copied();
                        assert_eq!(Some(*bucket), best, "not the smallest fitting bucket");
                        pad += (*bucket - u.real) as u64;
                    }
                    pad += ((width - units.len()) * bucket) as u64;
                }
            }
            assert_eq!(plan.execs_avoided, avoided, "{sizes:?} width {width}");
            assert_eq!(plan.pad_rows, pad, "{sizes:?} width {width}");
            assert_eq!(
                plan.launches.len(),
                plan.fanout_launches - plan.execs_avoided,
                "{sizes:?} width {width}: counters must reconcile"
            );
            assert!(fused_launches <= plan.launches.len());
        }
    }
}

// ---------------------------------------------------------------------
// XLA-backed tests (self-skip without artifacts; the fused tests also
// self-skip on manifests lacking eval_nll_all entries)
// ---------------------------------------------------------------------

struct Setup {
    engine: Engine,
    bpe: Bpe,
    mixture: smalltalk::coordinator::Mixture,
}

static SETUP: std::sync::OnceLock<Option<Setup>> = std::sync::OnceLock::new();

/// One trained E=4 mixture shared by the execution tests (the pattern of
/// `rust/tests/fused_scoring.rs`). Tests that assert on engine stats
/// build their own private engine instead of touching this shared one.
fn setup() -> Option<&'static Setup> {
    SETUP
        .get_or_init(|| {
            let dir = locate_artifacts()?;
            let engine = Engine::new(dir).expect("loading artifacts");
            let corpus = smalltalk::data::corpus::Corpus::generate(60, 400, 42, None);
            let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
            let cfg = PipelineConfig {
                router_variant: "router_micro".into(),
                expert_variant: "expert_sm".into(),
                n_experts: 4,
                em_rounds: 2,
                em_chunk: 96,
                em_steps_per_round: 8,
                shard_sequences: 128,
                expert_steps: 10,
                prefix_len: 32,
                seed: 3,
                threads: 0,
            };
            let mixture = run_pipeline(&engine, &bpe, &cfg)
                .expect("training the shared test mixture")
                .mixture;
            Some(Setup { engine, bpe, mixture })
        })
        .as_ref()
}

/// `expert_meta` with the fused eval entries stripped: the dispatcher
/// sees an empty ladder and takes the bit-identical per-expert fan-out —
/// the reference the fused path is compared against.
fn stripped_meta(meta: &VariantMeta) -> VariantMeta {
    let mut stripped = meta.clone();
    stripped
        .entry_points
        .retain(|e| !e.starts_with("eval_nll_all_"));
    assert!(stripped.fused_eval_buckets().is_empty());
    stripped
}

/// Wave token pool: full `seq_len + 1` eval rows.
fn pool(setup: &Setup, n: usize, seed: u64) -> Vec<Vec<u32>> {
    SequenceGen::new(&setup.bpe, setup.mixture.expert_meta.seq_len, seed)
        .batch(n)
        .into_iter()
        .map(|s| s.tokens)
        .collect()
}

/// Slice a flat pool into per-expert groups of the given sizes.
fn groups_of<'a>(pool: &'a [Vec<u32>], sizes: &[usize]) -> Vec<Vec<&'a [u32]>> {
    let mut start = 0usize;
    sizes
        .iter()
        .map(|&n| {
            let g: Vec<&[u32]> = pool[start..start + n].iter().map(Vec::as_slice).collect();
            start += n;
            g
        })
        .collect()
}

fn require_fused_eval(meta: &VariantMeta) -> bool {
    if meta.fused_eval_buckets().is_empty() {
        eprintln!(
            "[fused_eval] manifest has no eval_nll_all entries for {} — \
             re-run `make artifacts`; skipping",
            meta.name
        );
        return false;
    }
    true
}

/// Satellite: fused and fan-out wave eval are bit-identical — every
/// bucket edge, a skewed all-to-one wave, and an empty group included —
/// at worker counts {1, E}, and no dead padding row ever leaks into a
/// real slot (the outputs have exactly the group sizes, every value
/// accounted against the reference).
#[test]
fn fused_wave_matches_fanout_bit_for_bit() {
    let Some(setup) = setup() else { return };
    let meta = &setup.mixture.expert_meta;
    if !require_fused_eval(meta) {
        return;
    }
    let experts: Vec<&TrainState> = setup.mixture.experts.iter().collect();
    let e = experts.len();
    let bs = meta.eval_batch;
    let stripped = stripped_meta(meta);

    let waves: Vec<Vec<usize>> = vec![
        vec![1, bs - 1, bs, bs + 1],    // every bucket edge at once
        vec![2 * bs + 3, 0, 0, 0],      // skewed: one expert, empty groups
        vec![1, 1, 1, 1],               // all-tiny: one fused launch
        vec![0, 0, 0, 0],               // empty wave
        vec![bs, bs, bs, bs],           // aligned full buckets
    ];
    for sizes in &waves {
        let n: usize = sizes.iter().sum();
        let rows = pool(setup, n, 23);
        let groups = groups_of(&rows, sizes);
        let reference =
            eval_nll_groups(&setup.engine, &experts, &stripped, &groups, 1).unwrap();
        for (g, r) in reference.iter().enumerate() {
            assert_eq!(r.len(), sizes[g], "fan-out output shape");
        }
        for threads in [1usize, e] {
            let fused = eval_nll_groups(&setup.engine, &experts, meta, &groups, threads).unwrap();
            assert_eq!(fused.len(), reference.len());
            for (g, (f, r)) in fused.iter().zip(&reference).enumerate() {
                assert_eq!(f.len(), r.len(), "sizes {sizes:?} group {g}: dead rows leaked");
                for i in 0..f.len() {
                    assert_eq!(
                        f[i].to_bits(),
                        r[i].to_bits(),
                        "sizes {sizes:?} threads={threads}: [{g}][{i}] diverged from fan-out"
                    );
                }
            }
        }
    }
}

/// Launch accounting (the acceptance criterion): at E=4 a straddle wave
/// {1, bs-1, bs, bs+1} executes 2 bucketed launches instead of the
/// fan-out's 5, a skewed all-to-one wave executes 2 instead of 4, and
/// the [`EngineStats`] pad/avoided counters reconcile exactly with the
/// planner's arithmetic.
#[test]
fn fused_wave_launch_accounting() {
    let Some(setup) = setup() else { return };
    let Some(dir) = locate_artifacts() else { return };
    let meta = &setup.mixture.expert_meta;
    if !require_fused_eval(meta) {
        return;
    }
    // private engine: isolate counters from concurrently running tests
    let eng = Engine::new(dir).expect("loading artifacts");
    let experts: Vec<&TrainState> = setup.mixture.experts.iter().collect();
    let bs = meta.eval_batch;
    let stripped = stripped_meta(meta);

    for (label, sizes, fanout_want) in [
        ("straddle", vec![1, bs - 1, bs, bs + 1], 5usize),
        ("skewed", vec![3 * bs + 5, 0, 0, 0], 4),
    ] {
        let n: usize = sizes.iter().sum();
        let rows = pool(setup, n, 29);
        let groups = groups_of(&rows, &sizes);
        let plan = plan_wave(&sizes, bs, &meta.fused_eval_buckets(), meta.fused_experts);
        assert_eq!(plan.fanout_launches, fanout_want, "{label}");
        assert!(
            plan.launches.len() <= 2,
            "{label}: an E=4 wave must plan at most 2 launches"
        );

        // warm the compile cache (and the stacked cache for this member
        // set) so executions, not compiles, are measured
        eval_nll_groups(&eng, &experts, &stripped, &groups, 1).unwrap();
        eval_nll_groups(&eng, &experts, meta, &groups, 1).unwrap();

        let s0 = eng.stats();
        eval_nll_groups(&eng, &experts, &stripped, &groups, 1).unwrap();
        let fanout = eng.stats().since(&s0);
        assert_eq!(
            fanout.executions, fanout_want,
            "{label}: fan-out runs one launch per expert batch"
        );
        assert_eq!(fanout.fused_eval_executions, 0);
        assert_eq!(fanout.eval_pad_rows, 0);

        let s0 = eng.stats();
        eval_nll_groups(&eng, &experts, meta, &groups, 1).unwrap();
        let fused = eng.stats().since(&s0);
        let fused_want = plan
            .launches
            .iter()
            .filter(|l| matches!(l, EvalLaunch::Fused { .. }))
            .count();
        assert_eq!(
            fused.executions,
            plan.launches.len(),
            "{label}: total launches match the plan"
        );
        assert_eq!(fused.fused_eval_executions, fused_want, "{label}");
        assert_eq!(
            fused.expert_execs_avoided, plan.execs_avoided,
            "{label}: avoided launches reconcile with the plan"
        );
        assert_eq!(
            fused.eval_pad_rows, plan.pad_rows,
            "{label}: discarded rows reconcile with the plan"
        );
        assert_eq!(
            fused.stack_rebuilds, 0,
            "{label}: the warm-up call already stacked these versions"
        );
        assert_eq!(fused.compiles, 0, "{label}: warm cache — no compiles");
    }
}

/// End to end: closed-wave serving answers identically with and without
/// fused eval entries — same `(id, expert, nll)` triples at worker
/// counts {1, E} — so flipping manifests can never change results.
#[test]
fn serve_triples_identical_fused_vs_fanout() {
    let Some(setup) = setup() else { return };
    let meta = &setup.mixture.expert_meta;
    if !require_fused_eval(meta) {
        return;
    }
    let bs = meta.eval_batch;
    let m = 32usize;
    let rows = pool(setup, 2 * bs + 3, 31);
    let requests: Vec<smalltalk::coordinator::Request> = rows
        .iter()
        .enumerate()
        .map(|(i, r)| smalltalk::coordinator::Request {
            id: i as u64,
            tokens: r.clone(),
        })
        .collect();

    // a mixture whose expert manifest lacks the fused eval entries: the
    // serving loop transparently falls back to per-expert fan-out
    let fallback = smalltalk::coordinator::Mixture {
        routers: setup.mixture.routers.clone(),
        router_meta: setup.mixture.router_meta.clone(),
        experts: setup.mixture.experts.clone(),
        expert_meta: stripped_meta(meta),
    };

    let reference =
        serve_threaded(&setup.engine, &fallback, &requests, m, 1).unwrap();
    let want = response_triples(&reference);
    for threads in [1usize, setup.mixture.n_experts()] {
        let fused =
            serve_threaded(&setup.engine, &setup.mixture, &requests, m, threads).unwrap();
        assert_eq!(
            response_triples(&fused),
            want,
            "threads={threads}: fused serving diverged from fan-out"
        );
    }
}
