//! Integration: the device-resident buffer cache and score-matrix batching.
//!
//! Proves the two load-bearing properties of the runtime refactor:
//!
//! * parameters upload once per `(state, version)` — not once per call —
//!   and training evicts stale buffers (version bump);
//! * `score_matrix` tail-batch padding is invisible: a sequence count that
//!   is not a multiple of `prefix_batch` produces bit-identical scores to
//!   the batch-aligned case (padding rows discarded, no index skew).
//!
//! Like the other XLA-backed tests, these skip without compiled artifacts.

use smalltalk::coordinator::scoring::{score_matrix, score_matrix_rows};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};

fn engine() -> Option<Engine> {
    let dir = locate_artifacts()?;
    Some(Engine::new(dir).expect("loading artifacts"))
}

fn bpe() -> Bpe {
    let corpus = Corpus::generate(60, 400, 42, None);
    BpeTrainer::new(512).train(corpus.texts()).unwrap()
}

#[test]
fn params_upload_once_per_state_version() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let st = TrainState::init(&eng, "router_micro", 7).unwrap();
    let mut gen = SequenceGen::new(&b, meta.seq_len, 3);
    let m = 32;
    let batch: Vec<Vec<u32>> = gen
        .batch(meta.prefix_batch)
        .iter()
        .map(|s| s.prefix(m).to_vec())
        .collect();

    let s0 = eng.stats();
    st.prefix_nll(&eng, &batch, &meta, m).unwrap();
    let after_first = eng.stats().since(&s0);
    assert_eq!(
        after_first.param_uploads, 1,
        "first call must upload the parameter vector once"
    );

    let s1 = eng.stats();
    for _ in 0..3 {
        st.prefix_nll(&eng, &batch, &meta, m).unwrap();
    }
    let after_more = eng.stats().since(&s1);
    assert_eq!(
        after_more.param_uploads, 0,
        "repeat calls on an unchanged state must reuse the resident buffer"
    );
    assert_eq!(
        after_more.uploads_avoided, 3,
        "each repeat call serves params from the device cache"
    );
    // only the token batch moves host->device on repeat calls
    assert_eq!(
        after_more.h2d_bytes,
        3 * (meta.prefix_batch * m * 4) as u64,
        "repeat-call h2d traffic must be the token batch alone"
    );
}

#[test]
fn training_evicts_stale_param_buffers() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let mut st = TrainState::init(&eng, "router_micro", 8).unwrap();
    let mut gen = SequenceGen::new(&b, meta.seq_len, 5);
    let m = 32;
    let prefix_batch: Vec<Vec<u32>> = gen
        .batch(meta.prefix_batch)
        .iter()
        .map(|s| s.prefix(m).to_vec())
        .collect();
    let train_batch: Vec<Vec<u32>> = gen
        .batch(meta.train_batch)
        .into_iter()
        .map(|s| s.tokens)
        .collect();

    let before = st.prefix_nll(&eng, &prefix_batch, &meta, m).unwrap();
    let v0 = st.version();
    st.train_step(&eng, &train_batch, &meta).unwrap();
    assert!(st.version() > v0, "train_step must bump the version");

    let s0 = eng.stats();
    let after = st.prefix_nll(&eng, &prefix_batch, &meta, m).unwrap();
    let d = eng.stats().since(&s0);
    assert_eq!(
        d.param_uploads, 1,
        "post-training call must re-upload the changed parameters"
    );
    assert!(
        after != before,
        "scores must reflect the trained (not cached-stale) parameters"
    );
}

#[test]
fn tail_batch_padding_produces_identical_scores() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let routers = vec![
        TrainState::init(&eng, "router_micro", 11).unwrap(),
        TrainState::init(&eng, "router_micro", 12).unwrap(),
    ];
    let m = 32;
    let bs = meta.prefix_batch;
    let mut gen = SequenceGen::new(&b, meta.seq_len, 9);
    // bs + 3 sequences: one full batch plus a misaligned tail of 3
    let seqs = gen.batch(bs + 3);

    let full = score_matrix(&eng, &routers, &meta, &seqs, m).unwrap();
    assert_eq!(full.len(), bs + 3);

    // batch-aligned reference over the first bs sequences
    let aligned = score_matrix(&eng, &routers, &meta, &seqs[..bs], m).unwrap();
    for i in 0..bs {
        assert_eq!(full[i], aligned[i], "aligned row {i} skewed by tail handling");
    }

    // the tail scored alone (it is padded internally) must equal the same
    // rows from the combined call — padding rows discarded, no index skew
    let tail = score_matrix(&eng, &routers, &meta, &seqs[bs..], m).unwrap();
    for i in 0..3 {
        assert_eq!(full[bs + i], tail[i], "tail row {i} skewed by padding");
    }
}

#[test]
fn score_matrix_rows_matches_sequence_entry() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let meta = eng.variant("router_micro").unwrap().clone();
    let routers = vec![TrainState::init(&eng, "router_micro", 21).unwrap()];
    let m = 32;
    let mut gen = SequenceGen::new(&b, meta.seq_len, 13);
    let seqs = gen.batch(meta.prefix_batch + 1);

    let via_seqs = score_matrix(&eng, &routers, &meta, &seqs, m).unwrap();
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(m)).collect();
    let via_rows = score_matrix_rows(&eng, &routers, &meta, &rows, m).unwrap();
    assert_eq!(via_seqs, via_rows);
}
