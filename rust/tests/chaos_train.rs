//! Chaos suite for the elastic trainer (`make test-chaos`).
//!
//! Everything here runs on the stub backend (tier-1, no artifacts): the
//! point is the *fault machinery*, not the model. The stub routes on the
//! token sum alone — deliberately snapshot-version-independent — so a
//! delayed publish or a dropped delivery perturbs scheduling without
//! perturbing the math, and a faulted run can be compared bit-for-bit
//! against an uninterrupted one. Coverage:
//!
//! * three fixed fault seeds, each run featuring a kill + adoption, a
//!   scheduled leave (with rejoin/merge), a mid-run join and a gated
//!   (delayed) publish — converging onto the uninterrupted run;
//! * kill at a checkpoint boundary adopting bit-identically with zero
//!   steps lost, and the adoption byte total matching the checkpoint
//!   file exactly;
//! * exact `SnapshotBroadcast` / `CheckpointAdopt` / `ParamMerge` byte
//!   audits across the store's and the elastic run's ledgers;
//! * a JSON fault spec replayed twice producing identical states and
//!   stats (the `--chaos-spec` determinism contract);
//! * the degradation contract: a structurally failing node ends as
//!   `NodeEnd::Failed` (never a panic) while the survivors complete.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use smalltalk::coordinator::{
    run_elastic_nodes, CommKind, CommLedger, ElasticHandle, ElasticPlan, ElasticPolicy,
    ElasticReport, FaultPlan, LeaveEvent, NodeEnd, NodeRunConfig, PlanShape, PublishGate, Rejoin,
    RouterSnapshot, SnapshotStore, TrainBackend,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::TrainState;
use smalltalk::tokenizer::{Bpe, BpeTrainer};

// ---------------------------------------------------------------------
// shared fixtures (mirrors tests/async_train.rs)
// ---------------------------------------------------------------------

/// Stub expert/router parameter count.
const P: usize = 6;
/// Stub stream sequence length (tokens per sequence = SEQ_LEN + 1).
const SEQ_LEN: usize = 16;

static BPE: OnceLock<Bpe> = OnceLock::new();

fn bpe() -> &'static Bpe {
    BPE.get_or_init(|| {
        let corpus = Corpus::generate(60, 400, 42, None);
        BpeTrainer::new(512).train(corpus.texts()).unwrap()
    })
}

fn temp_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "smalltalk_chaos_train_{tag}_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn states_equal(a: &TrainState, b: &TrainState) -> bool {
    a.params == b.params && a.m == b.m && a.v == b.v && a.step == b.step
}

/// Deterministic model-free backend. Unlike the async suite's stub, the
/// routing key ignores `snap.version`: stale, held-back or dropped
/// snapshots perturb *scheduling* but never the partition, which is what
/// lets the chaos tests demand bit-identity against clean runs.
/// Optionally injects a non-transient crash at a (node, step) to
/// exercise the structured-failure path.
struct ChaosStub {
    /// Total seats (base nodes + spares); the routing modulus.
    n: usize,
    bs: usize,
    fail_at: Option<(usize, u64)>,
}

impl ChaosStub {
    fn new(n: usize, bs: usize) -> Self {
        ChaosStub {
            n,
            bs,
            fail_at: None,
        }
    }
}

impl TrainBackend for ChaosStub {
    fn train_batch_rows(&self) -> usize {
        self.bs
    }

    fn tokens_per_step(&self) -> usize {
        self.bs * SEQ_LEN
    }

    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState> {
        let params: Vec<f32> = (0..P)
            .map(|i| (seed % 1000) as f32 * 1e-3 + node as f32 + i as f32 * 0.1)
            .collect();
        Ok(TrainState::from_params(
            "stub",
            params,
            vec![0.0; P],
            vec![0.0; P],
            0,
        ))
    }

    fn train_step(&self, node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        if let Some((fail_node, at)) = self.fail_at {
            if node == fail_node && state.step >= at {
                bail!("injected crash at node {node} step {}", state.step);
            }
        }
        let mut acc = 0.0f32;
        for row in batch {
            for &t in *row {
                acc += (t % 97) as f32;
            }
        }
        let loss = acc / (batch.len().max(1) as f32 * 100.0);
        for i in 0..state.params.len() {
            let g = loss * 1e-3 + (i as f32 + 1.0) * 1e-4;
            state.m[i] = 0.9 * state.m[i] + 0.1 * g;
            state.v[i] = 0.99 * state.v[i] + 0.01 * g * g;
            state.params[i] -= 0.1 * state.m[i];
        }
        state.step += 1;
        Ok(loss)
    }

    fn route_local(&self, _snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| {
                let sum: u64 = r.iter().map(|&t| t as u64).sum();
                (sum % self.n as u64) as usize
            })
            .collect())
    }
}

/// One router state per seat, P params each (the broadcast payload whose
/// byte total the ledger tests audit: `k * P * 4` bytes per publish).
fn router_fleet(k: usize) -> Vec<TrainState> {
    (0..k)
        .map(|e| {
            TrainState::from_params(
                "router",
                vec![0.5 + e as f32 * 0.1; P],
                vec![0.0; P],
                vec![0.0; P],
                1,
            )
        })
        .collect()
}

fn seat_seeds(n: usize) -> Vec<u64> {
    (0..n).map(|e| 0xE0 + e as u64).collect()
}

/// The standard test driver: join the requested spares *before* the
/// first publish (every node blocks on v1, so the queue cannot drain
/// under the join), publish v1, honor the plan's gate on v2 (the
/// injected *delayed publish*), publish v2.
fn drive(
    handle: &ElasticHandle<'_, '_>,
    plan: &ElasticPlan,
    join_seeds: &[u64],
    n_routers: usize,
) -> Result<()> {
    for &seed in join_seeds {
        handle.join_new_node(seed)?;
    }
    handle.store().publish(router_fleet(n_routers), 1);
    if let Some(min) = plan.faults.publish_gate(2) {
        let t0 = Instant::now();
        while (handle.total_steps_done() as u64) < min
            && handle.live_nodes() > 0
            && !handle.failed()
        {
            if t0.elapsed() > Duration::from_secs(30) {
                bail!("publish gate starved: fleet never reached {min} total steps");
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    handle.store().publish(router_fleet(n_routers), 2);
    Ok(())
}

/// Run an elastic fleet over the shared stream factory and return the
/// report plus the store's (broadcast) ledger.
fn elastic_run<R>(
    backend: &ChaosStub,
    seeds: &[u64],
    cfg: &NodeRunConfig,
    plan: &ElasticPlan,
    driver: impl FnOnce(&ElasticHandle<'_, 'static>) -> Result<R>,
) -> Result<(ElasticReport, CommLedger, R)> {
    let store = SnapshotStore::new(seeds.len());
    let b = bpe();
    let factory = move |e: usize, salt: u64| {
        SequenceGen::new(
            b,
            SEQ_LEN,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    let (report, r) = run_elastic_nodes(backend, &store, seeds, factory, cfg, plan, driver)?;
    Ok((report, store.take_ledger(), r))
}

/// The seat's final state, demanding a normal completion.
fn completed_state(report: &ElasticReport, seat: usize) -> &TrainState {
    let end = report
        .ends
        .iter()
        .find(|e| e.node() == seat)
        .unwrap_or_else(|| panic!("seat {seat} has no end record"));
    match end {
        NodeEnd::Completed(o) => &o.state,
        NodeEnd::Left(o) => panic!("seat {seat} left unadopted at step {}", o.steps_done),
        NodeEnd::Failed(f) => panic!("seat {seat} failed: {:#}", f.error),
    }
}

// ---------------------------------------------------------------------
// three-seed chaos runs: kill + leave/rejoin + join + delayed publish
// ---------------------------------------------------------------------

/// For each of three fixed fault seeds: a chaos run with one kill (and
/// checkpoint adoption), one scheduled leave (adopted seat, offline
/// rejoin merged back), one mid-run join onto a spare seat and one gated
/// publish converges onto the uninterrupted run — bit-identically on
/// every seat the merge never touched, within tolerance on the merged
/// one — and every byte of injected traffic is audited exactly.
#[test]
fn chaos_runs_converge_across_three_seeds() {
    const NODES: usize = 3;
    const STEPS: usize = 24;
    let policy = ElasticPolicy {
        // two generated transients can collide on one (node, step); give
        // the retry loop headroom so collisions stay transient
        max_retries: 5,
        max_extra_nodes: 1,
        ..ElasticPolicy::default()
    };
    let leave = LeaveEvent {
        node: 1,
        at_step: 10,
        adopt: true,
        rejoin: Some(Rejoin {
            offline_steps: 2,
            merge_at_step: 16,
        }),
    };
    let backend = ChaosStub::new(NODES + 1, 4);
    let seeds = seat_seeds(NODES);
    let join_seeds = [0x77u64];

    // the uninterrupted reference: same seats, same join, no faults
    let clean = ElasticPlan {
        faults: FaultPlan::none(),
        leaves: vec![],
        policy,
        ..ElasticPlan::default()
    };
    let ref_cfg = NodeRunConfig {
        steps_per_node: STEPS,
        checkpoint_every: 2,
        checkpoint_dir: Some(temp_dir("ref")),
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let (ref_report, _, ()) =
        elastic_run(&backend, &seeds, &ref_cfg, &clean, |h| {
            drive(h, &clean, &join_seeds, NODES + 1)
        })
        .unwrap();

    for fault_seed in [11u64, 23, 47] {
        let mut faults = FaultPlan::generate(
            fault_seed,
            &PlanShape {
                nodes: NODES,
                steps_per_node: STEPS as u64,
                kills: 1,
                transients: 2,
                stalls: 1,
                drops: 1,
                publish_gates: 0,
                snapshot_versions: 2,
                ..PlanShape::default()
            },
        );
        // the delayed publish is pinned by hand: a generated gate could
        // land on v1, which nothing can ever step past
        faults.publish_gates = vec![PublishGate {
            version: 2,
            min_total_steps: 6,
        }];
        let expected_retries: u64 = faults.transients.iter().map(|t| t.failures as u64).sum();
        let plan = ElasticPlan {
            faults,
            leaves: vec![leave],
            policy,
            ..ElasticPlan::default()
        };
        let cfg = NodeRunConfig {
            checkpoint_dir: Some(temp_dir("chaos")),
            ..ref_cfg.clone()
        };
        let (report, broadcast, ()) =
            elastic_run(&backend, &seeds, &cfg, &plan, |h| {
                drive(h, &plan, &join_seeds, NODES + 1)
            })
            .unwrap();

        let s = &report.stats;
        assert_eq!(s.kills, 1, "seed {fault_seed}: kill did not fire");
        assert_eq!(s.leaves, 1, "seed {fault_seed}: leave did not fire");
        assert_eq!(s.joins, 1, "seed {fault_seed}: join did not land");
        assert_eq!(s.merges, 1, "seed {fault_seed}: rejoin never merged");
        assert_eq!(
            s.adoptions, 2,
            "seed {fault_seed}: expected kill + leave adoptions"
        );
        assert!(
            s.steps_lost <= 1,
            "seed {fault_seed}: checkpoint_every=2 bounds the loss to 1, got {}",
            s.steps_lost
        );
        assert_eq!(
            s.transient_retries, expected_retries,
            "seed {fault_seed}: every scheduled transient must be consumed"
        );

        // convergence: the merge only ever touches seat 1's params
        assert_eq!(report.ends.len(), NODES + 1);
        for seat in [0, 2, 3] {
            assert!(
                states_equal(completed_state(&report, seat), completed_state(&ref_report, seat)),
                "seed {fault_seed}: seat {seat} diverged from the clean run"
            );
        }
        let merged = completed_state(&report, 1);
        let clean1 = completed_state(&ref_report, 1);
        assert_eq!(merged.step, clean1.step, "seed {fault_seed}");
        assert_eq!(merged.m, clean1.m, "seed {fault_seed}: merge must not touch m");
        assert_eq!(merged.v, clean1.v, "seed {fault_seed}: merge must not touch v");
        for (i, (a, b)) in merged.params.iter().zip(&clean1.params).enumerate() {
            assert!(
                (a - b).abs() <= 0.1,
                "seed {fault_seed}: merged param {i} drifted: {a} vs {b}"
            );
        }

        // exact byte audit. Broadcasts: the joiner subscribes before v1
        // (see `drive`), so both versions go to all 4 seats — the leave
        // is adopted and the kill re-seats, so neither sheds a
        // subscriber; payload = 4 routers * P * 4.
        let payload = ((NODES + 1) * P * 4) as u64;
        assert_eq!(
            broadcast.kind_bytes(CommKind::SnapshotBroadcast),
            2 * 4 * payload,
            "seed {fault_seed}: broadcast byte total"
        );
        assert_eq!(broadcast.rounds(CommKind::SnapshotBroadcast), 2);
        let adopt_events = report
            .ledger
            .events
            .iter()
            .filter(|e| e.kind == CommKind::CheckpointAdopt)
            .count();
        assert_eq!(adopt_events as u64, s.adoptions, "seed {fault_seed}");
        assert_eq!(
            report.ledger.kind_bytes(CommKind::ParamMerge),
            (P * 4) as u64,
            "seed {fault_seed}: one merge ships exactly the param delta"
        );
        assert!(
            report.ledger.max_merge_staleness() <= 1,
            "seed {fault_seed}: only v2 can have landed after the leave"
        );
    }
}

// ---------------------------------------------------------------------
// kill at a checkpoint boundary: bit-identical, zero-loss adoption
// ---------------------------------------------------------------------

/// A kill landing exactly on a checkpoint boundary loses nothing: the
/// adopter resumes the just-written checkpoint and the run finishes
/// bit-identical to an unfaulted one. The adoption's ledger bytes equal
/// the checkpoint file's size exactly (measured by a probe run that
/// stops at the boundary, which writes the identical file).
#[test]
fn kill_at_checkpoint_boundary_adopts_bit_identically() {
    const STEPS: usize = 12;
    const BOUNDARY: u64 = 9; // checkpoint_every = 3
    let backend = ChaosStub::new(2, 4);
    let seeds = seat_seeds(2);
    let clean = ElasticPlan::default();
    let base = NodeRunConfig {
        steps_per_node: STEPS,
        checkpoint_every: 3,
        threads: 2,
        draw_budget: 1000, // pinned so the probe's node is byte-identical
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };

    // probe: stop at the boundary; its final checkpoint *is* the file
    // the chaos run's adopter will read
    let probe_dir = temp_dir("probe");
    let probe_cfg = NodeRunConfig {
        steps_per_node: BOUNDARY as usize,
        checkpoint_dir: Some(probe_dir.clone()),
        ..base.clone()
    };
    elastic_run(&backend, &seeds, &probe_cfg, &clean, |h| {
        drive(h, &clean, &[], 2)
    })
    .unwrap();
    let ckpt_bytes = std::fs::metadata(probe_dir.join("node1.ckpt"))
        .expect("probe run must leave node1's checkpoint behind")
        .len();

    let ref_cfg = NodeRunConfig {
        checkpoint_dir: Some(temp_dir("boundary_ref")),
        ..base.clone()
    };
    let (ref_report, _, ()) = elastic_run(&backend, &seeds, &ref_cfg, &clean, |h| {
        drive(h, &clean, &[], 2)
    })
    .unwrap();

    // the fault plan arrives as JSON, like a real --chaos-spec file
    let spec = format!(r#"{{ "seed": 5, "kills": [{{ "node": 1, "at_step": {BOUNDARY} }}] }}"#);
    let plan = ElasticPlan {
        faults: FaultPlan::from_json_str(&spec).unwrap(),
        ..ElasticPlan::default()
    };
    let cfg = NodeRunConfig {
        checkpoint_dir: Some(temp_dir("boundary")),
        ..base.clone()
    };
    let (report, _, ()) = elastic_run(&backend, &seeds, &cfg, &plan, |h| {
        drive(h, &plan, &[], 2)
    })
    .unwrap();

    assert_eq!(report.stats.kills, 1);
    assert_eq!(report.stats.adoptions, 1);
    assert_eq!(
        report.stats.steps_lost, 0,
        "a boundary kill must lose zero steps"
    );
    for seat in 0..2 {
        assert!(
            states_equal(completed_state(&report, seat), completed_state(&ref_report, seat)),
            "seat {seat} diverged after boundary adoption"
        );
    }
    let adopt: Vec<_> = report
        .ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::CheckpointAdopt)
        .collect();
    assert_eq!(adopt.len(), 1);
    assert_eq!(adopt[0].node, 1);
    assert_eq!(adopt[0].step, BOUNDARY, "adopter must resume at the boundary");
    assert_eq!(
        report.ledger.kind_bytes(CommKind::CheckpointAdopt),
        ckpt_bytes,
        "adoption bytes must equal the checkpoint file size"
    );
    assert_eq!(adopt[0].bytes_received, ckpt_bytes);
}

// ---------------------------------------------------------------------
// JSON spec replay determinism
// ---------------------------------------------------------------------

/// A `--chaos-spec`-shaped JSON plan (kill, retried transient, stall,
/// dropped delivery, gated publish) replayed twice through fresh runs
/// produces bit-identical states and identical stats — the whole point
/// of keying faults on step counts instead of the clock. Also pins the
/// JSON roundtrip (`to_json` -> parse -> `to_json`).
#[test]
fn json_fault_spec_replays_identically() {
    let spec = r#"{
        "seed": 9,
        "kills": [{ "node": 0, "at_step": 5 }],
        "transients": [{ "node": 1, "at_step": 3, "failures": 2 }],
        "stalls": [{ "node": 1, "at_step": 7, "micros": 500 }],
        "drops": [{ "node": 0, "version": 2 }],
        "publish_gates": [{ "version": 2, "min_total_steps": 4 }]
    }"#;
    let faults = FaultPlan::from_json_str(spec).unwrap();
    let roundtrip = FaultPlan::from_json_str(&faults.to_json().to_string()).unwrap();
    assert_eq!(
        roundtrip.to_json().to_string(),
        faults.to_json().to_string(),
        "JSON spec roundtrip must be lossless"
    );

    let backend = ChaosStub::new(2, 4);
    let seeds = seat_seeds(2);
    let plan = ElasticPlan {
        faults,
        ..ElasticPlan::default()
    };
    let run = |tag: &str| {
        let cfg = NodeRunConfig {
            steps_per_node: 12,
            checkpoint_every: 2,
            checkpoint_dir: Some(temp_dir(tag)),
            threads: 2,
            snapshot_wait_us: 10_000_000,
            ..NodeRunConfig::default()
        };
        let (report, _, ()) =
            elastic_run(&backend, &seeds, &cfg, &plan, |h| drive(h, &plan, &[], 2)).unwrap();
        report
    };
    let first = run("replay_a");
    let second = run("replay_b"); // run_elastic_nodes re-arms the plan

    assert_eq!(first.stats.kills, 1);
    assert_eq!(first.stats.transient_retries, 2);
    for seat in 0..2 {
        assert!(
            states_equal(completed_state(&first, seat), completed_state(&second, seat)),
            "seat {seat} diverged between replays of the same spec"
        );
    }
    let mut a = first.stats.clone();
    let mut b = second.stats.clone();
    // the only wall-clock-denominated stat; everything else must replay
    a.recovery_micros = 0;
    b.recovery_micros = 0;
    assert_eq!(a, b, "replays of one spec must count identical faults");
}

// ---------------------------------------------------------------------
// membership edges
// ---------------------------------------------------------------------

/// Joining past the spare-seat budget is a structured error on the
/// handle; the join that did fit completes its full step budget.
#[test]
fn join_beyond_spare_seats_is_rejected() {
    let backend = ChaosStub::new(3, 4);
    let seeds = seat_seeds(2);
    let plan = ElasticPlan {
        policy: ElasticPolicy {
            max_extra_nodes: 1,
            ..ElasticPolicy::default()
        },
        ..ElasticPlan::default()
    };
    let cfg = NodeRunConfig {
        steps_per_node: 8,
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let (report, _, ()) = elastic_run(&backend, &seeds, &cfg, &plan, |h| {
        // join before the first publish: everyone is still blocked on
        // v1, so the run cannot have drained out from under the join
        let seat = h.join_new_node(0x77)?;
        assert_eq!(seat, 2, "the one spare seat");
        let err = h.join_new_node(0x78).unwrap_err();
        assert!(
            format!("{err:#}").contains("no spare seat"),
            "unexpected join error: {err:#}"
        );
        h.store().publish(router_fleet(3), 1);
        Ok(())
    })
    .unwrap();
    assert_eq!(report.stats.joins, 1);
    assert_eq!(report.ends.len(), 3);
    let joiner = completed_state(&report, 2);
    assert_eq!(joiner.step, 8, "the admitted joiner trains its full budget");
}

/// Degradation contract: a node failing *structurally* (non-transient
/// backend error) becomes a `NodeEnd::Failed` with its salvageable state
/// attached — no panic, no aborted run — while the survivor completes,
/// which is all the run needs to return `Ok`.
#[test]
fn structural_failure_degrades_without_aborting() {
    let backend = ChaosStub {
        fail_at: Some((0, 4)),
        ..ChaosStub::new(2, 4)
    };
    let seeds = seat_seeds(2);
    let plan = ElasticPlan::default();
    let cfg = NodeRunConfig {
        steps_per_node: 12,
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let (report, _, ()) = elastic_run(&backend, &seeds, &cfg, &plan, |h| {
        h.store().publish(router_fleet(2), 1);
        Ok(())
    })
    .unwrap();

    assert_eq!(report.ends.len(), 2);
    match report.ends.iter().find(|e| e.node() == 0) {
        Some(NodeEnd::Failed(f)) => {
            assert_eq!(f.steps_done, 4);
            assert!(
                format!("{:#}", f.error).contains("injected crash"),
                "error must carry the backend's cause: {:#}",
                f.error
            );
            let salvage = f.salvage.as_ref().expect("state is salvageable after init");
            assert_eq!(salvage.step, 4);
        }
        other => panic!(
            "seat 0 should have failed structurally, got {:?}",
            other.map(NodeEnd::node)
        ),
    }
    let survivor = completed_state(&report, 1);
    assert_eq!(survivor.step, 12, "the survivor finishes its budget");
}
