//! Fleet-shard suite for the sharded elastic trainer (`make test-shard`).
//!
//! Everything runs on the stub backend (tier-1, no artifacts), mirroring
//! `tests/chaos_train.rs`: the stub routes on the token sum alone, so
//! shard-level faults (partitions, leader losses, whole-shard kills)
//! perturb scheduling and accounting but never the expert math — which is
//! what lets a faulted fleet be compared bit-for-bit against a clean one.
//! Coverage:
//!
//! * a JSON fault spec with a 2-round partition, a leader loss and a
//!   whole-shard kill completes `Ok` and converges bit-identically onto
//!   the uninterrupted fleet (experts and routers);
//! * the intra/inter-shard byte split reconciles exactly against closed-
//!   form publish/adopt/broadcast counts, with `CrossShardPublish`
//!   traffic landing only at EM-round boundaries;
//! * a generated sharded plan, exported to JSON and replayed twice,
//!   produces bit-identical states, stats and byte totals;
//! * a failed shard degrades (run stays `Ok`, its last exchanged block is
//!   salvaged into the final routers) and an all-shards failure aborts
//!   structurally;
//! * checkpoints are namespaced `<dir>/shard{s}/` (regression: the flat
//!   layout must be gone), stale temps in shard subdirectories are swept,
//!   and a one-shard fleet resumes pre-shard flat checkpoints;
//! * orphaned nodes error with shard/node/version context;
//! * the `FaultPlan` JSON surface round-trips over random shapes and
//!   rejects malformed specs with structured errors, never panics.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

use anyhow::{bail, Result};

use smalltalk::coordinator::{
    run_elastic_nodes, run_sharded_nodes, CommKind, ElasticHandle, ElasticPlan, ElasticStats,
    FaultPlan, FleetReport, NodeEnd, NodeRunConfig, PlanShape, RouterSnapshot, ShardCtx,
    ShardPlan, SnapshotStore, TrainBackend,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::TrainState;
use smalltalk::tokenizer::{Bpe, BpeTrainer};
use smalltalk::util::prop;

// ---------------------------------------------------------------------
// shared fixtures (mirrors tests/chaos_train.rs)
// ---------------------------------------------------------------------

/// Stub expert/router parameter count.
const P: usize = 6;
/// Stub stream sequence length (tokens per sequence = SEQ_LEN + 1).
const SEQ_LEN: usize = 16;

static BPE: OnceLock<Bpe> = OnceLock::new();

fn bpe() -> &'static Bpe {
    BPE.get_or_init(|| {
        let corpus = Corpus::generate(60, 400, 42, None);
        BpeTrainer::new(512).train(corpus.texts()).unwrap()
    })
}

fn temp_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "smalltalk_shard_train_{tag}_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn states_equal(a: &TrainState, b: &TrainState) -> bool {
    a.params == b.params && a.m == b.m && a.v == b.v && a.step == b.step
}

/// Deterministic model-free backend; the routing key ignores the snapshot
/// contents entirely (token sum modulo the *global* seat count), so stale
/// or missing cross-shard views perturb nothing but accounting — the
/// fleet tests can therefore demand bit-identity against clean runs.
struct ChaosStub {
    /// Total global seats; the routing modulus.
    n: usize,
    bs: usize,
}

impl ChaosStub {
    fn new(n: usize, bs: usize) -> Self {
        ChaosStub { n, bs }
    }
}

impl TrainBackend for ChaosStub {
    fn train_batch_rows(&self) -> usize {
        self.bs
    }

    fn tokens_per_step(&self) -> usize {
        self.bs * SEQ_LEN
    }

    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState> {
        let params: Vec<f32> = (0..P)
            .map(|i| (seed % 1000) as f32 * 1e-3 + node as f32 + i as f32 * 0.1)
            .collect();
        Ok(TrainState::from_params(
            "stub",
            params,
            vec![0.0; P],
            vec![0.0; P],
            0,
        ))
    }

    fn train_step(&self, _node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        let mut acc = 0.0f32;
        for row in batch {
            for &t in *row {
                acc += (t % 97) as f32;
            }
        }
        let loss = acc / (batch.len().max(1) as f32 * 100.0);
        for i in 0..state.params.len() {
            let g = loss * 1e-3 + (i as f32 + 1.0) * 1e-4;
            state.m[i] = 0.9 * state.m[i] + 0.1 * g;
            state.v[i] = 0.99 * state.v[i] + 0.01 * g * g;
            state.params[i] -= 0.1 * state.m[i];
        }
        state.step += 1;
        Ok(loss)
    }

    fn route_local(&self, _snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| {
                let sum: u64 = r.iter().map(|&t| t as u64).sum();
                (sum % self.n as u64) as usize
            })
            .collect())
    }
}

fn seat_seeds(n: usize) -> Vec<u64> {
    (0..n).map(|e| 0xE0 + e as u64).collect()
}

/// The deterministic router block shard `shard` publishes at `round`
/// (one state per member seat, member order). A pure function of
/// (seat, round), so clean and faulted fleets must assemble identical
/// final router sets regardless of partition schedules.
fn shard_block(plan: &ShardPlan, shard: usize, round: u64) -> Vec<TrainState> {
    plan.members(shard)
        .iter()
        .map(|&seat| {
            let params: Vec<f32> = (0..P)
                .map(|i| seat as f32 + round as f32 * 0.01 + i as f32 * 0.001)
                .collect();
            TrainState::from_params("router", params, vec![0.0; P], vec![0.0; P], round)
        })
        .collect()
}

/// Run a sharded fleet over the shared stream factory.
fn fleet_run(
    backend: &ChaosStub,
    plan: &ShardPlan,
    seeds: &[u64],
    cfg: &NodeRunConfig,
    fleet: &ElasticPlan,
    driver: impl Fn(usize, &ShardCtx<'_>, &ElasticHandle<'_, 'static>) -> Result<Vec<TrainState>>
        + Sync,
) -> Result<(FleetReport, Vec<TrainState>)> {
    let b = bpe();
    let factory = move |e: usize, salt: u64| {
        SequenceGen::new(
            b,
            SEQ_LEN,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    run_sharded_nodes(backend, plan, seeds, factory, cfg, fleet, driver)
}

/// The seat's final state, demanding a normal completion.
fn completed_state(ends: &[NodeEnd], seat: usize) -> &TrainState {
    let end = ends
        .iter()
        .find(|e| e.node() == seat)
        .unwrap_or_else(|| panic!("seat {seat} has no end record"));
    match end {
        NodeEnd::Completed(o) => &o.state,
        NodeEnd::Left(o) => panic!("seat {seat} left unadopted at step {}", o.steps_done),
        NodeEnd::Failed(f) => panic!("seat {seat} failed: {:#}", f.error),
    }
}

/// The shared 3-shard chaos scenario: a 2-round partition and a leader
/// loss on shard 1, a whole-shard kill on shard 2 — arriving as JSON,
/// like a real `--chaos-spec` file.
const ROUNDS: u64 = 4;
const STEPS: usize = 12;

fn chaos_spec() -> &'static str {
    r#"{
        "seed": 7,
        "partitions": [{ "shard": 1, "from_round": 2, "rounds": 2 }],
        "leader_losses": [{ "shard": 1, "at_round": 2 }],
        "shard_kills": [{ "shard": 2, "at_step": 8 }]
    }"#
}

fn base_cfg(tag: &str) -> NodeRunConfig {
    NodeRunConfig {
        steps_per_node: STEPS,
        checkpoint_every: 3,
        checkpoint_dir: Some(temp_dir(tag)),
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    }
}

// ---------------------------------------------------------------------
// shard chaos converges onto the clean fleet
// ---------------------------------------------------------------------

/// A 3-shard fleet under partition + leader loss + whole-shard kill
/// completes `Ok`, converges bit-identically onto the uninterrupted
/// fleet on every expert seat, assembles the identical (partition-
/// independent) global router set, and rolls the faults up into the
/// right per-shard rows.
#[test]
fn shard_chaos_converges_onto_the_clean_fleet() {
    let plan = ShardPlan::partition(6, 3).unwrap();
    let backend = ChaosStub::new(6, 4);
    let seeds = seat_seeds(6);
    let driver = |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
        for round in 1..=ROUNDS {
            ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
        }
        Ok(shard_block(&plan, s, ROUNDS))
    };

    let clean_fleet = ElasticPlan::default();
    let (clean, clean_routers) =
        fleet_run(&backend, &plan, &seeds, &base_cfg("clean"), &clean_fleet, driver).unwrap();

    let fleet = ElasticPlan {
        faults: FaultPlan::from_json_str(chaos_spec()).unwrap(),
        ..ElasticPlan::default()
    };
    let (report, routers) =
        fleet_run(&backend, &plan, &seeds, &base_cfg("chaos"), &fleet, driver).unwrap();

    // fleet-level rollup: 2 seats killed (the whole of shard 2), both
    // re-adopted from their step-6 checkpoints (kill at step 8,
    // checkpoint_every 3 -> exactly 2 steps re-done per seat)
    assert_eq!(report.stats.kills, 2);
    assert_eq!(report.stats.adoptions, 2);
    assert_eq!(report.stats.steps_lost, 4);
    assert_eq!(report.stats.leaves, 0);
    assert_eq!(report.stats.joins, 0);
    assert_eq!(report.stats.merges, 0);
    assert_eq!(report.stats.transient_retries, 0);

    // per-shard rows
    assert_eq!(report.shards.len(), 3);
    let s0 = &report.shards[0];
    assert_eq!((s0.shard, s0.promotions, s0.rounds_missed, s0.shard_kills), (0, 0, 0, 0));
    assert_eq!(s0.stats, ElasticStats::default(), "shard 0 saw no faults");
    let s1 = &report.shards[1];
    assert_eq!(s1.shard, 1);
    assert_eq!(s1.promotions, 1, "the leader loss promotes exactly once");
    assert_eq!(s1.rounds_missed, 2, "the partition cuts rounds 2 and 3");
    assert_eq!(s1.shard_kills, 0);
    assert_eq!(s1.stats.kills, 0, "partition and promotion kill nobody");
    let s2 = &report.shards[2];
    assert_eq!(s2.shard, 2);
    assert_eq!(s2.shard_kills, 2, "one ShardAdopt recovery per member seat");
    assert_eq!(s2.stats.kills, 2);
    assert_eq!(s2.stats.adoptions, 2);
    assert_eq!(s2.stats.steps_lost, 4);
    assert_eq!((s2.promotions, s2.rounds_missed), (0, 0));

    // convergence: every expert seat bit-identical to the clean fleet
    assert_eq!(clean.ends.len(), 6);
    assert_eq!(report.ends.len(), 6);
    for seat in 0..6 {
        let state = completed_state(&report.ends, seat);
        assert_eq!(state.step, STEPS as u64, "seat {seat} must finish its budget");
        assert!(
            states_equal(state, completed_state(&clean.ends, seat)),
            "seat {seat} diverged from the clean fleet"
        );
    }

    // the final global router set: each shard authoritative for its own
    // block, independent of the partition schedule
    assert_eq!(routers.len(), 6);
    for s in 0..3 {
        let expect = shard_block(&plan, s, ROUNDS);
        for (i, &seat) in plan.members(s).iter().enumerate() {
            assert_eq!(routers[seat].params, expect[i].params, "router seat {seat}");
            assert_eq!(routers[seat].step, ROUNDS);
            assert_eq!(clean_routers[seat].params, expect[i].params);
        }
    }
}

// ---------------------------------------------------------------------
// exact intra/inter-shard byte audit
// ---------------------------------------------------------------------

/// Every cross-shard byte reconciles in closed form: 16 boundary
/// publishes of one 2-router block each (6 per healthy round, 2 per
/// partitioned round), one promotion adoption, two shard-kill
/// re-adoptions — and `CrossShardPublish` traffic exists *only* at
/// EM-round boundaries. Intra-shard bytes are exactly the snapshot
/// broadcasts; intra + inter partitions the total.
#[test]
fn cross_shard_byte_audit_reconciles_exactly() {
    let plan = ShardPlan::partition(6, 3).unwrap();
    let backend = ChaosStub::new(6, 4);
    let seeds = seat_seeds(6);
    let fleet = ElasticPlan {
        faults: FaultPlan::from_json_str(chaos_spec()).unwrap(),
        ..ElasticPlan::default()
    };
    let (report, _) = fleet_run(
        &backend,
        &plan,
        &seeds,
        &base_cfg("audit"),
        &fleet,
        |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
            for round in 1..=ROUNDS {
                ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
            }
            Ok(shard_block(&plan, s, ROUNDS))
        },
    )
    .unwrap();
    let ledger = &report.ledger;
    let block = (2 * P * 4) as u64; // one 2-member router block on the wire

    // boundary publishes: rounds 1 and 4 have all 3 shards live (each
    // reads 2 foreign blocks -> 6 events); rounds 2 and 3 cut shard 1
    // (shards 0 and 2 read each other -> 2 events)
    let cross: Vec<_> = ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::CrossShardPublish)
        .collect();
    assert_eq!(cross.len(), 16);
    let per_round = |r: u64| cross.iter().filter(|e| e.step == r).count();
    assert_eq!(per_round(1), 6);
    assert_eq!(per_round(2), 2, "the cut shard neither sends nor receives");
    assert_eq!(per_round(3), 2);
    assert_eq!(per_round(4), 6, "the healed shard rejoins the exchange");
    for e in &cross {
        assert_eq!(e.bytes_sent, block);
        assert_eq!(e.bytes_received, block);
        assert!(
            (1..=ROUNDS).contains(&e.step),
            "cross-shard publish outside an EM-round boundary (step {})",
            e.step
        );
    }
    assert_eq!(ledger.kind_bytes(CommKind::CrossShardPublish), 16 * block);

    // partition heal: at round 4 exactly four edges carry a held view
    // that is 2 rounds stale (0<-1, 2<-1, 1<-0, 1<-2); every other
    // publish is fresh
    assert_eq!(
        cross.iter().filter(|e| e.staleness == 2 && e.step == 4).count(),
        4,
        "the heal must audit the rounds missed as staleness"
    );
    assert!(cross.iter().all(|e| e.staleness == 2 || e.staleness == 0));

    // receipts land on the *current* leader seat: shard 1 receives on
    // seat 2 before the promotion, on seat 3 after
    assert!(cross.iter().any(|e| e.node == 2 && e.step == 1));
    assert!(cross.iter().any(|e| e.node == 3 && e.step == 4));
    assert!(cross.iter().all(|e| [0, 2, 3, 4].contains(&e.node)));

    // ShardAdopt: one promotion (the dead leader's block, at its round)
    // plus two whole-shard recoveries (the step-6 member checkpoints)
    let adopts: Vec<_> = ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::ShardAdopt)
        .collect();
    assert_eq!(adopts.len(), 3);
    let promo: Vec<_> = adopts.iter().filter(|e| e.node == 3).collect();
    assert_eq!(promo.len(), 1, "the promoted member adopts the leader block");
    assert_eq!(promo[0].step, 2);
    assert_eq!(promo[0].bytes_sent, block);
    let rescue: Vec<_> = adopts
        .iter()
        .filter(|e| e.node == 4 || e.node == 5)
        .collect();
    assert_eq!(rescue.len(), 2, "every seat of the killed shard re-adopts");
    for e in &rescue {
        assert_eq!(e.step, 6, "re-adoption resumes the step-6 checkpoint");
        assert!(e.bytes_sent > 0, "the checkpoint file crosses the boundary");
    }

    // no in-shard adoption traffic: the only kills were the shard kill,
    // audited as fault-domain crossings
    assert_eq!(ledger.kind_bytes(CommKind::CheckpointAdopt), 0);

    // intra-shard traffic is exactly the snapshot broadcasts: 3 shards x
    // 4 rounds x 2 subscribers x the full 6-router global set
    let payload = (6 * P * 4) as u64;
    assert_eq!(ledger.kind_bytes(CommKind::SnapshotBroadcast), 3 * 4 * 2 * payload);
    assert_eq!(ledger.intra_shard_bytes(), 3 * 4 * 2 * payload);

    // the split reconciles and partitions the total exactly
    assert_eq!(
        ledger.inter_shard_bytes(),
        ledger.kind_bytes(CommKind::CrossShardPublish) + ledger.kind_bytes(CommKind::ShardAdopt)
    );
    assert_eq!(
        ledger.intra_shard_bytes() + ledger.inter_shard_bytes(),
        ledger.total_bytes()
    );

    // publisher pseudo-nodes sit past every real seat, one per shard
    let publishers: std::collections::BTreeSet<usize> = ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::SnapshotBroadcast && e.bytes_received == 0)
        .map(|e| e.node)
        .collect();
    assert_eq!(publishers, [6, 7, 8].into_iter().collect());
}

// ---------------------------------------------------------------------
// generated sharded plan: JSON export + bit-identical replay
// ---------------------------------------------------------------------

/// A seeded, *generated* plan with shard clauses, exported to JSON and
/// replayed through two fresh fleets, produces bit-identical states,
/// identical fault counts and identical byte totals — the `--chaos-spec`
/// determinism contract extended to shard faults. Event *order* in the
/// ledger is scheduling-dependent and deliberately not compared.
#[test]
fn generated_shard_spec_replays_bit_identically() {
    const R: u64 = 3;
    let plan = ShardPlan::partition(4, 2).unwrap();
    let backend = ChaosStub::new(4, 4);
    let seeds = seat_seeds(4);
    let shape = PlanShape {
        nodes: 4,
        steps_per_node: 10,
        kills: 1,
        transients: 1,
        snapshot_versions: 3,
        shards: 2,
        partitions: 1,
        leader_losses: 1,
        shard_kills: 1,
        em_rounds: R,
        ..PlanShape::default()
    };
    let text = FaultPlan::generate(31, &shape).to_json().to_string_pretty();

    let run = |tag: &str| {
        let fleet = ElasticPlan {
            faults: FaultPlan::from_json_str(&text).unwrap(),
            ..ElasticPlan::default()
        };
        let cfg = NodeRunConfig {
            steps_per_node: 10,
            checkpoint_every: 2,
            checkpoint_dir: Some(temp_dir(tag)),
            threads: 2,
            snapshot_wait_us: 10_000_000,
            ..NodeRunConfig::default()
        };
        fleet_run(
            &backend,
            &plan,
            &seeds,
            &cfg,
            &fleet,
            |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
                for round in 1..=R {
                    ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
                }
                Ok(shard_block(&plan, s, R))
            },
        )
        .unwrap()
    };
    let (a, routers_a) = run("replay_a");
    let (b, routers_b) = run("replay_b");

    for seat in 0..4 {
        assert!(
            states_equal(
                completed_state(&a.ends, seat),
                completed_state(&b.ends, seat)
            ),
            "seat {seat} diverged between replays of the same spec"
        );
    }
    for (ra, rb) in routers_a.iter().zip(&routers_b) {
        assert_eq!(ra.params, rb.params);
    }

    // stats replay exactly, modulo the one wall-clock-denominated field
    let mut sa = a.stats.clone();
    let mut sb = b.stats.clone();
    sa.recovery_micros = 0;
    sb.recovery_micros = 0;
    assert_eq!(sa, sb, "replays of one spec must count identical faults");
    assert_eq!(a.shards.len(), b.shards.len());
    for (ra, rb) in a.shards.iter().zip(&b.shards) {
        let mut x = ra.clone();
        let mut y = rb.clone();
        x.stats.recovery_micros = 0;
        y.stats.recovery_micros = 0;
        assert_eq!(x, y, "shard {} rows diverged between replays", ra.shard);
    }

    // byte totals replay exactly (event order may not)
    assert_eq!(a.ledger.total_bytes(), b.ledger.total_bytes());
    assert_eq!(a.ledger.intra_shard_bytes(), b.ledger.intra_shard_bytes());
    assert_eq!(a.ledger.inter_shard_bytes(), b.ledger.inter_shard_bytes());
    assert_eq!(
        a.ledger.kind_bytes(CommKind::CrossShardPublish),
        b.ledger.kind_bytes(CommKind::CrossShardPublish)
    );
    assert_eq!(
        a.ledger.kind_bytes(CommKind::ShardAdopt),
        b.ledger.kind_bytes(CommKind::ShardAdopt)
    );
}

// ---------------------------------------------------------------------
// shard-failure degradation and salvage
// ---------------------------------------------------------------------

/// A shard whose driver crashes mid-run degrades without taking the
/// fleet down: the run returns `Ok`, the dead shard's last exchanged
/// block is salvaged into the final router set, its seats report no
/// ends, and it contributes no cross-shard bytes after death. When
/// *every* shard fails, the run aborts structurally.
#[test]
fn failed_shard_degrades_and_its_last_block_is_salvaged() {
    const R: u64 = 2;
    let plan = ShardPlan::partition(4, 2).unwrap();
    let backend = ChaosStub::new(4, 4);
    let seeds = seat_seeds(4);
    let fleet = ElasticPlan::default();
    let cfg = NodeRunConfig {
        steps_per_node: 6,
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let (report, routers) = fleet_run(
        &backend,
        &plan,
        &seeds,
        &cfg,
        &fleet,
        |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
            for round in 1..=R {
                ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
                if s == 1 {
                    bail!("injected shard driver crash");
                }
            }
            Ok(shard_block(&plan, s, R))
        },
    )
    .unwrap();

    // only the surviving shard reports seats and stats
    assert_eq!(report.ends.len(), 2);
    for seat in 0..2 {
        assert_eq!(completed_state(&report.ends, seat).step, 6);
    }
    assert_eq!(report.stats, ElasticStats::default());
    assert_eq!(report.shards.len(), 2);
    assert_eq!(report.shards[1].stats, ElasticStats::default());

    // salvage: shard 1 deposited its round-1 block before dying — that
    // block is authoritative for its seats in the final router set
    let survivor = shard_block(&plan, 0, R);
    let salvaged = shard_block(&plan, 1, 1);
    for (i, &seat) in plan.members(0).iter().enumerate() {
        assert_eq!(routers[seat].params, survivor[i].params);
    }
    for (i, &seat) in plan.members(1).iter().enumerate() {
        assert_eq!(routers[seat].params, salvaged[i].params, "seat {seat}");
        assert_eq!(routers[seat].step, 1, "salvage must be the round-1 deposit");
    }

    // a dead shard stops producing cross-shard traffic: round 1 swapped
    // two blocks; at round 2 the survivor finds no round-2 deposit
    let cross: Vec<_> = report
        .ledger
        .events
        .iter()
        .filter(|e| e.kind == CommKind::CrossShardPublish)
        .collect();
    assert_eq!(cross.len(), 2);
    assert!(cross.iter().all(|e| e.step == 1));

    // every shard failing is a structured abort, chaining the cause
    let err = match fleet_run(
        &backend,
        &plan,
        &seeds,
        &cfg,
        &fleet,
        |_s: usize, _ctx: &ShardCtx<'_>, _handle: &ElasticHandle<'_, '_>| bail!("boom"),
    ) {
        Ok(_) => panic!("a fleet with every shard failed must abort"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("every fleet shard failed"), "{msg}");
    assert!(msg.contains("boom"), "{msg}");
}

// ---------------------------------------------------------------------
// checkpoint namespacing (regression: flat layout must be gone)
// ---------------------------------------------------------------------

/// Fleet checkpoints land under `<dir>/shard{s}/node{local}.ckpt` — the
/// flat single-fleet layout must NOT appear at the root — and stale
/// `*.tmp` orphans inside shard subdirectories are swept at startup.
#[test]
fn checkpoints_are_namespaced_per_shard_and_temps_swept() {
    const R: u64 = 2;
    let plan = ShardPlan::partition(4, 2).unwrap();
    let backend = ChaosStub::new(4, 4);
    let seeds = seat_seeds(4);
    let root = temp_dir("ns");
    std::fs::create_dir_all(root.join("shard0")).unwrap();
    std::fs::write(root.join("shard0").join("node0.ckpt.tmp"), b"stale").unwrap();
    let cfg = NodeRunConfig {
        steps_per_node: 6,
        checkpoint_every: 2,
        checkpoint_dir: Some(root.clone()),
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    fleet_run(
        &backend,
        &plan,
        &seeds,
        &cfg,
        &ElasticPlan::default(),
        |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
            for round in 1..=R {
                ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
            }
            Ok(shard_block(&plan, s, R))
        },
    )
    .unwrap();

    for s in 0..2 {
        for l in 0..2 {
            let path = root.join(format!("shard{s}")).join(format!("node{l}.ckpt"));
            assert!(path.exists(), "missing namespaced checkpoint {path:?}");
        }
    }
    assert!(
        !root.join("node0.ckpt").exists() && !root.join("node1.ckpt").exists(),
        "fleet checkpoints must not use the flat single-fleet layout"
    );
    assert!(
        !root.join("shard0").join("node0.ckpt.tmp").exists(),
        "stale temp files inside shard subdirectories must be swept"
    );
}

/// Back-compat: a one-shard fleet pointed at a pre-shard flat checkpoint
/// directory resumes those flat files (`legacy_flat_dir` fallback) and
/// finishes bit-identical to an uninterrupted fleet run of the full
/// budget.
#[test]
fn single_shard_fleet_resumes_legacy_flat_checkpoints() {
    const R: u64 = 2;
    let backend = ChaosStub::new(2, 4);
    let seeds = seat_seeds(2);
    let plan = ShardPlan::partition(2, 1).unwrap();
    let base = NodeRunConfig {
        steps_per_node: STEPS,
        checkpoint_every: 3,
        threads: 2,
        draw_budget: 1000, // pinned so the flat leg's draws are resume-exact
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let driver = |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
        for round in 1..=R {
            ctx.round_boundary(handle, round, &shard_block(&plan, s, round))?;
        }
        Ok(shard_block(&plan, s, R))
    };

    // a pre-shard flat elastic run leaves its checkpoints at the root
    let root = temp_dir("legacy");
    let store = SnapshotStore::new(2);
    let b = bpe();
    let factory = move |e: usize, salt: u64| {
        SequenceGen::new(
            b,
            SEQ_LEN,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    let flat_cfg = NodeRunConfig {
        steps_per_node: 6,
        checkpoint_dir: Some(root.clone()),
        ..base.clone()
    };
    run_elastic_nodes(
        &backend,
        &store,
        &seeds,
        factory,
        &flat_cfg,
        &ElasticPlan::default(),
        |h| {
            h.store().publish(shard_block(&plan, 0, 1), 1);
            Ok(())
        },
    )
    .unwrap();
    assert!(root.join("node0.ckpt").exists() && root.join("node1.ckpt").exists());

    // resume as a one-shard fleet: shard0/ holds no checkpoints yet, so
    // the flat files must be picked up through the legacy fallback
    let resume_cfg = NodeRunConfig {
        resume: true,
        checkpoint_dir: Some(root.clone()),
        ..base.clone()
    };
    let (resumed, _) = fleet_run(
        &backend,
        &plan,
        &seeds,
        &resume_cfg,
        &ElasticPlan::default(),
        driver,
    )
    .unwrap();

    // the clean reference trains the full budget from scratch
    let clean_cfg = NodeRunConfig {
        checkpoint_dir: Some(temp_dir("legacy_ref")),
        ..base.clone()
    };
    let (clean, _) = fleet_run(
        &backend,
        &plan,
        &seeds,
        &clean_cfg,
        &ElasticPlan::default(),
        driver,
    )
    .unwrap();

    for seat in 0..2 {
        let r = completed_state(&resumed.ends, seat);
        assert_eq!(r.step, STEPS as u64, "seat {seat} must finish the full budget");
        assert!(
            states_equal(r, completed_state(&clean.ends, seat)),
            "seat {seat} diverged across the legacy flat resume"
        );
    }
}

// ---------------------------------------------------------------------
// orphaned-node error context (shard + node + version attributability)
// ---------------------------------------------------------------------

/// Nodes orphaned on a sharded store (publisher never publishes) fail
/// structurally after `snapshot_wait_us`, and the error chain alone
/// names the shard, the node, and the snapshot version waited on.
#[test]
fn orphaned_fleet_nodes_fail_with_shard_and_node_context() {
    let backend = ChaosStub::new(2, 4);
    let seeds = seat_seeds(2);
    let store = SnapshotStore::new_sharded(2, 3);
    let b = bpe();
    let factory = move |e: usize, salt: u64| {
        SequenceGen::new(
            b,
            SEQ_LEN,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    let cfg = NodeRunConfig {
        steps_per_node: 4,
        threads: 2,
        snapshot_wait_us: 50_000,
        ..NodeRunConfig::default()
    };
    let err = match run_elastic_nodes(
        &backend,
        &store,
        &seeds,
        factory,
        &cfg,
        &ElasticPlan::default(),
        |_h| {
            // the silent publisher: outlive every node's orphan valve
            std::thread::sleep(Duration::from_millis(300));
            Ok(())
        },
    ) {
        Ok(_) => panic!("orphaned nodes must fail the run"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("every trainer node failed"), "{msg}");
    assert!(msg.contains("timed out"), "{msg}");
    assert!(msg.contains("shard 3"), "{msg}");
    assert!(msg.contains("node "), "{msg}");
    assert!(msg.contains("version >= 1"), "{msg}");
    assert!(msg.contains("orphaned"), "{msg}");
}

// ---------------------------------------------------------------------
// FaultPlan JSON surface: property tests
// ---------------------------------------------------------------------

/// `generate -> to_json -> from_json_str` is the identity on every fault
/// section (shard clauses included) over random seeds and shapes, and a
/// second serialization is byte-stable.
#[test]
fn fault_plan_json_roundtrips_over_random_shapes() {
    prop::check(
        "fault-plan-json-roundtrip",
        120,
        |r| {
            let shape = PlanShape {
                nodes: 1 + r.usize_below(6),
                steps_per_node: 2 + r.below(20),
                kills: r.usize_below(4),
                transients: r.usize_below(3),
                stalls: r.usize_below(3),
                drops: r.usize_below(3),
                publish_gates: r.usize_below(3),
                snapshot_versions: 1 + r.below(4),
                shards: 1 + r.usize_below(4),
                partitions: r.usize_below(4),
                leader_losses: r.usize_below(3),
                shard_kills: r.usize_below(3),
                em_rounds: 1 + r.below(6),
            };
            (r.below(1 << 32), shape)
        },
        |&(seed, shape)| {
            let p = FaultPlan::generate(seed, &shape);
            let text = p.to_json().to_string_pretty();
            let q = FaultPlan::from_json_str(&text)
                .map_err(|e| format!("reparse failed: {e:#}"))?;
            if p.seed != q.seed {
                return Err("seed drifted".into());
            }
            if p.kills != q.kills
                || p.transients != q.transients
                || p.stalls != q.stalls
                || p.drops != q.drops
                || p.publish_gates != q.publish_gates
            {
                return Err("node-fault sections drifted".into());
            }
            if p.partitions != q.partitions
                || p.leader_losses != q.leader_losses
                || p.shard_kills != q.shard_kills
            {
                return Err("shard-fault sections drifted".into());
            }
            if q.to_json().to_string() != p.to_json().to_string() {
                return Err("second serialization differs".into());
            }
            Ok(())
        },
    );
}

/// Malformed specs — wrong top-level shape, non-array sections, missing
/// or negative or mistyped fields, truncations, corruptions, garbage —
/// always produce a structured `chaos spec` error and never panic.
#[test]
fn malformed_chaos_specs_error_structurally_never_panic() {
    for bad in [
        "",
        "not json",
        "[1, 2, 3]",
        "42",
        "\"kills\"",
        r#"{"kills": 3}"#,
        r#"{"kills": [{"node": 0}]}"#,
        r#"{"kills": [{"node": -1, "at_step": 2}]}"#,
        r#"{"transients": [{"node": 0, "at_step": 1}]}"#,
        r#"{"partitions": [{"shard": 0, "from_round": 1}]}"#,
        r#"{"partitions": [{"shard": "x", "from_round": 1, "rounds": 1}]}"#,
        r#"{"leader_losses": [{"shard": 0}]}"#,
        r#"{"shard_kills": [{"shard": 0, "at_step": null}]}"#,
        r#"{"shard_kills": {"shard": 0}}"#,
    ] {
        let err = FaultPlan::from_json_str(bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chaos spec"), "unstructured error for {bad:?}: {msg}");
    }

    // property: random truncations/corruptions of a valid sharded spec
    // parse to Ok or a structured error — never a panic
    let shape = PlanShape {
        nodes: 3,
        steps_per_node: 9,
        kills: 2,
        transients: 1,
        stalls: 1,
        drops: 1,
        publish_gates: 1,
        snapshot_versions: 2,
        shards: 2,
        partitions: 1,
        leader_losses: 1,
        shard_kills: 1,
        em_rounds: 3,
    };
    prop::check(
        "chaos-spec-corruption-is-structured",
        200,
        move |r| {
            let text = FaultPlan::generate(r.below(64), &shape)
                .to_json()
                .to_string_pretty();
            match r.below(3) {
                0 => text[..r.usize_below(text.len())].to_string(),
                1 => {
                    let mut bytes = text.into_bytes();
                    let i = r.usize_below(bytes.len());
                    bytes[i] = 0x20 + r.below(0x5f) as u8;
                    String::from_utf8(bytes).unwrap()
                }
                _ => (0..r.usize_below(40))
                    .map(|_| (0x20 + r.below(0x5f) as u8) as char)
                    .collect(),
            }
        },
        |text| match FaultPlan::from_json_str(text) {
            Ok(_) => Ok(()),
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("chaos spec") {
                    Ok(())
                } else {
                    Err(format!("unstructured error: {msg}"))
                }
            }
        },
    );
}
