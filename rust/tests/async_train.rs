//! Orchestrator suite for the async-trainer refactor (`make test-async`).
//!
//! Two tiers, following the server/fused-scoring suites' pattern:
//!
//! * **tier-1 (stub backend, no artifacts):** the node machinery itself —
//!   staged mode bit-identical across worker counts and to an inline
//!   classic-loop reference; kill-and-resume (staged and async) matching
//!   an uninterrupted run bit-for-bit, including the exact stream
//!   position; stale-snapshot routing converging onto a refresh without
//!   ever blocking a node; comm-ledger byte totals exact; node-checkpoint
//!   roundtrip as a property test.
//! * **artifacts-gated (standard self-skip):** the new staged
//!   orchestrator reproducing the classic `run_pipeline_reference`
//!   bit-identically (mixture params, ledger totals, full log series) at
//!   threads {1, E}, and an engine-backed async end-to-end smoke run.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use smalltalk::coordinator::expert::segment_batch;
use smalltalk::coordinator::{
    run_async_nodes, run_pipeline, run_pipeline_reference, run_staged_nodes, run_trainer,
    CommKind, NodeRunConfig, PipelineConfig, RouterSnapshot, SnapshotStore, TrainBackend,
    TrainerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::{Sequence, SequenceGen};
use smalltalk::metrics::RunLog;
use smalltalk::model::{load_node_checkpoint, save_node_checkpoint, NodeCheckpointView};
use smalltalk::runtime::{locate_artifacts, Engine, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};
use smalltalk::util::prop;
use smalltalk::util::rng::Rng;

// ---------------------------------------------------------------------
// shared fixtures
// ---------------------------------------------------------------------

/// Stub expert parameter count.
const P: usize = 6;
/// Stub stream sequence length (tokens per sequence = SEQ_LEN + 1).
const SEQ_LEN: usize = 16;

static BPE: OnceLock<Bpe> = OnceLock::new();

/// One tokenizer per test binary (same corpus/vocab as the integration
/// suite, so the artifacts-gated tests match the compiled manifest).
fn bpe() -> &'static Bpe {
    BPE.get_or_init(|| {
        let corpus = Corpus::generate(60, 400, 42, None);
        BpeTrainer::new(512).train(corpus.texts()).unwrap()
    })
}

fn temp_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let d = std::env::temp_dir().join(format!(
        "smalltalk_async_train_{tag}_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn states_equal(a: &TrainState, b: &TrainState) -> bool {
    a.params == b.params && a.m == b.m && a.v == b.v && a.step == b.step
}

/// Deterministic model-free backend: training folds the batch tokens into
/// the state with pure arithmetic, routing keys on (token sum + snapshot
/// version) so a refreshed snapshot visibly changes the partition.
/// Optionally injects a crash at a specific (node, step) to simulate a
/// killed node.
struct StubBackend {
    n: usize,
    bs: usize,
    fail_at: Option<(usize, u64)>,
}

impl StubBackend {
    fn new(n: usize, bs: usize) -> Self {
        StubBackend {
            n,
            bs,
            fail_at: None,
        }
    }
}

impl TrainBackend for StubBackend {
    fn train_batch_rows(&self) -> usize {
        self.bs
    }

    fn tokens_per_step(&self) -> usize {
        self.bs * SEQ_LEN
    }

    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState> {
        let params: Vec<f32> = (0..P)
            .map(|i| (seed % 1000) as f32 * 1e-3 + node as f32 + i as f32 * 0.1)
            .collect();
        Ok(TrainState::from_params(
            "stub",
            params,
            vec![0.0; P],
            vec![0.0; P],
            0,
        ))
    }

    fn train_step(&self, node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        if let Some((fail_node, at)) = self.fail_at {
            if node == fail_node && state.step >= at {
                bail!("injected crash at node {node} step {}", state.step);
            }
        }
        let mut acc = 0.0f32;
        for row in batch {
            for &t in *row {
                acc += (t % 97) as f32;
            }
        }
        let loss = acc / (batch.len().max(1) as f32 * 100.0);
        for i in 0..state.params.len() {
            let g = loss * 1e-3 + (i as f32 + 1.0) * 1e-4;
            state.m[i] = 0.9 * state.m[i] + 0.1 * g;
            state.v[i] = 0.99 * state.v[i] + 0.01 * g * g;
            state.params[i] -= 0.1 * state.m[i];
        }
        state.step += 1;
        Ok(loss)
    }

    fn route_local(&self, snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| {
                let sum: u64 = r.iter().map(|&t| t as u64).sum();
                ((sum + snap.version) % self.n as u64) as usize
            })
            .collect())
    }
}

/// Hand-built staged segment (no tokenizer needed).
fn segment(node: usize, len: usize) -> Vec<Sequence> {
    (0..len)
        .map(|i| Sequence {
            tokens: (0..SEQ_LEN as u32 + 1)
                .map(|t| (node as u32 * 131 + i as u32 * 17 + t) % 251)
                .collect(),
            domain: (node + i) % 8,
        })
        .collect()
}

fn async_jobs<'a>(bpe: &'a Bpe, n: usize) -> Vec<(u64, SequenceGen<'a>)> {
    (0..n)
        .map(|e| {
            (
                0xE0 + e as u64,
                SequenceGen::new(bpe, SEQ_LEN, 0xA5_0000 + e as u64),
            )
        })
        .collect()
}

fn publish_once(store: &SnapshotStore) -> u64 {
    let router = TrainState::from_params(
        "stub_router",
        vec![0.5; P],
        vec![0.0; P],
        vec![0.0; P],
        0,
    );
    store.publish(vec![router], 1)
}

// ---------------------------------------------------------------------
// tier-1: staged mode
// ---------------------------------------------------------------------

/// Staged node outcomes are bit-identical at any worker count and equal
/// to an inline transcription of the classic expert loop (same batch
/// cycling, same logging cadence).
#[test]
fn staged_nodes_bit_identical_across_thread_counts_and_reference() {
    let backend = StubBackend::new(3, 4);
    let steps = 11usize;
    let jobs =
        || -> Vec<(u64, Vec<Sequence>)> { (0..3).map(|e| (0xE0 + e as u64, segment(e, 9))).collect() };

    // inline reference: the classic train_expert_continue loop
    let reference: Vec<(TrainState, RunLog)> = jobs()
        .into_iter()
        .enumerate()
        .map(|(e, (seed, seg))| {
            let mut log = RunLog::new();
            let mut state = backend.init_expert(e, seed).unwrap();
            let mut cursor = 0u64;
            for step in 0..steps {
                let batch = segment_batch(&seg, &mut cursor, 4);
                let loss = backend.train_step(e, &mut state, &batch).unwrap();
                if step % 10 == 0 || step + 1 == steps {
                    log.scalar("loss", state.step as f64, loss as f64);
                    log.scalar(
                        "tokens",
                        (state.step as usize * backend.tokens_per_step()) as f64,
                        loss as f64,
                    );
                }
            }
            (state, log)
        })
        .collect();

    for threads in [1usize, 2, 8] {
        let cfg = NodeRunConfig {
            steps_per_node: steps,
            threads,
            ..NodeRunConfig::default()
        };
        let outcomes = run_staged_nodes(&backend, jobs(), &cfg).unwrap();
        assert_eq!(outcomes.len(), 3);
        for (o, (ref_state, ref_log)) in outcomes.iter().zip(&reference) {
            assert!(
                states_equal(&o.state, ref_state),
                "threads={threads}: node {} state diverged from the classic loop",
                o.node
            );
            assert_eq!(
                o.log.series, ref_log.series,
                "threads={threads}: node {} log diverged",
                o.node
            );
            assert_eq!(o.steps_done, steps);
        }
    }
}

#[test]
fn staged_empty_segment_is_structured_error() {
    let backend = StubBackend::new(2, 4);
    let cfg = NodeRunConfig {
        steps_per_node: 3,
        threads: 1,
        ..NodeRunConfig::default()
    };
    let err = run_staged_nodes(&backend, vec![(1, segment(0, 5)), (2, vec![])], &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("cannot train on an empty segment"), "{msg}");
    assert!(msg.contains("node 1"), "{msg}");
}

/// Kill a staged node mid-run (injected crash), then resume from the
/// checkpoints: the final states match an uninterrupted run bit-for-bit.
#[test]
fn staged_kill_and_resume_matches_uninterrupted() {
    let steps = 12usize;
    let jobs =
        || -> Vec<(u64, Vec<Sequence>)> { (0..2).map(|e| (7 + e as u64, segment(e, 8))).collect() };
    let clean = StubBackend::new(2, 4);
    let base = NodeRunConfig {
        steps_per_node: steps,
        threads: 2,
        ..NodeRunConfig::default()
    };
    let reference = run_staged_nodes(&clean, jobs(), &base).unwrap();

    let dir = temp_dir("staged_resume");
    let ck = NodeRunConfig {
        checkpoint_every: 3,
        checkpoint_dir: Some(dir.clone()),
        ..base.clone()
    };
    let failing = StubBackend {
        fail_at: Some((1, 7)),
        ..StubBackend::new(2, 4)
    };
    let err = run_staged_nodes(&failing, jobs(), &ck).unwrap_err();
    assert!(format!("{err:#}").contains("injected crash"), "{err:#}");

    let resume = NodeRunConfig {
        resume: true,
        ..ck.clone()
    };
    let resumed = run_staged_nodes(&clean, jobs(), &resume).unwrap();
    for (a, b) in reference.iter().zip(&resumed) {
        assert!(
            states_equal(&a.state, &b.state),
            "node {} diverged after resume",
            a.node
        );
        assert_eq!(a.steps_done, b.steps_done);
    }
}

// ---------------------------------------------------------------------
// tier-1: async mode
// ---------------------------------------------------------------------

/// The acceptance property: an async run killed mid-flight and resumed
/// from its node checkpoints produces the same trained experts as an
/// uninterrupted async run — same parameters and Adam moments, same
/// stream positions (drawn), same routed-keep counts, same domain
/// histograms. Holds for *any* kill timing because each checkpoint
/// captures the node's full continuation state.
#[test]
fn async_kill_and_resume_matches_uninterrupted() {
    let b = bpe();
    let n = 3usize;
    let steps = 6usize;
    let clean = StubBackend::new(n, 4);
    let base = NodeRunConfig {
        steps_per_node: steps,
        threads: 2,
        route_chunk: 8,
        ..NodeRunConfig::default()
    };

    // reference: uninterrupted async run under a fixed snapshot (v1)
    let store_a = SnapshotStore::new(n);
    let (ref_out, ()) = run_async_nodes(&clean, &store_a, async_jobs(b, n), &base, |_h| {
        publish_once(&store_a);
        Ok(())
    })
    .unwrap();

    // interrupted: node 2 crashes after its 4th step; checkpoints every 2
    let dir = temp_dir("async_resume");
    let ck = NodeRunConfig {
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.clone()),
        ..base.clone()
    };
    let failing = StubBackend {
        fail_at: Some((2, 3)),
        ..StubBackend::new(n, 4)
    };
    let store_b = SnapshotStore::new(n);
    let err = run_async_nodes(&failing, &store_b, async_jobs(b, n), &ck, |_h| {
        publish_once(&store_b);
        Ok(())
    })
    .unwrap_err();
    assert!(format!("{err:#}").contains("injected crash"), "{err:#}");

    // resume with a clean backend: bit-identical continuation
    let resume = NodeRunConfig {
        resume: true,
        ..ck.clone()
    };
    let store_c = SnapshotStore::new(n);
    let (res_out, ()) = run_async_nodes(&clean, &store_c, async_jobs(b, n), &resume, |_h| {
        publish_once(&store_c);
        Ok(())
    })
    .unwrap();

    assert_eq!(ref_out.len(), res_out.len());
    for (a, r) in ref_out.iter().zip(&res_out) {
        assert!(
            states_equal(&a.state, &r.state),
            "node {} state diverged after kill-and-resume",
            a.node
        );
        assert_eq!(a.steps_done, r.steps_done, "node {}", a.node);
        assert_eq!(a.drawn, r.drawn, "node {} stream position diverged", a.node);
        assert_eq!(a.kept, r.kept, "node {}", a.node);
        assert_eq!(a.domain_counts, r.domain_counts, "node {}", a.node);
        assert_eq!(a.snapshot_version, 1);
        assert_eq!(r.snapshot_version, 1);
        assert_eq!(a.steps_done, steps, "node {} fell short of its budget", a.node);
    }
}

/// Nodes make progress under a stale snapshot, pick a refresh up without
/// blocking, and the broadcast ledger records exactly the published
/// snapshots with exact byte totals.
#[test]
fn stale_snapshot_routing_converges_onto_refresh() {
    let b = bpe();
    let n = 2usize;
    let steps = 16usize;
    let backend = StubBackend::new(n, 4);
    let cfg = NodeRunConfig {
        steps_per_node: steps,
        threads: 2,
        route_chunk: 8,
        ..NodeRunConfig::default()
    };
    let store = SnapshotStore::new(n);
    let router =
        || TrainState::from_params("stub_router", vec![0.1; P], vec![0.0; P], vec![0.0; P], 0);

    let (outcomes, seen_before_refresh) =
        run_async_nodes(&backend, &store, async_jobs(b, n), &cfg, |h| {
            store.publish(vec![router()], 1);
            // wait until the nodes demonstrably trained under v1 ...
            let t0 = Instant::now();
            while h.total_steps_done() < 2 {
                if h.failed() {
                    bail!("run failed while the driver waited for progress");
                }
                if t0.elapsed() > Duration::from_secs(60) {
                    bail!("nodes made no progress under the stale snapshot");
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            let seen = h.total_steps_done();
            // ... then refresh; nodes must converge onto v2
            store.publish(vec![router()], 2);
            Ok(seen)
        })
        .unwrap();

    assert!(seen_before_refresh >= 2, "driver observed {seen_before_refresh}");
    for o in &outcomes {
        assert_eq!(o.steps_done, steps, "node {} starved", o.node);
        assert_eq!(
            o.snapshot_version, 2,
            "node {} never picked up the refreshed snapshot",
            o.node
        );
        assert!(o.kept >= (steps * 4) as u64, "node {} kept too few", o.node);
    }

    // ledger: exactly 2 broadcasts; the publisher sent the full router
    // parameter set (P f32s) to each of the n nodes per publish
    let ledger = store.take_ledger();
    assert_eq!(ledger.rounds(CommKind::SnapshotBroadcast), 2);
    let per_subscriber = (P * 4) as u64;
    assert_eq!(ledger.total_bytes(), 2 * n as u64 * per_subscriber);
    let totals = ledger.totals_per_node();
    assert_eq!(totals[&n].bytes_sent, 2 * n as u64 * per_subscriber);
    for node in 0..n {
        assert_eq!(totals[&node].bytes_received, 2 * per_subscriber);
    }
}

/// A router driver that exits without ever publishing fails the run with
/// a structured error instead of deadlocking the waiting nodes.
#[test]
fn driver_without_snapshot_fails_cleanly() {
    let b = bpe();
    let backend = StubBackend::new(2, 4);
    let cfg = NodeRunConfig {
        steps_per_node: 3,
        threads: 2,
        ..NodeRunConfig::default()
    };
    let store = SnapshotStore::new(2);
    let err = run_async_nodes(&backend, &store, async_jobs(b, 2), &cfg, |_h| Ok(()))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("closed before any router snapshot"), "{msg}");
}

/// A draw budget too small to fill the step budget finishes the node
/// early and deterministically (exhausted flag), rather than spinning.
#[test]
fn draw_budget_exhaustion_finishes_early() {
    let b = bpe();
    let n = 2usize;
    let backend = StubBackend::new(n, 4);
    let cfg = NodeRunConfig {
        steps_per_node: 1000,
        threads: 2,
        route_chunk: 8,
        draw_budget: 40,
        ..NodeRunConfig::default()
    };
    let store = SnapshotStore::new(n);
    let (outcomes, ()) = run_async_nodes(&backend, &store, async_jobs(b, n), &cfg, |_h| {
        publish_once(&store);
        Ok(())
    })
    .unwrap();
    for o in &outcomes {
        assert!(o.exhausted, "node {} should have exhausted its budget", o.node);
        assert_eq!(o.drawn, 40, "node {} overdrew its budget", o.node);
        assert!(o.steps_done < 1000);
        assert!(
            o.log.get("stream_exhausted").is_some(),
            "node {} did not log exhaustion",
            o.node
        );
    }
}

// ---------------------------------------------------------------------
// tier-1: node-checkpoint property test
// ---------------------------------------------------------------------

#[derive(Debug)]
struct CkptCase {
    params: Vec<f32>,
    pool_lens: Vec<usize>,
    steps: u64,
    drawn: u64,
}

#[test]
fn node_checkpoint_roundtrip_property() {
    let dir = temp_dir("ckpt_prop");
    let mut case_no = 0usize;
    prop::check(
        "node-checkpoint-roundtrip",
        40,
        |rng: &mut Rng| CkptCase {
            params: (0..1 + rng.usize_below(40)).map(|_| rng.f32() * 8.0 - 4.0).collect(),
            pool_lens: (0..rng.usize_below(5)).map(|_| 1 + rng.usize_below(20)).collect(),
            steps: rng.below(1 << 40),
            drawn: rng.below(1 << 40),
        },
        |case| {
            case_no += 1;
            let nf = case.params.len();
            let state = TrainState::from_params(
                "prop_variant",
                case.params.clone(),
                case.params.iter().map(|x| x * 0.5).collect(),
                case.params.iter().map(|x| x * x).collect(),
                case.steps,
            );
            let pool: Vec<Sequence> = case
                .pool_lens
                .iter()
                .enumerate()
                .map(|(i, &len)| Sequence {
                    tokens: (0..len as u32).map(|t| t * 3 + i as u32).collect(),
                    domain: i % 8,
                })
                .collect();
            let counts: Vec<u64> = (0..8).map(|i| case.drawn.wrapping_add(i) % 1000).collect();
            let stream = smalltalk::data::StreamPos {
                rng: [case.steps, case.drawn, 3, 4],
                doc_bytes: nf as u64,
                drawn: case.drawn,
            };
            let view = NodeCheckpointView {
                node: (case_no % 7) as u32,
                mode: 1,
                steps_done: case.steps,
                cursor: 0,
                stream: Some(stream),
                pool: &pool,
                domain_counts: &counts,
                drawn: case.drawn,
                kept: case.drawn / 2,
                snapshot_version: 3,
                state: &state,
            };
            let path = dir.join(format!("case{case_no}.ckpt"));
            save_node_checkpoint(&view, &path).map_err(|e| e.to_string())?;
            let loaded = load_node_checkpoint(&path).map_err(|e| e.to_string())?;
            if loaded.state.params.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
                != state.params.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            {
                return Err("params not bit-identical".into());
            }
            if loaded.state.m != state.m || loaded.state.v != state.v {
                return Err("moments diverged".into());
            }
            if loaded.stream != Some(stream) {
                return Err("stream position diverged".into());
            }
            if loaded.pool.len() != pool.len()
                || loaded
                    .pool
                    .iter()
                    .zip(&pool)
                    .any(|(a, b)| a.tokens != b.tokens || a.domain != b.domain)
            {
                return Err("pool diverged".into());
            }
            if loaded.domain_counts != counts || loaded.drawn != case.drawn {
                return Err("counters diverged".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// artifacts-gated: the staged orchestrator vs the classic pipeline
// ---------------------------------------------------------------------

/// XLA-backed tests skip (not fail) without compiled artifacts.
fn engine() -> Option<Engine> {
    let dir = locate_artifacts()?;
    Some(Engine::new(dir).expect("loading artifacts"))
}

fn tiny_pipeline(threads: usize) -> PipelineConfig {
    PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "router_micro".into(), // tiny expert: fast test
        n_experts: 2,
        em_rounds: 2,
        em_chunk: 48,
        em_steps_per_round: 4,
        shard_sequences: 64,
        expert_steps: 6,
        prefix_len: 32,
        seed: 11,
        threads,
    }
}

/// The acceptance criterion: staged mode reproduces the classic
/// pipeline's outputs bit-identically — mixture params, ledger totals,
/// and the full log series — at threads {1, E}.
#[test]
fn staged_pipeline_bit_identical_to_classic_reference() {
    if engine().is_none() {
        return;
    }
    let b = bpe();
    for threads in [1usize, 2] {
        let cfg = tiny_pipeline(threads);
        // fresh engines per run: engine-lifetime transfer stats land in
        // the log, so a shared engine would trivially diverge
        let eng_a = engine().unwrap();
        let reference = run_pipeline_reference(&eng_a, b, &cfg).unwrap();
        let eng_b = engine().unwrap();
        let staged = run_pipeline(&eng_b, b, &cfg).unwrap();

        assert_eq!(reference.mixture.routers.len(), staged.mixture.routers.len());
        for (x, y) in reference.mixture.routers.iter().zip(&staged.mixture.routers) {
            assert_eq!(x.params, y.params, "threads={threads}: router params diverged");
        }
        assert_eq!(reference.mixture.experts.len(), staged.mixture.experts.len());
        for (x, y) in reference.mixture.experts.iter().zip(&staged.mixture.experts) {
            assert!(states_equal(x, y), "threads={threads}: expert diverged");
        }
        assert_eq!(reference.ledger.events.len(), staged.ledger.events.len());
        assert_eq!(reference.ledger.total_bytes(), staged.ledger.total_bytes());
        assert_eq!(
            reference.ledger.peak_node_bytes(),
            staged.ledger.peak_node_bytes()
        );
        assert_eq!(
            reference.ledger.rounds(CommKind::ScoreAllGather),
            staged.ledger.rounds(CommKind::ScoreAllGather)
        );
        assert_eq!(
            reference.log.series, staged.log.series,
            "threads={threads}: log series diverged"
        );
        assert_eq!(reference.segment_sizes, staged.segment_sizes);
        assert_eq!(reference.segment_purity, staged.segment_purity);
    }
}

/// Engine-backed async smoke: the barrier-free orchestrator trains a
/// mixture end to end, its ledger holds snapshot broadcasts *only* (no
/// corpus-wide score all-gather), and checkpoints let it resume.
#[test]
fn async_trainer_end_to_end_with_engine() {
    let Some(eng) = engine() else { return };
    let b = bpe();
    let cfg = tiny_pipeline(2);
    let dir = temp_dir("engine_async");
    let mut t = TrainerConfig::asynchronous();
    t.checkpoint_dir = Some(dir.clone());
    t.checkpoint_every = 2;
    let result = run_trainer(&eng, b, &cfg, &t).unwrap();

    assert_eq!(result.mixture.experts.len(), cfg.n_experts);
    assert!(
        result.mixture.experts.iter().any(|x| x.step > 0),
        "no expert trained at all"
    );
    assert!(result.ledger.rounds(CommKind::SnapshotBroadcast) >= 1);
    assert_eq!(result.ledger.rounds(CommKind::ScoreAllGather), 0);
    assert!(result
        .ledger
        .events
        .iter()
        .all(|ev| ev.kind == CommKind::SnapshotBroadcast));
    // node checkpoints exist and resuming the finished run is a no-op
    // that reproduces the same experts
    for e in 0..cfg.n_experts {
        assert!(dir.join(format!("node{e}.ckpt")).exists(), "node {e} checkpoint missing");
    }
    let mut t2 = t.clone();
    t2.resume = true;
    let resumed = run_trainer(&eng, b, &cfg, &t2).unwrap();
    for (x, y) in result.mixture.experts.iter().zip(&resumed.mixture.experts) {
        assert_eq!(x.step, y.step);
        assert_eq!(x.params, y.params, "resumed no-op changed expert params");
    }
}
