//! API-compatible stub of the `xla` crate (PJRT bindings).
//!
//! The build environment has no `xla_extension` native library, so this
//! vendored crate keeps the workspace compiling and keeps every *host-side*
//! operation real: literals store typed data, reshape validates element
//! counts, buffers hold uploaded literals, and `to_literal_sync` round-trips
//! them. The two operations that need the native runtime — `compile` and
//! `execute_b` — return a descriptive error instead. Code paths that gate on
//! the presence of `artifacts/manifest.json` (tests, benches) therefore skip
//! cleanly on machines without the real backend, and swapping this crate for
//! the real `xla` dependency requires no source changes upstream.
//!
//! Errors are `String` so callers can `.map_err(anyhow::Error::msg)` exactly
//! as with the real crate's error type.

use std::borrow::Borrow;
use std::path::Path;

pub type Error = String;
pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------

/// Element types the workspace uses. The sealed trait maps Rust scalars to
/// typed storage, mirroring the real crate's `NativeType`.
pub trait NativeType: Copy + Sized {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(storage: &Storage) -> Option<&[Self]>;
    const DTYPE: &'static str;
}

#[derive(Clone, Debug, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

macro_rules! native {
    ($ty:ty, $variant:ident, $name:literal) => {
        impl NativeType for $ty {
            fn wrap(data: Vec<Self>) -> Storage {
                Storage::$variant(data)
            }
            fn unwrap(storage: &Storage) -> Option<&[Self]> {
                match storage {
                    Storage::$variant(v) => Some(v),
                    _ => None,
                }
            }
            const DTYPE: &'static str = $name;
        }
    };
}

native!(f32, F32, "f32");
native!(i32, I32, "i32");
native!(u32, U32, "u32");

/// A host-side typed array (or tuple of arrays) with a shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(xs: &[T]) -> Literal {
        Literal {
            storage: T::wrap(xs.to_vec()),
            dims: vec![xs.len() as i64],
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal {
            storage: T::wrap(vec![x]),
            dims: vec![],
        }
    }

    /// Tuple literal (what jax entry points return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            storage: Storage::Tuple(elems),
            dims: vec![],
        }
    }

    /// Total element count (summed over tuple members).
    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::U32(v) => v.len(),
            Storage::Tuple(t) => t.iter().map(Literal::element_count).sum(),
        }
    }

    /// Reinterpret the shape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err("reshape: cannot reshape a tuple literal".into());
        }
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(format!(
                "reshape: {:?} has {} elements, target shape {:?} wants {}",
                self.dims,
                self.element_count(),
                dims,
                want
            ));
        }
        Ok(Literal {
            storage: self.storage.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out the data as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .map(<[T]>::to_vec)
            .ok_or_else(|| format!("to_vec: literal is not {}", T::DTYPE))
    }

    /// First element of a typed literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.storage)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| format!("get_first_element: empty or not {}", T::DTYPE))
    }

    /// Flatten a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(t) => Ok(t),
            _ => Err("to_tuple: literal is not a tuple".into()),
        }
    }
}

// ---------------------------------------------------------------------
// HLO loading / compilation handles
// ---------------------------------------------------------------------

/// Parsed-HLO handle. The stub stores the text so load errors (missing
/// artifact files) surface exactly like the real crate's.
#[derive(Debug)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("reading {}: {e}", path.as_ref().display()))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

// ---------------------------------------------------------------------
// PJRT client / buffers / executables
// ---------------------------------------------------------------------

const BACKEND_UNAVAILABLE: &str = "xla stub backend: compilation/execution requires the native \
     xla_extension library, which is not present in this build environment \
     (swap rust/vendor/xla for the real `xla` crate to run on hardware)";

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The stub "CPU client" always constructs; only compile/execute fail.
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(BACKEND_UNAVAILABLE.into())
    }

    /// Upload a literal to a device buffer. Host-side this is a real copy,
    /// so upload accounting and buffer-reuse logic are fully exercisable
    /// without the native backend.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer {
            literal: literal.clone(),
        })
    }
}

/// A device-resident buffer (stub: host copy of the uploaded literal).
#[derive(Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }

    pub fn element_count(&self) -> usize {
        self.literal.element_count()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with pre-uploaded buffers. Generic over `Borrow` so callers
    /// can pass owned buffers or references (the device-cache path mixes
    /// cached and freshly-uploaded inputs).
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(BACKEND_UNAVAILABLE.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        assert_eq!(lit.element_count(), 6);
        let m = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn tuple_flatten() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[2u32, 3])]);
        assert_eq!(t.element_count(), 3);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn buffers_roundtrip_through_client() {
        let client = PjRtClient::cpu().unwrap();
        let lit = Literal::vec1(&[9u32, 8, 7]);
        let buf = client.buffer_from_host_literal(None, &lit).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap(), lit);
        assert_eq!(buf.element_count(), 3);
    }

    #[test]
    fn execution_reports_backend_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: String::new(),
        });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.contains("xla stub backend"));
    }
}
