//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! implements exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a
//! context chain (outermost first) like the real crate; `{}` prints the
//! outermost message, `{:#}` joins the chain with `": "`, and `{:?}`
//! prints a `Caused by:` listing.

use std::fmt::{self, Debug, Display};

/// An error wrapping a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (mirror of `anyhow::Error::msg`).
    pub fn msg<M: Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line, like real anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: a blanket conversion from std errors. `Error` itself
// deliberately does NOT implement `std::error::Error`, which keeps this
// blanket impl coherent alongside the `Context` impl for `Result<T, Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option` (mirror of `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return ::std::result::Result::Err($crate::Error::msg(format!($msg)))
    };
    ($err:expr $(,)?) => {
        return ::std::result::Result::Err($crate::Error::msg($err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(format!($fmt, $($arg)*)))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $msg:literal $(,)?) => {
        if !($cond) {
            $crate::bail!($msg);
        }
    };
    ($cond:expr, $err:expr $(,)?) => {
        if !($cond) {
            $crate::bail!($err);
        }
    };
    ($cond:expr, $fmt:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($fmt, $($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn macros_format() {
        fn inner(x: usize) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(())
        }
        assert!(inner(2).is_ok());
        assert_eq!(format!("{}", inner(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", inner(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
