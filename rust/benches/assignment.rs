//! Bench: balanced assignment (Fig. 1 machinery) — the only coordination
//! step whose cost grows with corpus size, so it must stay O(n log n).

use smalltalk::coordinator::{argmin_assign, balanced_assign, sequential_assign};
use smalltalk::util::bench::BenchSuite;
use smalltalk::util::rng::Rng;

fn matrix(n: usize, e: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..e).map(|_| rng.f32() * 20.0).collect())
        .collect()
}

fn main() {
    let mut suite = BenchSuite::new("assignment");
    suite.header();

    for &(n, e) in &[(1_000usize, 8usize), (10_000, 8), (10_000, 32), (100_000, 32)] {
        let m = matrix(n, e, 42);
        let r = suite.bench(&format!("balanced n={n} E={e}"), || {
            std::hint::black_box(balanced_assign(&m, None));
        });
        println!(
            "    -> {:.2}M sequences/s",
            r.throughput(n as f64) / 1e6
        );
    }

    let m = matrix(10_000, 8, 7);
    suite.bench("argmin n=10000 E=8", || {
        std::hint::black_box(argmin_assign(&m));
    });
    suite.bench("sequential n=10000 E=8", || {
        std::hint::black_box(sequential_assign(&m, None));
    });

    suite.write_json().unwrap();
}
