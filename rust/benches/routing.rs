//! Bench: the serving path — prefix score matrix, argmin routing, and the
//! batched serve loop (requests/s). The router overhead must stay a few
//! percent of expert execution (§3.2).

use std::time::Duration;

use smalltalk::coordinator::scoring::score_matrix;
use smalltalk::coordinator::{argmin_assign, run_pipeline, serve, PipelineConfig, Request};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::BenchSuite;

fn main() {
    let engine = Engine::new("artifacts").expect("run `make artifacts`");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    // a minimal trained mixture to measure against
    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts: 4,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 8,
        shard_sequences: 128,
        expert_steps: 10,
        prefix_len: 32,
        seed: 3,
    };
    eprintln!("[routing bench] preparing mixture ...");
    let result = run_pipeline(&engine, &bpe, &cfg).unwrap();
    let mixture = result.mixture;

    let mut suite =
        BenchSuite::new("routing").with_budget(Duration::from_millis(500), Duration::from_secs(4));
    suite.header();

    let mut gen = SequenceGen::new(&bpe, mixture.expert_meta.seq_len, 17);
    let seqs = gen.batch(32);

    let r = suite.bench("score_matrix 32 seqs x 4 routers (M=32)", || {
        std::hint::black_box(
            score_matrix(&engine, &mixture.routers, &mixture.router_meta, &seqs, 32).unwrap(),
        );
    });
    println!("    -> {:.0} seqs/s", r.throughput(32.0));

    let nll = score_matrix(&engine, &mixture.routers, &mixture.router_meta, &seqs, 32).unwrap();
    suite.bench("argmin routing decision x 32", || {
        std::hint::black_box(argmin_assign(&nll));
    });

    let requests: Vec<Request> = gen
        .batch(32)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: i as u64,
            tokens: s.tokens,
        })
        .collect();
    let r = suite.bench("serve 32 requests end-to-end", || {
        std::hint::black_box(serve(&engine, &mixture, &requests, 32).unwrap());
    });
    println!("    -> {:.1} req/s", r.throughput(32.0));

    // routing overhead share of the serve path
    let score_only = suite.bench("routing-only share (score+argmin)", || {
        let nll =
            score_matrix(&engine, &mixture.routers, &mixture.router_meta, &seqs, 32).unwrap();
        std::hint::black_box(argmin_assign(&nll));
    });
    println!(
        "    -> routing share of serving: {:.1}% (paper claims ~3% at 1.3B scale; \
         tiny experts inflate the ratio here)",
        score_only.mean_ns / r.mean_ns * 100.0
    );

    suite.write_json().unwrap();
}
