//! Bench: the serving path — prefix score matrix, argmin routing, and the
//! batched serve loop (requests/s). The router overhead must stay a few
//! percent of expert execution (§3.2).
//!
//! Prints before/after rows for the device-resident buffer cache: the
//! "seed path" row re-uploads every router's parameter vector and rebuilds
//! the token literal per router (the pre-cache behavior), the main row
//! uses the cached path. Per-row transfer bytes come from `EngineStats`.

use std::time::Duration;

use smalltalk::coordinator::scoring::score_matrix_threaded;
use smalltalk::coordinator::{argmin_assign, run_pipeline, serve_threaded, PipelineConfig, Request};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::engine::{f32_literal, tokens_literal};
use smalltalk::runtime::{default_threads, locate_artifacts, Engine};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::{env_threads, BenchSuite};

fn main() {
    let Some(artifacts) = locate_artifacts() else {
        eprintln!("[routing bench] no artifacts/manifest.json — run `make artifacts`; skipping");
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    // a minimal trained mixture to measure against
    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts: 4,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 8,
        shard_sequences: 128,
        expert_steps: 10,
        prefix_len: 32,
        seed: 3,
        threads: 0,
    };
    eprintln!("[routing bench] preparing mixture ...");
    let result = run_pipeline(&engine, &bpe, &cfg).unwrap();
    let mixture = result.mixture;
    let n_routers = mixture.routers.len();

    let mut suite =
        BenchSuite::new("routing").with_budget(Duration::from_millis(500), Duration::from_secs(4));
    suite.header();

    let mut gen = SequenceGen::new(&bpe, mixture.expert_meta.seq_len, 17);
    let seqs = gen.batch(32);
    let m = 32usize;

    // Worker count for every threaded row: the SMALLTALK_BENCH_THREADS
    // pin (bench_smoke.sh exports it for cross-machine comparability),
    // else the machine's parallelism. The seed-path row stays sequential
    // by construction — it replicates the pre-cache implementation.
    let bench_threads = env_threads().unwrap_or_else(default_threads);

    // ---- seed path: rebuild the token literal and re-upload parameters
    // for every router on every call (what the runtime did before the
    // device cache) ----
    let rmeta = mixture.router_meta.clone();
    let entry = format!("prefix_nll_{m}");
    let seed_path = |engine: &Engine| {
        let bs = rmeta.prefix_batch;
        let mut out = vec![vec![0.0f32; n_routers]; seqs.len()];
        let mut start = 0;
        while start < seqs.len() {
            let real = (seqs.len() - start).min(bs);
            let mut batch: Vec<Vec<u32>> = seqs[start..start + real]
                .iter()
                .map(|s| s.prefix(m).to_vec())
                .collect();
            while batch.len() < bs {
                batch.push(batch[real - 1].clone());
            }
            for (r, router) in mixture.routers.iter().enumerate() {
                let tokens = tokens_literal(&batch, m).unwrap();
                let scores = engine
                    .run(&router.variant, &entry, &[f32_literal(&router.params), tokens])
                    .unwrap();
                let scores = scores[0].to_vec::<f32>().unwrap();
                for i in 0..real {
                    out[start + i][r] = scores[i];
                }
            }
            start += real;
        }
        out
    };

    let seed_r = suite.bench(
        &format!("score_matrix 32 seqs x {n_routers} routers (seed path: upload per call)"),
        || {
            std::hint::black_box(seed_path(&engine));
        },
    );
    println!("    -> {:.0} seqs/s", seed_r.throughput(32.0));
    let s0 = engine.stats();
    std::hint::black_box(seed_path(&engine));
    let d = engine.stats().since(&s0);
    suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
    suite.annotate("d2h_bytes_per_iter", d.d2h_bytes as f64);

    // ---- cached path: token batch uploaded once per batch, parameters
    // resident per (state, version) ----
    let cached_r = suite.bench(
        &format!("score_matrix 32 seqs x {n_routers} routers (device cache)"),
        || {
            std::hint::black_box(
                score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap(),
            );
        },
    );
    println!("    -> {:.0} seqs/s", cached_r.throughput(32.0));
    let s0 = engine.stats();
    std::hint::black_box(
        score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap(),
    );
    let d = engine.stats().since(&s0);
    suite.annotate("threads", bench_threads as f64);
    suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
    suite.annotate("h2d_bytes_avoided_per_iter", d.h2d_bytes_avoided as f64);
    suite.annotate("uploads_avoided_per_iter", d.uploads_avoided as f64);
    println!(
        "    -> cache speedup vs seed path: {:.2}x seqs/s, h2d reduction {:.0}x",
        seed_r.mean_ns / cached_r.mean_ns,
        if d.h2d_bytes > 0 {
            (d.h2d_bytes + d.h2d_bytes_avoided) as f64 / d.h2d_bytes as f64
        } else {
            f64::INFINITY
        }
    );

    // consistency guard: both paths must produce identical scores
    assert_eq!(
        seed_path(&engine),
        score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap(),
        "cached score_matrix diverged from the seed path"
    );

    // ---- fused all-routers path vs per-router fan-out: one kernel launch
    // per token batch instead of E (needs a manifest exported with
    // `aot.py --fused`; pre-fused manifests skip these rows) ----
    if mixture.router_meta.fused_prefix_entry(m).is_some() {
        use smalltalk::coordinator::{score_matrix_rows_fanout, score_matrix_rows_fused};
        let rows: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(m)).collect();
        let rmeta = &mixture.router_meta;

        let fan_r = suite.bench(
            &format!("score_matrix 32 seqs x {n_routers} routers (fan-out)"),
            || {
                std::hint::black_box(
                    score_matrix_rows_fanout(&engine, &mixture.routers, rmeta, &rows, m, bench_threads)
                        .unwrap(),
                );
            },
        );
        println!("    -> {:.0} seqs/s", fan_r.throughput(32.0));
        let s0 = engine.stats();
        let fan_scores =
            score_matrix_rows_fanout(&engine, &mixture.routers, rmeta, &rows, m, bench_threads)
                .unwrap();
        let d = engine.stats().since(&s0);
        suite.annotate("threads", bench_threads as f64);
        suite.annotate("executions_per_request", d.executions as f64 / 32.0);
        suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);

        let fused_r = suite.bench(
            &format!("score_matrix 32 seqs x {n_routers} routers (fused all-routers)"),
            || {
                std::hint::black_box(
                    score_matrix_rows_fused(&engine, &mixture.routers, rmeta, &rows, m, bench_threads)
                        .unwrap(),
                );
            },
        );
        println!("    -> {:.0} seqs/s", fused_r.throughput(32.0));
        let s0 = engine.stats();
        let fused_scores =
            score_matrix_rows_fused(&engine, &mixture.routers, rmeta, &rows, m, bench_threads)
                .unwrap();
        let d = engine.stats().since(&s0);
        suite.annotate("threads", bench_threads as f64);
        suite.annotate("executions_per_request", d.executions as f64 / 32.0);
        suite.annotate("fused_executions_per_iter", d.fused_executions as f64);
        suite.annotate("router_execs_avoided_per_iter", d.router_execs_avoided as f64);
        suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
        println!(
            "    -> fused vs fan-out: {:.2}x seqs/s, {} launches per matrix (vs {}), \
             {} per-router dispatch/readback round-trips avoided",
            fan_r.mean_ns / fused_r.mean_ns,
            d.fused_executions,
            d.fused_executions * n_routers,
            d.router_execs_avoided,
        );
        // score-equality guard: fused must be bit-identical to the fan-out
        assert_eq!(
            fan_scores, fused_scores,
            "fused score matrix diverged from the per-router fan-out"
        );
    } else {
        eprintln!(
            "[routing bench] manifest has no prefix_nll_all_{m} entry \
             (re-run `make artifacts` with the fused exporter); skipping fused rows"
        );
    }

    // ---- fused stacked-expert eval vs per-expert fan-out: a routed
    // wave's expert batches pad up the bucket ladder and stack into
    // eval_nll_all_{b} launches (needs eval entries from `aot.py
    // --fused`; pre-fused manifests skip these rows) ----
    if mixture.expert_meta.fused_eval_buckets().is_empty() {
        eprintln!(
            "[routing bench] manifest has no eval_nll_all entries \
             (re-run `make artifacts` with the fused exporter); skipping fused-expert rows"
        );
    } else {
        use smalltalk::coordinator::inference::eval_nll_groups;
        use smalltalk::coordinator::group_by_expert;
        use smalltalk::runtime::TrainState;
        // route the wave once; benchmark only the expert phase
        let nll = score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap();
        let routes = argmin_assign(&nll).expert_of;
        let groups = group_by_expert(&routes, mixture.n_experts()).unwrap();
        let group_rows: Vec<Vec<&[u32]>> = groups
            .iter()
            .map(|idx| idx.iter().map(|&i| seqs[i].tokens.as_slice()).collect())
            .collect();
        let experts: Vec<&TrainState> = mixture.experts.iter().collect();
        let emeta = &mixture.expert_meta;
        let mut stripped = emeta.clone();
        stripped
            .entry_points
            .retain(|e| !e.starts_with("eval_nll_all_"));
        let n_experts = experts.len();

        let fan_r = suite.bench(
            &format!("expert wave eval 32 seqs x {n_experts} experts (fan-out)"),
            || {
                std::hint::black_box(
                    eval_nll_groups(&engine, &experts, &stripped, &group_rows, bench_threads)
                        .unwrap(),
                );
            },
        );
        println!("    -> {:.0} seqs/s", fan_r.throughput(32.0));
        let s0 = engine.stats();
        let fan_nll =
            eval_nll_groups(&engine, &experts, &stripped, &group_rows, bench_threads).unwrap();
        let d = engine.stats().since(&s0);
        suite.annotate("threads", bench_threads as f64);
        suite.annotate("expert_launches_per_wave", d.executions as f64);
        suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);

        let fused_r = suite.bench(
            &format!("expert wave eval 32 seqs x {n_experts} experts (fused bucket ladder)"),
            || {
                std::hint::black_box(
                    eval_nll_groups(&engine, &experts, emeta, &group_rows, bench_threads).unwrap(),
                );
            },
        );
        println!("    -> {:.0} seqs/s", fused_r.throughput(32.0));
        let s0 = engine.stats();
        let fused_nll =
            eval_nll_groups(&engine, &experts, emeta, &group_rows, bench_threads).unwrap();
        let d = engine.stats().since(&s0);
        suite.annotate("threads", bench_threads as f64);
        suite.annotate("expert_launches_per_wave", d.executions as f64);
        suite.annotate("fused_eval_launches_per_wave", d.fused_eval_executions as f64);
        suite.annotate("expert_launches_avoided_per_wave", d.expert_execs_avoided as f64);
        suite.annotate("eval_pad_rows_per_wave", d.eval_pad_rows as f64);
        suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
        println!(
            "    -> fused vs fan-out: {:.2}x seqs/s, {} launches per wave (vs {}), \
             {} pad rows discarded",
            fan_r.mean_ns / fused_r.mean_ns,
            d.executions,
            d.executions + d.expert_execs_avoided,
            d.eval_pad_rows,
        );
        // score-equality guard: fused must be bit-identical to the fan-out
        assert_eq!(
            fan_nll, fused_nll,
            "fused expert wave eval diverged from the per-expert fan-out"
        );
    }

    let nll = score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap();
    suite.bench("argmin routing decision x 32", || {
        std::hint::black_box(argmin_assign(&nll));
    });

    let requests: Vec<Request> = gen
        .batch(32)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: i as u64,
            tokens: s.tokens,
        })
        .collect();
    let r = suite.bench("serve 32 requests end-to-end", || {
        std::hint::black_box(serve_threaded(&engine, &mixture, &requests, m, bench_threads).unwrap());
    });
    println!("    -> {:.1} req/s", r.throughput(32.0));
    let s0 = engine.stats();
    std::hint::black_box(serve_threaded(&engine, &mixture, &requests, m, bench_threads).unwrap());
    let d = engine.stats().since(&s0);
    suite.annotate("threads", bench_threads as f64);
    suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
    suite.annotate("h2d_bytes_avoided_per_iter", d.h2d_bytes_avoided as f64);

    // ---- thread sweep: sequential vs parallel expert-group execution.
    // Expert groups are independent, so the wave fans across workers;
    // the sweep records threads + per-thread seqs/s per row. A pinned
    // SMALLTALK_BENCH_THREADS is honored as-is (pinning 1 collapses the
    // sweep to the sequential row alone).
    let sweep: Vec<usize> = if bench_threads > 1 { vec![1, bench_threads] } else { vec![1] };
    let sequential = serve_threaded(&engine, &mixture, &requests, m, 1).unwrap();
    for t in sweep {
        let r = suite.bench(&format!("serve 32 requests (threads={t})"), || {
            std::hint::black_box(serve_threaded(&engine, &mixture, &requests, m, t).unwrap());
        });
        suite.annotate("threads", t as f64);
        suite.annotate("seqs_per_s", r.throughput(32.0));
        suite.annotate("seqs_per_s_per_thread", r.throughput(32.0) / t as f64);
        // determinism guard: parallel responses must be bit-identical to
        // the sequential wave (ids, experts, NLLs, input order)
        let parallel = serve_threaded(&engine, &mixture, &requests, m, t).unwrap();
        assert_eq!(parallel.len(), sequential.len());
        for (p, s) in parallel.iter().zip(&sequential) {
            assert_eq!((p.id, p.expert, p.nll), (s.id, s.expert, s.nll),
                "parallel serve (threads={t}) diverged from sequential");
        }
    }

    // ---- continuous-batching row: the same 32 requests as a burst
    // through the admission scheduler (full sweep in benches/serve.rs) ----
    {
        use smalltalk::coordinator::{response_triples, run_server, MixtureBackend, ServerConfig};
        let backend = MixtureBackend {
            engine: &engine,
            mixture: &mixture,
            prefix_len: m,
        };
        let scfg = ServerConfig::continuous(mixture.expert_meta.eval_batch, 500, bench_threads);
        let r = suite.bench("serve 32 requests (continuous, burst)", || {
            std::hint::black_box(
                run_server(&backend, &scfg, |client| {
                    client.submit_wave(requests.clone());
                })
                .unwrap(),
            );
        });
        suite.annotate("threads", bench_threads as f64);
        suite.annotate("req_per_s", r.throughput(32.0));
        // determinism guard: same (id, expert, nll) set as the closed wave
        let (responses, _stats, ()) = run_server(&backend, &scfg, |client| {
            client.submit_wave(requests.clone());
        })
        .unwrap();
        assert_eq!(
            response_triples(&responses),
            response_triples(&sequential),
            "continuous serve diverged from sequential"
        );
    }

    // routing overhead share of the serve path
    let score_only = suite.bench("routing-only share (score+argmin)", || {
        let nll =
            score_matrix_threaded(&engine, &mixture.routers, &mixture.router_meta, &seqs, m, bench_threads)
                .unwrap();
        std::hint::black_box(argmin_assign(&nll));
    });
    println!(
        "    -> routing share of serving: {:.1}% (paper claims ~3% at 1.3B scale; \
         tiny experts inflate the ratio here)",
        score_only.mean_ns / r.mean_ns * 100.0
    );

    let stats = engine.stats();
    println!(
        "\nengine totals: {} uploads ({} B h2d), {} avoided ({} B), {} param uploads, {} evictions",
        stats.uploads,
        stats.h2d_bytes,
        stats.uploads_avoided,
        stats.h2d_bytes_avoided,
        stats.param_uploads,
        stats.cache_evictions
    );

    suite.write_json().unwrap();
}
