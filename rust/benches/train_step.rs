//! Bench: the XLA hot path — fused train_step, eval_nll and prefix
//! scoring per variant. Reports tokens/s and the literal-copy overhead
//! that §Perf tracks.

use std::time::Duration;

use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{Engine, TrainState};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::BenchSuite;

fn main() {
    let engine = Engine::new("artifacts").expect("run `make artifacts`");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    let mut suite = BenchSuite::new("train_step")
        .with_budget(Duration::from_millis(500), Duration::from_secs(5));
    suite.header();

    for variant in ["router_micro", "router_sm", "expert_sm", "expert_md"] {
        let Ok(meta) = engine.variant(variant) else {
            continue;
        };
        let meta = meta.clone();
        let mut st = TrainState::init(&engine, variant, 1).unwrap();
        let mut gen = SequenceGen::new(&bpe, meta.seq_len, 5);
        let train_batch: Vec<Vec<u32>> = gen
            .batch(meta.train_batch)
            .into_iter()
            .map(|s| s.tokens)
            .collect();
        let tokens = meta.tokens_per_step() as f64;

        let r = suite.bench(&format!("{variant}: train_step"), || {
            std::hint::black_box(st.train_step(&engine, &train_batch, &meta).unwrap());
        });
        println!("    -> {:.1}k tokens/s", r.throughput(tokens) / 1e3);

        let eval_batch: Vec<Vec<u32>> = gen
            .batch(meta.eval_batch)
            .into_iter()
            .map(|s| s.tokens)
            .collect();
        let r = suite.bench(&format!("{variant}: eval_nll"), || {
            std::hint::black_box(st.eval_nll(&engine, &eval_batch, &meta).unwrap());
        });
        println!(
            "    -> {:.1}k tokens/s",
            r.throughput((meta.eval_batch * meta.seq_len) as f64) / 1e3
        );

        let m = *meta.prefix_lens.iter().min().unwrap_or(&32);
        let prefix_batch: Vec<Vec<u32>> = gen
            .batch(meta.prefix_batch)
            .iter()
            .map(|s| s.prefix(m).to_vec())
            .collect();
        let r = suite.bench(&format!("{variant}: prefix_nll_{m}"), || {
            std::hint::black_box(st.prefix_nll(&engine, &prefix_batch, &meta, m).unwrap());
        });
        println!(
            "    -> {:.0} sequences/s",
            r.throughput(meta.prefix_batch as f64)
        );
    }

    let stats = engine.stats();
    println!(
        "\nengine: {} compiles {:.1}s total, {} executions {:.1}s total",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    suite.write_json().unwrap();
}
