//! Bench: the XLA hot path — fused train_step, eval_nll and prefix
//! scoring per variant. Reports tokens/s plus per-row host↔device
//! transfer bytes from `EngineStats` (the literal-copy overhead §Perf
//! tracks, and what the device-resident buffer cache eliminates on the
//! scoring/eval rows).

use std::time::Duration;

use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine, TrainState};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::BenchSuite;

fn main() {
    let Some(artifacts) = locate_artifacts() else {
        eprintln!("[train_step bench] no artifacts/manifest.json — run `make artifacts`; skipping");
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    let mut suite = BenchSuite::new("train_step")
        .with_budget(Duration::from_millis(500), Duration::from_secs(5));
    suite.header();

    // measure the transfer bytes of one steady-state call (deterministic
    // given the shapes, so a single sample is exact)
    fn annotate_transfer(suite: &mut BenchSuite, engine: &Engine, call: &mut dyn FnMut()) {
        let s0 = engine.stats();
        call();
        let d = engine.stats().since(&s0);
        suite.annotate("h2d_bytes_per_iter", d.h2d_bytes as f64);
        suite.annotate("d2h_bytes_per_iter", d.d2h_bytes as f64);
        suite.annotate("h2d_bytes_avoided_per_iter", d.h2d_bytes_avoided as f64);
    }

    for variant in ["router_micro", "router_sm", "expert_sm", "expert_md"] {
        let Ok(meta) = engine.variant(variant) else {
            continue;
        };
        let meta = meta.clone();
        let mut st = TrainState::init(&engine, variant, 1).unwrap();
        let mut gen = SequenceGen::new(&bpe, meta.seq_len, 5);
        let train_batch: Vec<Vec<u32>> = gen
            .batch(meta.train_batch)
            .into_iter()
            .map(|s| s.tokens)
            .collect();
        let tokens = meta.tokens_per_step() as f64;

        let r = suite.bench(&format!("{variant}: train_step"), || {
            std::hint::black_box(st.train_step(&engine, &train_batch, &meta).unwrap());
        });
        println!("    -> {:.1}k tokens/s", r.throughput(tokens) / 1e3);
        annotate_transfer(&mut suite, &engine, &mut || {
            std::hint::black_box(st.train_step(&engine, &train_batch, &meta).unwrap());
        });

        let eval_batch: Vec<Vec<u32>> = gen
            .batch(meta.eval_batch)
            .into_iter()
            .map(|s| s.tokens)
            .collect();
        let r = suite.bench(&format!("{variant}: eval_nll"), || {
            std::hint::black_box(st.eval_nll(&engine, &eval_batch, &meta).unwrap());
        });
        println!(
            "    -> {:.1}k tokens/s",
            r.throughput((meta.eval_batch * meta.seq_len) as f64) / 1e3
        );
        annotate_transfer(&mut suite, &engine, &mut || {
            std::hint::black_box(st.eval_nll(&engine, &eval_batch, &meta).unwrap());
        });

        let m = *meta.prefix_lens.iter().min().unwrap_or(&32);
        let prefix_batch: Vec<Vec<u32>> = gen
            .batch(meta.prefix_batch)
            .iter()
            .map(|s| s.prefix(m).to_vec())
            .collect();
        let r = suite.bench(&format!("{variant}: prefix_nll_{m}"), || {
            std::hint::black_box(st.prefix_nll(&engine, &prefix_batch, &meta, m).unwrap());
        });
        println!(
            "    -> {:.0} sequences/s",
            r.throughput(meta.prefix_batch as f64)
        );
        annotate_transfer(&mut suite, &engine, &mut || {
            std::hint::black_box(st.prefix_nll(&engine, &prefix_batch, &meta, m).unwrap());
        });
    }

    let stats = engine.stats();
    println!(
        "\nengine: {} compiles {:.1}s total, {} executions {:.1}s total",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    println!(
        "transfers: {} uploads / {} B h2d, {} B d2h; {} uploads avoided / {} B \
         (params resident per state version: {} param uploads, {} evictions)",
        stats.uploads,
        stats.h2d_bytes,
        stats.d2h_bytes,
        stats.uploads_avoided,
        stats.h2d_bytes_avoided,
        stats.param_uploads,
        stats.cache_evictions
    );
    suite.write_json().unwrap();
}
