//! Bench: smoke-budget run of every paper table/figure driver.
//!
//! `cargo bench --bench paper_tables` proves each experiment regenerator
//! end to end in seconds; the scaled numbers for EXPERIMENTS.md come from
//! `cargo run --release --example paper_suite -- all`.

use std::time::Instant;

use smalltalk::data::corpus::Corpus;
use smalltalk::experiments::{
    comm_overhead, fig2, fig3_tables45, fig4a, fig4b, fig4c, fig6, table3, Budget, Suite,
};
use smalltalk::runtime::Engine;
use smalltalk::tokenizer::BpeTrainer;

fn main() {
    let Some(artifacts) = smalltalk::runtime::locate_artifacts() else {
        eprintln!("[paper_tables bench] no artifacts/manifest.json — run `make artifacts`; skipping");
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let budget = Budget::smoke();
    let corpus = Corpus::generate(60, 400, budget.seed, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
    let suite = Suite::new(&engine, &bpe, budget);

    println!("=== bench: paper_tables (smoke budget) ===");
    let t0 = Instant::now();

    let t = Instant::now();
    let a = fig2(&suite).unwrap();
    println!("fig2+fig5   ok in {:>8.1?} ({} rows)", t.elapsed(),
        a.json.get("rows").and_then(|r| r.as_arr()).map(|r| r.len()).unwrap_or(0));

    let t = Instant::now();
    let j = fig3_tables45(&suite, Some(&a)).unwrap();
    println!("fig3+t4/5   ok in {:>8.1?} (win rate {:.0}%)", t.elapsed(),
        j.get("win_fraction").and_then(|v| v.as_f64()).unwrap_or(0.0) * 100.0);

    let t = Instant::now();
    fig4a(&suite).unwrap();
    println!("fig4a       ok in {:>8.1?}", t.elapsed());

    let t = Instant::now();
    fig4b(&suite, Some(&a)).unwrap();
    println!("fig4b       ok in {:>8.1?}", t.elapsed());

    let t = Instant::now();
    fig4c(&suite).unwrap();
    println!("fig4c       ok in {:>8.1?}", t.elapsed());

    let t = Instant::now();
    fig6(&suite).unwrap();
    println!("fig6        ok in {:>8.1?}", t.elapsed());

    let t = Instant::now();
    table3(&suite, Some(&a.json)).unwrap();
    println!("table3      ok in {:>8.1?}", t.elapsed());

    let t = Instant::now();
    comm_overhead(&suite).unwrap();
    println!("comm        ok in {:>8.1?}", t.elapsed());

    println!("total: {:.1?}", t0.elapsed());
}
