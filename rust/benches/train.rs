//! Bench: staged vs async trainer orchestration — trained sequences per
//! second plus the communication ledger each mode actually generates
//! (score all-gathers for the staged barrier pipeline, snapshot
//! broadcasts for the async node pool) — and an elastic *chaos* row
//! (stub backend, no artifacts needed): a faulted fleet's throughput
//! with steps lost to kills, checkpoint-recovery wall-clock and rejoin
//! merge counts. Lands in BENCH_train.json via scripts/bench_smoke.sh
//! for the per-PR perf trajectory.

use std::path::Path;
use std::time::Duration;

use anyhow::Result;

use smalltalk::coordinator::{
    run_elastic_nodes, run_pipeline_reference, run_sharded_nodes, run_trainer, CommKind,
    ElasticHandle, ElasticPlan, ElasticPolicy, ElasticReport, FaultPlan, FleetReport, LeaveEvent,
    NodeRunConfig, PipelineConfig, PlanShape, Rejoin, RouterSnapshot, ShardCtx, ShardPlan,
    SnapshotStore, TrainBackend, TrainerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::runtime::{locate_artifacts, Engine, TrainState};
use smalltalk::tokenizer::{Bpe, BpeTrainer};
use smalltalk::util::bench::{env_threads, BenchSuite};

fn bench_cfg(threads: usize) -> PipelineConfig {
    PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "router_micro".into(), // tiny expert: bench the orchestration
        n_experts: 2,
        em_rounds: 2,
        em_chunk: 48,
        em_steps_per_round: 4,
        shard_sequences: 64,
        expert_steps: 6,
        prefix_len: 32,
        seed: 2024,
        threads,
    }
}

// ------------------------------------------------------------------
// elastic chaos row (stub backend — measures the orchestration layer)
// ------------------------------------------------------------------

const CHAOS_P: usize = 6;
const CHAOS_SEQ: usize = 16;
const CHAOS_BS: usize = 4;
const CHAOS_NODES: usize = 3;
const CHAOS_STEPS: usize = 24;

/// Model-free backend matching the chaos test suite's stub: pure
/// arithmetic training, routing on the token sum alone.
struct ElasticStub {
    seats: usize,
}

impl TrainBackend for ElasticStub {
    fn train_batch_rows(&self) -> usize {
        CHAOS_BS
    }

    fn tokens_per_step(&self) -> usize {
        CHAOS_BS * CHAOS_SEQ
    }

    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState> {
        let params: Vec<f32> = (0..CHAOS_P)
            .map(|i| (seed % 1000) as f32 * 1e-3 + node as f32 + i as f32 * 0.1)
            .collect();
        Ok(TrainState::from_params(
            "stub",
            params,
            vec![0.0; CHAOS_P],
            vec![0.0; CHAOS_P],
            0,
        ))
    }

    fn train_step(&self, _node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        let mut acc = 0.0f32;
        for row in batch {
            for &t in *row {
                acc += (t % 97) as f32;
            }
        }
        let loss = acc / (batch.len().max(1) as f32 * 100.0);
        for i in 0..state.params.len() {
            let g = loss * 1e-3 + (i as f32 + 1.0) * 1e-4;
            state.m[i] = 0.9 * state.m[i] + 0.1 * g;
            state.v[i] = 0.99 * state.v[i] + 0.01 * g * g;
            state.params[i] -= 0.1 * state.m[i];
        }
        state.step += 1;
        Ok(loss)
    }

    fn route_local(&self, _snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| {
                let sum: u64 = r.iter().map(|&t| t as u64).sum();
                (sum % self.seats as u64) as usize
            })
            .collect())
    }
}

/// One elastic run under a fixed fault plan: a seeded kill (adopted from
/// checkpoint), a scheduled leave whose offline leg merges back, and a
/// mid-run join onto the spare seat.
fn chaos_run(bpe: &Bpe, dir: &Path) -> ElasticReport {
    // fresh checkpoint dir per run: stale files from a previous timed
    // iteration must not feed an adoption
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).expect("chaos bench dir");
    let backend = ElasticStub {
        seats: CHAOS_NODES + 1,
    };
    let plan = ElasticPlan {
        faults: FaultPlan::generate(
            11,
            &PlanShape {
                nodes: CHAOS_NODES,
                steps_per_node: CHAOS_STEPS as u64,
                kills: 1,
                transients: 1,
                stalls: 1,
                drops: 1,
                publish_gates: 0,
                snapshot_versions: 1,
                ..PlanShape::default()
            },
        ),
        leaves: vec![LeaveEvent {
            node: 1,
            at_step: 10,
            adopt: true,
            rejoin: Some(Rejoin {
                offline_steps: 2,
                merge_at_step: 16,
            }),
        }],
        policy: ElasticPolicy {
            max_retries: 5,
            max_extra_nodes: 1,
            ..ElasticPolicy::default()
        },
    };
    let seeds: Vec<u64> = (0..CHAOS_NODES).map(|e| 0xE0 + e as u64).collect();
    let cfg = NodeRunConfig {
        steps_per_node: CHAOS_STEPS,
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.to_path_buf()),
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let store = SnapshotStore::new(CHAOS_NODES);
    let factory = |e: usize, salt: u64| {
        SequenceGen::new(
            bpe,
            CHAOS_SEQ,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    let (report, ()) = run_elastic_nodes(&backend, &store, &seeds, factory, &cfg, &plan, |h| {
        // join before the first publish so the run cannot drain early
        h.join_new_node(0x77)?;
        let routers: Vec<TrainState> = (0..CHAOS_NODES + 1)
            .map(|i| {
                TrainState::from_params(
                    "router",
                    vec![0.5 + i as f32 * 0.1; CHAOS_P],
                    vec![0.0; CHAOS_P],
                    vec![0.0; CHAOS_P],
                    1,
                )
            })
            .collect();
        h.store().publish(routers, 1);
        Ok(())
    })
    .expect("elastic chaos run");
    report
}

// ------------------------------------------------------------------
// sharded fleet chaos row (stub backend — the multi-shard fault model)
// ------------------------------------------------------------------

const SHARD_SEATS: usize = 4;
const SHARD_COUNT: usize = 2;
const SHARD_ROUNDS: u64 = 3;
const SHARD_STEPS: usize = 12;

/// One fleet run under a seeded shard-level fault plan: a node kill, a
/// cross-shard partition, a leader loss, and a whole-shard kill, all
/// recovered — measures what the fault-domain machinery costs and how
/// the traffic splits across the shard boundary.
fn shard_chaos_run(bpe: &Bpe, dir: &Path) -> FleetReport {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).expect("shard bench dir");
    let backend = ElasticStub { seats: SHARD_SEATS };
    let plan = ShardPlan::partition(SHARD_SEATS, SHARD_COUNT).expect("shard plan");
    let fleet = ElasticPlan {
        faults: FaultPlan::generate(
            23,
            &PlanShape {
                nodes: SHARD_SEATS,
                steps_per_node: SHARD_STEPS as u64,
                kills: 1,
                transients: 1,
                shards: SHARD_COUNT,
                partitions: 1,
                leader_losses: 1,
                shard_kills: 1,
                em_rounds: SHARD_ROUNDS,
                ..PlanShape::default()
            },
        ),
        ..ElasticPlan::default()
    };
    let seeds: Vec<u64> = (0..SHARD_SEATS).map(|e| 0xE0 + e as u64).collect();
    let cfg = NodeRunConfig {
        steps_per_node: SHARD_STEPS,
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.to_path_buf()),
        threads: 2,
        snapshot_wait_us: 10_000_000,
        ..NodeRunConfig::default()
    };
    let factory = |e: usize, salt: u64| {
        SequenceGen::new(
            bpe,
            CHAOS_SEQ,
            (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    let blocks = |s: usize, round: u64| -> Vec<TrainState> {
        plan.members(s)
            .iter()
            .map(|&seat| {
                TrainState::from_params(
                    "router",
                    vec![seat as f32 + round as f32 * 0.01; CHAOS_P],
                    vec![0.0; CHAOS_P],
                    vec![0.0; CHAOS_P],
                    round,
                )
            })
            .collect()
    };
    let (report, _routers) = run_sharded_nodes(
        &backend,
        &plan,
        &seeds,
        factory,
        &cfg,
        &fleet,
        |s: usize, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
            for round in 1..=SHARD_ROUNDS {
                ctx.round_boundary(handle, round, &blocks(s, round))?;
            }
            Ok(blocks(s, SHARD_ROUNDS))
        },
    )
    .expect("sharded chaos run");
    report
}

fn main() {
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
    let threads = env_threads().unwrap_or(2);
    let cfg = bench_cfg(threads);

    let mut suite = BenchSuite::new("train")
        .with_budget(Duration::from_millis(200), Duration::from_secs(4));
    suite.header();

    // chaos row first: it needs no artifacts, so every environment gets
    // a fault-tolerance trajectory point
    let chaos_dir = std::env::temp_dir().join(format!(
        "smalltalk_bench_chaos_{}",
        std::process::id()
    ));
    let chaos_once = chaos_run(&bpe, &chaos_dir);
    let chaos_seqs = ((CHAOS_NODES + 1) * CHAOS_STEPS * CHAOS_BS) as f64;
    let r = suite.bench("elastic chaos run (3+1 nodes, kill+leave+join)", || {
        std::hint::black_box(chaos_run(&bpe, &chaos_dir).ends.len());
    });
    println!(
        "    -> {:.1} trained seqs/s under chaos",
        r.throughput(chaos_seqs)
    );
    let cs = &chaos_once.stats;
    suite.annotate("chaos_kills", cs.kills as f64);
    suite.annotate("chaos_adoptions", cs.adoptions as f64);
    suite.annotate("chaos_joins", cs.joins as f64);
    suite.annotate("chaos_merges", cs.merges as f64);
    suite.annotate("chaos_steps_lost", cs.steps_lost as f64);
    suite.annotate("chaos_recovery_micros", cs.recovery_micros as f64);
    suite.annotate(
        "chaos_adopt_bytes",
        chaos_once.ledger.kind_bytes(CommKind::CheckpointAdopt) as f64,
    );
    suite.annotate(
        "chaos_merge_bytes",
        chaos_once.ledger.kind_bytes(CommKind::ParamMerge) as f64,
    );
    println!(
        "    chaos: {} kill(s), {} adoption(s), {} step(s) lost, {} µs recovering, {} merge(s)",
        cs.kills, cs.adoptions, cs.steps_lost, cs.recovery_micros, cs.merges
    );
    let _ = std::fs::remove_dir_all(&chaos_dir);

    // sharded fleet row: the same orchestration under multi-shard fault
    // domains, with the intra/inter-shard traffic split on the record
    let shard_dir = std::env::temp_dir().join(format!(
        "smalltalk_bench_shard_{}",
        std::process::id()
    ));
    let shard_once = shard_chaos_run(&bpe, &shard_dir);
    let shard_seqs = (SHARD_SEATS * SHARD_STEPS * CHAOS_BS) as f64;
    let r = suite.bench("sharded fleet chaos run (2 shards x 2 seats)", || {
        std::hint::black_box(shard_chaos_run(&bpe, &shard_dir).ends.len());
    });
    println!(
        "    -> {:.1} trained seqs/s under shard chaos",
        r.throughput(shard_seqs)
    );
    let ss = &shard_once.stats;
    let promotions: u64 = shard_once.shards.iter().map(|s| s.promotions).sum();
    let rounds_missed: u64 = shard_once.shards.iter().map(|s| s.rounds_missed).sum();
    suite.annotate("shard_chaos_shards", SHARD_COUNT as f64);
    suite.annotate("shard_chaos_kills", ss.kills as f64);
    suite.annotate("shard_chaos_steps_lost", ss.steps_lost as f64);
    suite.annotate("shard_chaos_recovery_micros", ss.recovery_micros as f64);
    suite.annotate("shard_chaos_promotions", promotions as f64);
    suite.annotate("shard_chaos_rounds_missed", rounds_missed as f64);
    suite.annotate(
        "shard_chaos_intra_bytes",
        shard_once.ledger.intra_shard_bytes() as f64,
    );
    suite.annotate(
        "shard_chaos_inter_bytes",
        shard_once.ledger.inter_shard_bytes() as f64,
    );
    println!(
        "    shard chaos: {} kill(s), {} step(s) lost, {} promotion(s), {} round(s) missed, \
         intra {} B vs inter {} B",
        ss.kills,
        ss.steps_lost,
        promotions,
        rounds_missed,
        shard_once.ledger.intra_shard_bytes(),
        shard_once.ledger.inter_shard_bytes(),
    );
    let _ = std::fs::remove_dir_all(&shard_dir);

    let Some(artifacts) = locate_artifacts() else {
        eprintln!(
            "[train bench] no artifacts/manifest.json — run `make artifacts`; chaos rows only"
        );
        suite.write_json().unwrap();
        return;
    };
    let engine = match Engine::new(artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[train bench] engine load failed ({e:#}); chaos rows only");
            suite.write_json().unwrap();
            return;
        }
    };

    // determinism guard: the staged orchestrator must reproduce the
    // classic pipeline bit-for-bit before its numbers mean anything
    let reference = run_pipeline_reference(&engine, &bpe, &cfg).expect("reference pipeline");
    let staged_once = run_trainer(&engine, &bpe, &cfg, &TrainerConfig::staged())
        .expect("staged trainer");
    for (a, b) in reference
        .mixture
        .experts
        .iter()
        .zip(&staged_once.mixture.experts)
    {
        assert_eq!(
            a.params, b.params,
            "staged orchestrator diverged from the classic pipeline"
        );
    }

    let meta = engine.variant(&cfg.expert_variant).unwrap().clone();
    let staged_seqs = (cfg.n_experts * cfg.expert_steps * meta.train_batch) as f64;

    let r = suite.bench(&format!("staged trainer (t={threads})"), || {
        std::hint::black_box(
            run_trainer(&engine, &bpe, &cfg, &TrainerConfig::staged())
                .expect("staged trainer")
                .mixture
                .experts
                .len(),
        );
    });
    println!("    -> {:.1} trained seqs/s", r.throughput(staged_seqs));
    suite.annotate("threads", threads as f64);
    suite.annotate("trained_seqs_per_run", staged_seqs);
    suite.annotate(
        "ledger_total_bytes",
        staged_once.ledger.total_bytes() as f64,
    );
    suite.annotate(
        "ledger_peak_node_bytes",
        staged_once.ledger.peak_node_bytes() as f64,
    );
    suite.annotate(
        "score_allgather_rounds",
        staged_once.ledger.rounds(CommKind::ScoreAllGather) as f64,
    );

    let async_once = run_trainer(&engine, &bpe, &cfg, &TrainerConfig::asynchronous())
        .expect("async trainer");
    let async_seqs: f64 = async_once.segment_sizes.iter().sum::<usize>() as f64;
    let r = suite.bench(&format!("async trainer (t={threads})"), || {
        std::hint::black_box(
            run_trainer(&engine, &bpe, &cfg, &TrainerConfig::asynchronous())
                .expect("async trainer")
                .mixture
                .experts
                .len(),
        );
    });
    println!(
        "    -> {:.1} trained seqs/s ({} seqs/run)",
        r.throughput(async_seqs),
        async_seqs
    );
    suite.annotate("threads", threads as f64);
    suite.annotate("trained_seqs_per_run", async_seqs);
    suite.annotate("ledger_total_bytes", async_once.ledger.total_bytes() as f64);
    suite.annotate(
        "ledger_peak_node_bytes",
        async_once.ledger.peak_node_bytes() as f64,
    );
    suite.annotate(
        "snapshot_broadcast_rounds",
        async_once.ledger.rounds(CommKind::SnapshotBroadcast) as f64,
    );

    println!(
        "\nledger: staged moved {} B (peak node {} B, {} all-gathers); \
         async moved {} B (peak node {} B, {} snapshot broadcasts)",
        staged_once.ledger.total_bytes(),
        staged_once.ledger.peak_node_bytes(),
        staged_once.ledger.rounds(CommKind::ScoreAllGather),
        async_once.ledger.total_bytes(),
        async_once.ledger.peak_node_bytes(),
        async_once.ledger.rounds(CommKind::SnapshotBroadcast),
    );
    suite.write_json().unwrap();
}
