//! Bench: staged vs async trainer orchestration — trained sequences per
//! second plus the communication ledger each mode actually generates
//! (score all-gathers for the staged barrier pipeline, snapshot
//! broadcasts for the async node pool). Lands in BENCH_train.json via
//! scripts/bench_smoke.sh for the per-PR perf trajectory.

use std::time::Duration;

use smalltalk::coordinator::{
    run_pipeline_reference, run_trainer, CommKind, PipelineConfig, TrainerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::runtime::{locate_artifacts, Engine};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::{env_threads, BenchSuite};

fn bench_cfg(threads: usize) -> PipelineConfig {
    PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "router_micro".into(), // tiny expert: bench the orchestration
        n_experts: 2,
        em_rounds: 2,
        em_chunk: 48,
        em_steps_per_round: 4,
        shard_sequences: 64,
        expert_steps: 6,
        prefix_len: 32,
        seed: 2024,
        threads,
    }
}

fn main() {
    let Some(artifacts) = locate_artifacts() else {
        eprintln!("[train bench] no artifacts/manifest.json — run `make artifacts`; skipping");
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
    let threads = env_threads().unwrap_or(2);
    let cfg = bench_cfg(threads);

    let mut suite = BenchSuite::new("train")
        .with_budget(Duration::from_millis(200), Duration::from_secs(4));
    suite.header();

    // determinism guard: the staged orchestrator must reproduce the
    // classic pipeline bit-for-bit before its numbers mean anything
    let reference = run_pipeline_reference(&engine, &bpe, &cfg).expect("reference pipeline");
    let staged_once = run_trainer(&engine, &bpe, &cfg, &TrainerConfig::staged())
        .expect("staged trainer");
    for (a, b) in reference
        .mixture
        .experts
        .iter()
        .zip(&staged_once.mixture.experts)
    {
        assert_eq!(
            a.params, b.params,
            "staged orchestrator diverged from the classic pipeline"
        );
    }

    let meta = engine.variant(&cfg.expert_variant).unwrap().clone();
    let staged_seqs = (cfg.n_experts * cfg.expert_steps * meta.train_batch) as f64;

    let r = suite.bench(&format!("staged trainer (t={threads})"), || {
        std::hint::black_box(
            run_trainer(&engine, &bpe, &cfg, &TrainerConfig::staged())
                .expect("staged trainer")
                .mixture
                .experts
                .len(),
        );
    });
    println!("    -> {:.1} trained seqs/s", r.throughput(staged_seqs));
    suite.annotate("threads", threads as f64);
    suite.annotate("trained_seqs_per_run", staged_seqs);
    suite.annotate(
        "ledger_total_bytes",
        staged_once.ledger.total_bytes() as f64,
    );
    suite.annotate(
        "ledger_peak_node_bytes",
        staged_once.ledger.peak_node_bytes() as f64,
    );
    suite.annotate(
        "score_allgather_rounds",
        staged_once.ledger.rounds(CommKind::ScoreAllGather) as f64,
    );

    let async_once = run_trainer(&engine, &bpe, &cfg, &TrainerConfig::asynchronous())
        .expect("async trainer");
    let async_seqs: f64 = async_once.segment_sizes.iter().sum::<usize>() as f64;
    let r = suite.bench(&format!("async trainer (t={threads})"), || {
        std::hint::black_box(
            run_trainer(&engine, &bpe, &cfg, &TrainerConfig::asynchronous())
                .expect("async trainer")
                .mixture
                .experts
                .len(),
        );
    });
    println!(
        "    -> {:.1} trained seqs/s ({} seqs/run)",
        r.throughput(async_seqs),
        async_seqs
    );
    suite.annotate("threads", threads as f64);
    suite.annotate("trained_seqs_per_run", async_seqs);
    suite.annotate("ledger_total_bytes", async_once.ledger.total_bytes() as f64);
    suite.annotate(
        "ledger_peak_node_bytes",
        async_once.ledger.peak_node_bytes() as f64,
    );
    suite.annotate(
        "snapshot_broadcast_rounds",
        async_once.ledger.rounds(CommKind::SnapshotBroadcast) as f64,
    );

    println!(
        "\nledger: staged moved {} B (peak node {} B, {} all-gathers); \
         async moved {} B (peak node {} B, {} snapshot broadcasts)",
        staged_once.ledger.total_bytes(),
        staged_once.ledger.peak_node_bytes(),
        staged_once.ledger.rounds(CommKind::ScoreAllGather),
        async_once.ledger.total_bytes(),
        async_once.ledger.peak_node_bytes(),
        async_once.ledger.rounds(CommKind::SnapshotBroadcast),
    );
    suite.write_json().unwrap();
}
