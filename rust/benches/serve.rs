//! Bench: closed-wave vs continuous-batching serve under staggered
//! arrivals, the replica-fleet dispatch layer under hot-expert skew
//! (stub backend — these rows run even without artifacts), plus the
//! TCP/JSONL front-end under open-loop offered load.
//! Each continuous row streams the request set with a fixed
//! inter-arrival gap through the admission scheduler and records
//! steady-state req/s plus p50/p95 queue and total latency (and the
//! scheduler counters). Each socket row drives the same requests over a
//! loopback connection without waiting for responses (open loop) and
//! records client-observed p50/p95/p99 latency and shed counts, so
//! `BENCH_serve.json` carries closed-wave, continuous, and socket rows
//! (one per offered load, plus an overload row) for every PR. Every row
//! asserts the served `(id, expert, nll)` set against the closed-wave
//! reference.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use smalltalk::coordinator::{
    response_triples, run_pipeline, run_server, serve_net, serve_threaded, Mixture,
    MixtureBackend, NetConfig, PipelineConfig, Request, ServeBackend, ServerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::metrics::percentile;
use smalltalk::runtime::{default_threads, locate_artifacts, Engine};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::{env_threads, BenchSuite};
use smalltalk::util::Json;

/// Deterministic model-free backend for the replica-fleet rows (route by
/// first token, NLL = expert * 1000 + token sum — same idiom as
/// `rust/tests/replica.rs`), so the fleet sweep runs on any machine.
/// `expert_param_bytes` makes the rebalance sync audit non-trivial.
struct StubFleetBackend {
    n: usize,
}

impl ServeBackend for StubFleetBackend {
    fn n_experts(&self) -> usize {
        self.n
    }
    fn route(&self, rows: &[&[u32]], _threads: usize) -> anyhow::Result<Vec<usize>> {
        Ok(rows
            .iter()
            .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
            .collect())
    }
    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> anyhow::Result<Vec<f32>> {
        Ok(rows
            .iter()
            .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
            .collect())
    }
    fn expert_param_bytes(&self) -> u64 {
        1 << 20 // a 1 MiB expert: sync bytes are legible in the JSON
    }
}

/// Replica-fleet sweep on the stub backend: req/s and p50/p95/p99 total
/// latency at replicas {1,2,4} x replication {1,2} under 70%-hot-expert
/// skewed arrivals, plus rebalance move counts and sync bytes. Runs even
/// without artifacts, so `BENCH_serve.json` always carries a fleet
/// trajectory point.
fn stub_replica_rows(suite: &mut BenchSuite) {
    let backend = StubFleetBackend { n: 4 };
    let n_req = 240usize;
    // 70% of arrivals hit expert 0; the rest spread over experts 1..=3
    let requests: Vec<Request> = (0..n_req)
        .map(|i| Request {
            id: i as u64,
            tokens: vec![
                if i % 10 < 7 { 0 } else { (1 + i % 3) as u32 },
                i as u32,
                3,
            ],
        })
        .collect();
    let mut reference: Option<Vec<(u64, usize, u32)>> = None;
    for replicas in [1usize, 2, 4] {
        for replication in [1usize, 2] {
            let scfg =
                ServerConfig::continuous(4, 200, 2).with_replicas(replicas, replication, 1);
            let run_once = || {
                run_server(&backend, &scfg, |client| {
                    for req in &requests {
                        client.submit(req.clone());
                    }
                })
                .unwrap()
            };
            let r = suite.bench(
                &format!(
                    "stub replica serve {n_req} skewed requests \
                     (replicas {replicas}, replication {replication})"
                ),
                || {
                    std::hint::black_box(run_once());
                },
            );
            let (responses, stats, ()) = run_once();
            // determinism guard: every fleet shape answers exactly like
            // the replicas=1 reference
            let triples = response_triples(&responses);
            match &reference {
                None => reference = Some(triples),
                Some(sorted_ref) => assert_eq!(
                    &triples, sorted_ref,
                    "fleet ({replicas},{replication}) diverged from replicas=1"
                ),
            }
            let total_us: Vec<f64> =
                responses.iter().map(|x| x.total_micros() as f64).collect();
            suite.annotate("stub_backend", 1.0);
            suite.annotate("replicas", replicas as f64);
            suite.annotate("replication", replication as f64);
            suite.annotate("hot_expert_share", 0.7);
            suite.annotate("req_per_s", r.throughput(n_req as f64));
            suite.annotate("total_p50_us", percentile(&total_us, 50.0));
            suite.annotate("total_p95_us", percentile(&total_us, 95.0));
            suite.annotate("total_p99_us", percentile(&total_us, 99.0));
            suite.annotate("mean_queue_depth", stats.mean_queue_depth());
            if let Some(rep) = &stats.replica {
                let rows = &rep.executed_rows;
                suite.annotate("rebalances", rep.rebalances as f64);
                suite.annotate("placement_moves", rep.moves as f64);
                suite.annotate("replica_sync_bytes", rep.sync_bytes as f64);
                suite.annotate(
                    "executed_rows_min",
                    rows.iter().copied().min().unwrap_or(0) as f64,
                );
                suite.annotate(
                    "executed_rows_max",
                    rows.iter().copied().max().unwrap_or(0) as f64,
                );
            }
        }
    }
}

fn main() {
    let mut suite =
        BenchSuite::new("serve").with_budget(Duration::from_millis(300), Duration::from_secs(3));
    suite.header();

    // ---- replica-fleet rows: stub backend, never artifact-gated ----
    stub_replica_rows(&mut suite);

    let Some(artifacts) = locate_artifacts() else {
        eprintln!(
            "[serve bench] no artifacts/manifest.json — run `make artifacts`; \
             wrote the stub replica rows only"
        );
        suite.write_json().unwrap();
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts: 4,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 8,
        shard_sequences: 128,
        expert_steps: 10,
        prefix_len: 32,
        seed: 3,
        threads: 0,
    };
    eprintln!("[serve bench] preparing mixture ...");
    let result = run_pipeline(&engine, &bpe, &cfg).unwrap();
    let mixture = result.mixture;
    let m = cfg.prefix_len;
    let threads = env_threads().unwrap_or_else(default_threads);
    let batch_size = mixture.expert_meta.eval_batch;

    let n_req = 64usize;
    let requests: Vec<Request> = SequenceGen::new(&bpe, mixture.expert_meta.seq_len, 17)
        .batch(n_req)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: i as u64,
            tokens: s.tokens,
        })
        .collect();

    // ---- closed-wave reference: the whole set as one wave ----
    let reference = serve_threaded(&engine, &mixture, &requests, m, 1).unwrap();
    let r = suite.bench(&format!("closed-wave serve {n_req} requests"), || {
        std::hint::black_box(
            serve_threaded(&engine, &mixture, &requests, m, threads).unwrap(),
        );
    });
    suite.annotate("threads", threads as f64);
    suite.annotate("req_per_s", r.throughput(n_req as f64));
    suite.annotate("mode_closed_wave", 1.0);

    // ---- fused-expert rows: the identical closed wave served with and
    // without the manifest's fused eval_nll_all entries. The fan-out row
    // strips the entries from a cloned expert meta (the exact pre-fused
    // dispatch); per-wave expert launch counts and discarded pad rows
    // come from EngineStats deltas, the wall latency distribution from
    // repeated single-wave runs, and a triples guard pins bit-identity.
    if mixture.expert_meta.fused_eval_buckets().is_empty() {
        eprintln!(
            "[serve bench] manifest has no eval_nll_all entries \
             (re-run `make artifacts` with the fused exporter); skipping fused-expert rows"
        );
    } else {
        let mut stripped = mixture.expert_meta.clone();
        stripped
            .entry_points
            .retain(|e| !e.starts_with("eval_nll_all_"));
        let fallback = Mixture {
            routers: mixture.routers.clone(),
            router_meta: mixture.router_meta.clone(),
            experts: mixture.experts.clone(),
            expert_meta: stripped,
        };
        let sorted_ref = response_triples(&reference);
        let mut wave_ns: Vec<f64> = Vec::new();
        for (mode, mix) in [("fan-out", &fallback), ("fused buckets", &mixture)] {
            let r = suite.bench(
                &format!("closed-wave serve {n_req} requests ({mode} experts)"),
                || {
                    std::hint::black_box(serve_threaded(&engine, mix, &requests, m, 1).unwrap());
                },
            );
            // per-request wall latency distribution over repeated waves
            let lat_us: Vec<f64> = (0..12)
                .map(|_| {
                    let t = Instant::now();
                    std::hint::black_box(serve_threaded(&engine, mix, &requests, m, 1).unwrap());
                    t.elapsed().as_secs_f64() * 1e6 / n_req as f64
                })
                .collect();
            // one instrumented wave for the launch accounting
            let s0 = engine.stats();
            let responses = serve_threaded(&engine, mix, &requests, m, 1).unwrap();
            let d = engine.stats().since(&s0);
            suite.annotate("req_per_s", r.throughput(n_req as f64));
            suite.annotate("wave_p50_us_per_req", percentile(&lat_us, 50.0));
            suite.annotate("wave_p95_us_per_req", percentile(&lat_us, 95.0));
            suite.annotate("executions_per_wave", d.executions as f64);
            suite.annotate("fused_eval_launches_per_wave", d.fused_eval_executions as f64);
            suite.annotate("expert_launches_avoided_per_wave", d.expert_execs_avoided as f64);
            suite.annotate("eval_pad_rows_per_wave", d.eval_pad_rows as f64);
            // score-equality guard: both dispatches answer identically
            assert_eq!(
                response_triples(&responses),
                sorted_ref,
                "closed-wave serve ({mode} experts) diverged from the reference"
            );
            wave_ns.push(r.mean_ns);
        }
        println!(
            "    -> fused vs fan-out experts: {:.2}x waves/s",
            wave_ns[0] / wave_ns[1]
        );
    }

    // ---- continuous rows: one per arrival rate ----
    let backend = MixtureBackend {
        engine: &engine,
        mixture: &mixture,
        prefix_len: m,
    };
    let sorted_ref = response_triples(&reference);

    for gap_us in [0u64, 200, 1000] {
        let scfg = ServerConfig::continuous(batch_size, 500, threads);
        let run_once = || {
            run_server(&backend, &scfg, |client| {
                for req in &requests {
                    if gap_us > 0 {
                        std::thread::sleep(Duration::from_micros(gap_us));
                    }
                    client.submit(req.clone());
                }
            })
            .unwrap()
        };
        let r = suite.bench(
            &format!("continuous serve {n_req} requests (arrival gap {gap_us} µs)"),
            || {
                std::hint::black_box(run_once());
            },
        );
        // one instrumented run for the latency/scheduler annotations
        let (responses, stats, ()) = run_once();
        let queue_us: Vec<f64> = responses.iter().map(|x| x.queue_micros as f64).collect();
        let total_us: Vec<f64> = responses.iter().map(|x| x.total_micros() as f64).collect();
        suite.annotate("threads", threads as f64);
        suite.annotate("arrival_gap_us", gap_us as f64);
        suite.annotate("batch_size", batch_size as f64);
        suite.annotate("max_wait_us", 500.0);
        suite.annotate("req_per_s", r.throughput(n_req as f64));
        suite.annotate("queue_p50_us", percentile(&queue_us, 50.0));
        suite.annotate("queue_p95_us", percentile(&queue_us, 95.0));
        suite.annotate("total_p50_us", percentile(&total_us, 50.0));
        suite.annotate("total_p95_us", percentile(&total_us, 95.0));
        suite.annotate("batches_dispatched", stats.batches_dispatched as f64);
        suite.annotate("linger_batches", stats.linger_batches as f64);
        suite.annotate("slots_refilled", stats.slots_refilled as f64);
        suite.annotate("mean_queue_depth", stats.mean_queue_depth());

        // determinism guard: same (id, expert, nll) set as the sequential
        // closed-wave reference, at every arrival rate
        assert_eq!(
            response_triples(&responses),
            sorted_ref,
            "continuous serve (gap {gap_us} µs) diverged from the closed-wave reference"
        );
    }

    // ---- open-loop socket rows: the TCP front-end under offered load ----
    //
    // One client streams the request set over a loopback socket at a
    // fixed inter-arrival gap without waiting for responses; a reader
    // thread matches response lines back by id and records the
    // client-observed latency (send -> response line). The server runs
    // the identical scheduler config behind `serve_net`.
    let request_lines: Vec<String> = requests
        .iter()
        .map(|r| format!("{{\"id\":{},\"tokens\":{:?}}}\n", r.id, r.tokens))
        .collect();
    let socket_once = |gap_us: u64, high_water: usize| {
        let ncfg = NetConfig {
            listen: "127.0.0.1:0".to_string(),
            max_conns: 0,
            high_water,
            want_tokens: None,
            server: ServerConfig::continuous(batch_size, 500, threads),
        };
        let (tx, rx) = mpsc::channel();
        let send_t: Vec<Mutex<Option<Instant>>> =
            requests.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            let (b, st) = (&backend, &send_t);
            let server = s.spawn(move || serve_net(b, &ncfg, None, move |h| drop(tx.send(h))));
            let h = rx.recv().expect("socket server never became ready");
            let conn = TcpStream::connect(h.addr()).unwrap();
            let mut wconn = conn.try_clone().unwrap();
            let n = requests.len();
            let reader = s.spawn(move || {
                let mut r = BufReader::new(conn);
                let mut trip: Vec<(u64, usize, u32)> = Vec::new();
                let mut lat_us: Vec<f64> = Vec::new();
                let mut shed = 0usize;
                let mut line = String::new();
                while trip.len() + shed < n {
                    line.clear();
                    if r.read_line(&mut line).unwrap() == 0 {
                        panic!("server closed before answering every request");
                    }
                    let now = Instant::now();
                    let j = Json::parse(line.trim_end()).unwrap();
                    let id = j.get("id").and_then(Json::as_f64).expect("id") as usize;
                    match j.get("code").and_then(Json::as_f64) {
                        None => {
                            let sent = st[id].lock().unwrap().expect("response before send");
                            lat_us.push((now - sent).as_secs_f64() * 1e6);
                            let expert = j.get("expert").and_then(Json::as_usize).unwrap();
                            // f32 Display -> f64 parse -> f32 cast is exact
                            let nll = j.get("nll").and_then(Json::as_f64).unwrap() as f32;
                            trip.push((id as u64, expert, nll.to_bits()));
                        }
                        Some(code) if code == 429.0 => shed += 1,
                        Some(code) => panic!("unexpected error line ({code}): {line}"),
                    }
                }
                (trip, lat_us, shed)
            });
            for (i, line) in request_lines.iter().enumerate() {
                if gap_us > 0 {
                    std::thread::sleep(Duration::from_micros(gap_us));
                }
                *st[i].lock().unwrap() = Some(Instant::now());
                wconn.write_all(line.as_bytes()).unwrap();
            }
            let (trip, lat_us, shed) = reader.join().unwrap();
            drop(wconn);
            h.shutdown();
            let (stats, report) = server.join().unwrap().unwrap();
            (trip, lat_us, shed, stats, report)
        })
    };

    for gap_us in [0u64, 200, 1000] {
        let r = suite.bench(
            &format!("socket serve {n_req} requests (open loop, gap {gap_us} µs)"),
            || {
                std::hint::black_box(socket_once(gap_us, 1 << 20));
            },
        );
        let (trip, lat_us, shed, stats, report) = socket_once(gap_us, 1 << 20);
        assert_eq!(shed, 0, "no shedding expected below the high-water mark");
        let mut sorted = trip;
        sorted.sort_unstable();
        // determinism guard: socket-served set == in-process closed wave
        assert_eq!(
            sorted, sorted_ref,
            "socket serve (gap {gap_us} µs) diverged from the closed-wave reference"
        );
        suite.annotate("threads", threads as f64);
        suite.annotate("arrival_gap_us", gap_us as f64);
        suite.annotate(
            "offered_req_per_s",
            if gap_us == 0 { 0.0 } else { 1e6 / gap_us as f64 },
        );
        suite.annotate("req_per_s", r.throughput(n_req as f64));
        suite.annotate("shed", shed as f64);
        suite.annotate("ok_lines", report.ok_lines as f64);
        suite.annotate("client_p50_us", percentile(&lat_us, 50.0));
        suite.annotate("client_p95_us", percentile(&lat_us, 95.0));
        suite.annotate("client_p99_us", percentile(&lat_us, 99.0));
        suite.annotate("mean_queue_depth", stats.mean_queue_depth());
    }

    // overload row: full-rate flood into a tiny high-water mark — the
    // shed count lands in the JSON, every request still gets exactly one
    // line, and everything served is bit-correct
    {
        let r = suite.bench(
            &format!("socket serve {n_req} requests (overload, high-water 8)"),
            || {
                std::hint::black_box(socket_once(0, 8));
            },
        );
        let (trip, lat_us, shed, stats, report) = socket_once(0, 8);
        assert_eq!(
            trip.len() + shed,
            n_req,
            "every request gets exactly one response line"
        );
        for t in &trip {
            assert!(
                sorted_ref.binary_search(t).is_ok(),
                "served triple {t:?} is not in the reference set"
            );
        }
        assert_eq!(stats.shed, report.shed_lines, "wire sheds == scheduler sheds");
        suite.annotate("threads", threads as f64);
        suite.annotate("high_water", 8.0);
        suite.annotate("req_per_s", r.throughput(n_req as f64));
        suite.annotate("shed", shed as f64);
        suite.annotate("ok_lines", report.ok_lines as f64);
        suite.annotate("client_p50_us", percentile(&lat_us, 50.0));
        suite.annotate("client_p95_us", percentile(&lat_us, 95.0));
        suite.annotate("client_p99_us", percentile(&lat_us, 99.0));
    }

    suite.write_json().unwrap();
}
