//! Bench: closed-wave vs continuous-batching serve under staggered
//! arrivals. Each continuous row streams the request set with a fixed
//! inter-arrival gap through the admission scheduler and records
//! steady-state req/s plus p50/p95 queue and total latency (and the
//! scheduler counters), so `BENCH_serve.json` carries a closed-wave row
//! and one continuous row per arrival rate for every PR.

use std::time::Duration;

use smalltalk::coordinator::{
    response_triples, run_pipeline, run_server, serve_threaded, MixtureBackend, PipelineConfig,
    Request, ServerConfig,
};
use smalltalk::data::corpus::Corpus;
use smalltalk::data::SequenceGen;
use smalltalk::metrics::percentile;
use smalltalk::runtime::{default_threads, locate_artifacts, Engine};
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::{env_threads, BenchSuite};

fn main() {
    let Some(artifacts) = locate_artifacts() else {
        eprintln!("[serve bench] no artifacts/manifest.json — run `make artifacts`; skipping");
        return;
    };
    let engine = Engine::new(artifacts).expect("loading artifacts");
    let corpus = Corpus::generate(60, 400, 42, None);
    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();

    let cfg = PipelineConfig {
        router_variant: "router_micro".into(),
        expert_variant: "expert_sm".into(),
        n_experts: 4,
        em_rounds: 2,
        em_chunk: 96,
        em_steps_per_round: 8,
        shard_sequences: 128,
        expert_steps: 10,
        prefix_len: 32,
        seed: 3,
        threads: 0,
    };
    eprintln!("[serve bench] preparing mixture ...");
    let result = run_pipeline(&engine, &bpe, &cfg).unwrap();
    let mixture = result.mixture;
    let m = cfg.prefix_len;
    let threads = env_threads().unwrap_or_else(default_threads);
    let batch_size = mixture.expert_meta.eval_batch;

    let n_req = 64usize;
    let requests: Vec<Request> = SequenceGen::new(&bpe, mixture.expert_meta.seq_len, 17)
        .batch(n_req)
        .into_iter()
        .enumerate()
        .map(|(i, s)| Request {
            id: i as u64,
            tokens: s.tokens,
        })
        .collect();

    let mut suite =
        BenchSuite::new("serve").with_budget(Duration::from_millis(300), Duration::from_secs(3));
    suite.header();

    // ---- closed-wave reference: the whole set as one wave ----
    let reference = serve_threaded(&engine, &mixture, &requests, m, 1).unwrap();
    let r = suite.bench(&format!("closed-wave serve {n_req} requests"), || {
        std::hint::black_box(
            serve_threaded(&engine, &mixture, &requests, m, threads).unwrap(),
        );
    });
    suite.annotate("threads", threads as f64);
    suite.annotate("req_per_s", r.throughput(n_req as f64));
    suite.annotate("mode_closed_wave", 1.0);

    // ---- continuous rows: one per arrival rate ----
    let backend = MixtureBackend {
        engine: &engine,
        mixture: &mixture,
        prefix_len: m,
    };
    let sorted_ref = response_triples(&reference);

    for gap_us in [0u64, 200, 1000] {
        let scfg = ServerConfig::continuous(batch_size, 500, threads);
        let run_once = || {
            run_server(&backend, &scfg, |client| {
                for req in &requests {
                    if gap_us > 0 {
                        std::thread::sleep(Duration::from_micros(gap_us));
                    }
                    client.submit(req.clone());
                }
            })
            .unwrap()
        };
        let r = suite.bench(
            &format!("continuous serve {n_req} requests (arrival gap {gap_us} µs)"),
            || {
                std::hint::black_box(run_once());
            },
        );
        // one instrumented run for the latency/scheduler annotations
        let (responses, stats, ()) = run_once();
        let queue_us: Vec<f64> = responses.iter().map(|x| x.queue_micros as f64).collect();
        let total_us: Vec<f64> = responses.iter().map(|x| x.total_micros() as f64).collect();
        suite.annotate("threads", threads as f64);
        suite.annotate("arrival_gap_us", gap_us as f64);
        suite.annotate("batch_size", batch_size as f64);
        suite.annotate("max_wait_us", 500.0);
        suite.annotate("req_per_s", r.throughput(n_req as f64));
        suite.annotate("queue_p50_us", percentile(&queue_us, 50.0));
        suite.annotate("queue_p95_us", percentile(&queue_us, 95.0));
        suite.annotate("total_p50_us", percentile(&total_us, 50.0));
        suite.annotate("total_p95_us", percentile(&total_us, 95.0));
        suite.annotate("batches_dispatched", stats.batches_dispatched as f64);
        suite.annotate("linger_batches", stats.linger_batches as f64);
        suite.annotate("slots_refilled", stats.slots_refilled as f64);
        suite.annotate("mean_queue_depth", stats.mean_queue_depth());

        // determinism guard: same (id, expert, nll) set as the sequential
        // closed-wave reference, at every arrival rate
        assert_eq!(
            response_triples(&responses),
            sorted_ref,
            "continuous serve (gap {gap_us} µs) diverged from the closed-wave reference"
        );
    }

    suite.write_json().unwrap();
}
