//! Bench + table: §A.4 communication overhead, mixture vs DDP.
//!
//! Regenerates the paper's closed-form numbers and measures the ledger's
//! own bookkeeping cost (which must be negligible next to training).

use smalltalk::coordinator::comm::{
    ddp_bytes_per_step, router_bytes_per_comm, router_comm_rounds, CommLedger,
};
use smalltalk::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("comm_overhead");
    suite.header();

    // annotate each row with the bytes the modeled exchange would move, so
    // the JSON output carries (modeled) transfer volume next to ledger cost
    let allgather_bytes = {
        let mut l = CommLedger::default();
        for r in 0..100 {
            l.record_score_allgather(32, 43_945, r);
        }
        l.total_bytes() as f64
    };
    suite.bench("ledger: 100 allgathers x 32 nodes", || {
        let mut l = CommLedger::default();
        for r in 0..100 {
            l.record_score_allgather(32, 43_945, r);
        }
        std::hint::black_box(l.peak_node_bytes());
    });
    suite.annotate("modeled_transfer_bytes", allgather_bytes);

    let ddp_bytes = {
        let mut l = CommLedger::default();
        for s in 0..512 {
            l.record_ddp_allreduce(32, 1_300_000_000, s);
        }
        l.total_bytes() as f64
    };
    suite.bench("ledger: 512-step DDP x 32 nodes", || {
        let mut l = CommLedger::default();
        for s in 0..512 {
            l.record_ddp_allreduce(32, 1_300_000_000, s);
        }
        std::hint::black_box(l.total_bytes());
    });
    suite.annotate("modeled_transfer_bytes", ddp_bytes);

    println!("\n§A.4 closed forms (paper scale):");
    println!(
        "  router comm rounds (128k steps, B=32, S=1024, T=45M): {}",
        router_comm_rounds(128_000, 1024, 32, 45_000_000)
    );
    println!(
        "  bytes per router per round (E=32): {:.3} MB",
        router_bytes_per_comm(45_000_000, 32, 1024) as f64 / 1e6
    );
    println!(
        "  DDP 1.3B gradient all-reduce: {:.1} GB per node per step",
        ddp_bytes_per_step(1_300_000_000) as f64 / 1e9
    );
    let mix_total = 94.0 * 5.625e6;
    let ddp_total = 1_024_000.0 * 10.4e9;
    println!(
        "  total per node over training: mixture {:.1} MB vs DDP {:.1} PB ({}x)",
        mix_total / 1e6,
        ddp_total / 1e15,
        (ddp_total / mix_total) as u64
    );

    suite.write_json().unwrap();
}
