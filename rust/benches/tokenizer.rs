//! Bench: BPE tokenizer — encode throughput feeds every pipeline stage.

use smalltalk::data::corpus::Corpus;
use smalltalk::tokenizer::BpeTrainer;
use smalltalk::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("tokenizer");
    suite.header();

    let corpus = Corpus::generate(120, 500, 42, None);
    let train_docs: Vec<&str> = corpus.texts().collect();

    let r = suite.bench("train vocab=512 (~60KB corpus)", || {
        std::hint::black_box(
            BpeTrainer::new(512)
                .train(train_docs.iter().copied())
                .unwrap(),
        );
    });
    println!("    -> {:.2}s per training", r.mean_ns * 1e-9);

    let bpe = BpeTrainer::new(512).train(corpus.texts()).unwrap();
    let doc = Corpus::generate(1, 4000, 7, None).docs.pop().unwrap().text;
    let bytes = doc.len() as f64;

    // seed encoder (full rescan, O(n²·merges)) as the before/after baseline
    let r_ref = suite.bench("encode 4KB document (seed O(n^2) rescan)", || {
        std::hint::black_box(bpe.encode_reference(&doc));
    });
    println!("    -> {:.2} MB/s", r_ref.throughput(bytes) / 1e6);

    let r = suite.bench("encode 4KB document (heap single-pass)", || {
        std::hint::black_box(bpe.encode(&doc));
    });
    println!(
        "    -> {:.2} MB/s ({:.1}x vs seed encoder)",
        r.throughput(bytes) / 1e6,
        r_ref.mean_ns / r.mean_ns
    );
    assert_eq!(
        bpe.encode(&doc),
        bpe.encode_reference(&doc),
        "heap encoder diverged from reference on the bench document"
    );

    let ids = bpe.encode(&doc);
    let r = suite.bench("decode 4KB document", || {
        std::hint::black_box(bpe.decode(&ids));
    });
    println!("    -> {:.2} MB/s", r.throughput(bytes) / 1e6);

    suite.write_json().unwrap();
}
