//! Experiment configuration: JSON files under `configs/` + CLI overrides.
//!
//! A config fully describes a run: corpus/tokenizer settings, the model
//! variants, the mixture shape, and the training budgets. `smalltalk`
//! subcommands start from [`ExperimentConfig::default()`], optionally load
//! `--config <file.json>`, then apply `--key value` overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::pipeline::PipelineConfig;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Everything a run needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Where AOT artifacts live.
    pub artifacts_dir: String,
    /// BPE vocabulary size (must match the manifest's `vocab`).
    pub vocab: usize,
    /// Documents used to train the tokenizer.
    pub tokenizer_docs: usize,
    /// Target bytes per tokenizer-training document.
    pub tokenizer_doc_bytes: usize,
    /// Pipeline (mixture) settings.
    pub pipeline: PipelineConfig,
    /// Held-out sequences for perplexity eval.
    pub eval_sequences: usize,
    /// Downstream tasks per domain.
    pub tasks_per_domain: usize,
    /// Options per downstream task.
    pub task_options: usize,
    /// Master seed.
    pub seed: u64,
    /// Output directory for results.
    pub results_dir: String,
    /// Continuous serving: per-expert dispatch batch size (0 = the expert
    /// variant's compiled `eval_batch`).
    pub serve_batch_size: usize,
    /// Continuous serving: linger before a partial expert batch is
    /// dispatched anyway, in microseconds (`u64::MAX` disables).
    pub serve_max_wait_us: u64,
    /// Wire serving (`serve --listen`): max simultaneous connections
    /// (0 = unlimited); further connects get a structured 503 line.
    pub net_max_conns: usize,
    /// Wire serving: arrival-queue high-water mark — requests arriving
    /// past it are shed with a structured 429-style line instead of
    /// queueing unboundedly.
    pub net_high_water: usize,
    /// Continuous serving: engine replicas behind the dispatch queue
    /// (1 = the single-queue bit-exact reference path).
    pub serve_replicas: usize,
    /// Continuous serving: placement-copy floor for hot experts; demand
    /// can escalate past it, up to one copy per replica.
    pub serve_replication: usize,
    /// Continuous serving: admission waves between online placement
    /// rebalances from the route histogram (0 = never rebalance).
    pub serve_rebalance_every: usize,
    /// Train with the asynchronous (barrier-free, snapshot-routed)
    /// orchestrator instead of the staged pipeline (`--async`).
    pub train_async: bool,
    /// Trainer-node checkpoint directory (empty = checkpointing off).
    pub checkpoint_dir: String,
    /// Checkpoint every N expert steps (0 = final checkpoint only).
    pub checkpoint_every: usize,
    /// Resume trainer nodes from their checkpoints (`--resume`).
    pub resume: bool,
    /// Async: broadcast a router snapshot every N EM rounds (the final
    /// round always broadcasts).
    pub snapshot_every: usize,
    /// Async: JSON fault-plan spec for the elastic chaos harness
    /// (`--chaos-spec`; empty = no injected faults).
    pub chaos_spec: String,
    /// Async: schedule the last trainer node to leave at this local step
    /// (`--leave-after`; 0 = nobody leaves).
    pub leave_after: usize,
    /// Async: re-adopt the departed seat once the fleet reaches this many
    /// total steps (`--join-after`; 0 = no adoption).
    pub join_after: usize,
    /// Async: partition expert seats across this many snapshot-store
    /// fault domains (`--shards`; 1 = the single-store elastic trainer).
    pub shards: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            artifacts_dir: "artifacts".into(),
            vocab: 512,
            tokenizer_docs: 120,
            tokenizer_doc_bytes: 500,
            pipeline: PipelineConfig::default(),
            eval_sequences: 128,
            tasks_per_domain: 12,
            task_options: 4,
            seed: 1234,
            results_dir: "results".into(),
            serve_batch_size: 0,
            serve_max_wait_us: 2000,
            net_max_conns: 64,
            net_high_water: 1024,
            serve_replicas: 1,
            serve_replication: 1,
            serve_rebalance_every: 0,
            train_async: false,
            checkpoint_dir: String::new(),
            checkpoint_every: 0,
            resume: false,
            snapshot_every: 1,
            chaos_spec: String::new(),
            leave_after: 0,
            join_after: 0,
            shards: 1,
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file (flat keys; missing keys keep defaults).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let j = Json::parse(&text).context("parsing config json")?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_json(&j);
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) {
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(String::from);
        let u = |k: &str| j.get(k).and_then(Json::as_usize);
        if let Some(v) = s("artifacts_dir") {
            self.artifacts_dir = v;
        }
        if let Some(v) = s("results_dir") {
            self.results_dir = v;
        }
        if let Some(v) = u("vocab") {
            self.vocab = v;
        }
        if let Some(v) = u("tokenizer_docs") {
            self.tokenizer_docs = v;
        }
        if let Some(v) = u("tokenizer_doc_bytes") {
            self.tokenizer_doc_bytes = v;
        }
        if let Some(v) = u("eval_sequences") {
            self.eval_sequences = v;
        }
        if let Some(v) = u("tasks_per_domain") {
            self.tasks_per_domain = v;
        }
        if let Some(v) = u("task_options") {
            self.task_options = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_i64) {
            self.seed = v as u64;
            self.pipeline.seed = v as u64;
        }
        if let Some(v) = s("router_variant") {
            self.pipeline.router_variant = v;
        }
        if let Some(v) = s("expert_variant") {
            self.pipeline.expert_variant = v;
        }
        if let Some(v) = u("n_experts") {
            self.pipeline.n_experts = v;
        }
        if let Some(v) = u("em_rounds") {
            self.pipeline.em_rounds = v;
        }
        if let Some(v) = u("em_chunk") {
            self.pipeline.em_chunk = v;
        }
        if let Some(v) = u("em_steps_per_round") {
            self.pipeline.em_steps_per_round = v;
        }
        if let Some(v) = u("shard_sequences") {
            self.pipeline.shard_sequences = v;
        }
        if let Some(v) = u("expert_steps") {
            self.pipeline.expert_steps = v;
        }
        if let Some(v) = u("prefix_len") {
            self.pipeline.prefix_len = v;
        }
        if let Some(v) = u("threads") {
            self.pipeline.threads = v;
        }
        if let Some(v) = u("serve_batch_size") {
            self.serve_batch_size = v;
        }
        // as_usize (not as_i64): a negative value must be ignored, not
        // wrapped into a near-MAX linger that silently disables the timer
        if let Some(v) = u("serve_max_wait_us") {
            self.serve_max_wait_us = v as u64;
        }
        if let Some(v) = u("net_max_conns") {
            self.net_max_conns = v;
        }
        if let Some(v) = u("net_high_water") {
            self.net_high_water = v;
        }
        if let Some(v) = u("serve_replicas") {
            self.serve_replicas = v;
        }
        if let Some(v) = u("serve_replication") {
            self.serve_replication = v;
        }
        if let Some(v) = u("serve_rebalance_every") {
            self.serve_rebalance_every = v;
        }
        if let Some(v) = j.get("train_async").and_then(Json::as_bool) {
            self.train_async = v;
        }
        if let Some(v) = s("checkpoint_dir") {
            self.checkpoint_dir = v;
        }
        if let Some(v) = u("checkpoint_every") {
            self.checkpoint_every = v;
        }
        if let Some(v) = j.get("resume").and_then(Json::as_bool) {
            self.resume = v;
        }
        if let Some(v) = u("snapshot_every") {
            self.snapshot_every = v;
        }
        if let Some(v) = s("chaos_spec") {
            self.chaos_spec = v;
        }
        if let Some(v) = u("leave_after") {
            self.leave_after = v;
        }
        if let Some(v) = u("join_after") {
            self.join_after = v;
        }
        if let Some(v) = u("shards") {
            self.shards = v;
        }
    }

    /// Apply `--key value` CLI overrides (same keys as the JSON form).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("artifacts-dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = args.get("results-dir") {
            self.results_dir = v.to_string();
        }
        if let Some(v) = args.get("router") {
            self.pipeline.router_variant = v.to_string();
        }
        if let Some(v) = args.get("expert") {
            self.pipeline.expert_variant = v.to_string();
        }
        self.pipeline.n_experts = args.get_usize("experts", self.pipeline.n_experts)?;
        self.pipeline.em_rounds = args.get_usize("em-rounds", self.pipeline.em_rounds)?;
        self.pipeline.em_chunk = args.get_usize("em-chunk", self.pipeline.em_chunk)?;
        self.pipeline.em_steps_per_round =
            args.get_usize("em-steps", self.pipeline.em_steps_per_round)?;
        self.pipeline.shard_sequences =
            args.get_usize("shard-sequences", self.pipeline.shard_sequences)?;
        self.pipeline.expert_steps = args.get_usize("expert-steps", self.pipeline.expert_steps)?;
        self.pipeline.prefix_len = args.get_usize("prefix", self.pipeline.prefix_len)?;
        // worker threads for expert/router group fan-out (0 = auto)
        self.pipeline.threads = args.get_usize("threads", self.pipeline.threads)?;
        // continuous-serving knobs (also per-command `serve` overrides)
        self.serve_batch_size = args.get_usize("batch-size", self.serve_batch_size)?;
        self.serve_max_wait_us = args.get_u64("max-wait-us", self.serve_max_wait_us)?;
        // wire front-end knobs (only read by `serve --listen`)
        self.net_max_conns = args.get_usize("max-conns", self.net_max_conns)?;
        self.net_high_water = args.get_usize("high-water", self.net_high_water)?;
        self.serve_replicas = args.get_usize("replicas", self.serve_replicas)?;
        self.serve_replication = args.get_usize("replication", self.serve_replication)?;
        self.serve_rebalance_every =
            args.get_usize("rebalance-every", self.serve_rebalance_every)?;
        self.eval_sequences = args.get_usize("eval-sequences", self.eval_sequences)?;
        self.tasks_per_domain = args.get_usize("tasks-per-domain", self.tasks_per_domain)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.pipeline.seed = self.seed;
        // async-trainer knobs: --async / --resume are flags, the rest
        // take values (flags only switch ON — a config file's setting is
        // not silently reverted by their absence on the command line)
        if args.flag("async") {
            self.train_async = true;
        }
        if args.flag("resume") {
            self.resume = true;
        }
        if let Some(v) = args.get("checkpoint-dir") {
            self.checkpoint_dir = v.to_string();
        }
        self.checkpoint_every = args.get_usize("checkpoint-every", self.checkpoint_every)?;
        self.snapshot_every = args.get_usize("snapshot-every", self.snapshot_every)?;
        if let Some(v) = args.get("chaos-spec") {
            self.chaos_spec = v.to_string();
        }
        self.leave_after = args.get_usize("leave-after", self.leave_after)?;
        self.join_after = args.get_usize("join-after", self.join_after)?;
        self.shards = args.get_usize("shards", self.shards)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
            ("results_dir", Json::str(self.results_dir.clone())),
            ("vocab", Json::num(self.vocab as f64)),
            ("tokenizer_docs", Json::num(self.tokenizer_docs as f64)),
            (
                "tokenizer_doc_bytes",
                Json::num(self.tokenizer_doc_bytes as f64),
            ),
            ("eval_sequences", Json::num(self.eval_sequences as f64)),
            ("tasks_per_domain", Json::num(self.tasks_per_domain as f64)),
            ("task_options", Json::num(self.task_options as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "router_variant",
                Json::str(self.pipeline.router_variant.clone()),
            ),
            (
                "expert_variant",
                Json::str(self.pipeline.expert_variant.clone()),
            ),
            ("n_experts", Json::num(self.pipeline.n_experts as f64)),
            ("em_rounds", Json::num(self.pipeline.em_rounds as f64)),
            ("em_chunk", Json::num(self.pipeline.em_chunk as f64)),
            (
                "em_steps_per_round",
                Json::num(self.pipeline.em_steps_per_round as f64),
            ),
            (
                "shard_sequences",
                Json::num(self.pipeline.shard_sequences as f64),
            ),
            ("expert_steps", Json::num(self.pipeline.expert_steps as f64)),
            ("prefix_len", Json::num(self.pipeline.prefix_len as f64)),
            ("threads", Json::num(self.pipeline.threads as f64)),
            ("serve_batch_size", Json::num(self.serve_batch_size as f64)),
            ("serve_max_wait_us", Json::num(self.serve_max_wait_us as f64)),
            ("net_max_conns", Json::num(self.net_max_conns as f64)),
            ("net_high_water", Json::num(self.net_high_water as f64)),
            ("serve_replicas", Json::num(self.serve_replicas as f64)),
            ("serve_replication", Json::num(self.serve_replication as f64)),
            (
                "serve_rebalance_every",
                Json::num(self.serve_rebalance_every as f64),
            ),
            ("train_async", Json::Bool(self.train_async)),
            ("checkpoint_dir", Json::str(self.checkpoint_dir.clone())),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("resume", Json::Bool(self.resume)),
            ("snapshot_every", Json::num(self.snapshot_every as f64)),
            ("chaos_spec", Json::str(self.chaos_spec.clone())),
            ("leave_after", Json::num(self.leave_after as f64)),
            ("join_after", Json::num(self.join_after as f64)),
            ("shards", Json::num(self.shards as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_consistent() {
        let c = ExperimentConfig::default();
        assert_eq!(c.seed, c.pipeline.seed);
        assert!(c.pipeline.n_experts >= 2);
    }

    #[test]
    fn json_roundtrip_preserves_fields() {
        let mut c = ExperimentConfig::default();
        c.pipeline.n_experts = 8;
        c.seed = 99;
        c.pipeline.seed = 99;
        c.pipeline.threads = 6;
        c.serve_batch_size = 16;
        c.serve_max_wait_us = 750;
        c.net_max_conns = 9;
        c.net_high_water = 333;
        c.serve_replicas = 4;
        c.serve_replication = 2;
        c.serve_rebalance_every = 6;
        c.train_async = true;
        c.checkpoint_dir = "ckpts".into();
        c.checkpoint_every = 25;
        c.resume = true;
        c.snapshot_every = 2;
        c.chaos_spec = "plans/faults.json".into();
        c.leave_after = 12;
        c.join_after = 40;
        c.shards = 3;
        let j = c.to_json();
        let mut c2 = ExperimentConfig::default();
        c2.apply_json(&j);
        assert_eq!(c2.pipeline.n_experts, 8);
        assert_eq!(c2.seed, 99);
        assert_eq!(c2.pipeline.seed, 99);
        assert_eq!(c2.pipeline.threads, 6);
        assert_eq!(c2.serve_batch_size, 16);
        assert_eq!(c2.serve_max_wait_us, 750);
        assert_eq!(c2.net_max_conns, 9);
        assert_eq!(c2.net_high_water, 333);
        assert_eq!(c2.serve_replicas, 4);
        assert_eq!(c2.serve_replication, 2);
        assert_eq!(c2.serve_rebalance_every, 6);
        assert!(c2.train_async);
        assert_eq!(c2.checkpoint_dir, "ckpts");
        assert_eq!(c2.checkpoint_every, 25);
        assert!(c2.resume);
        assert_eq!(c2.snapshot_every, 2);
        assert_eq!(c2.chaos_spec, "plans/faults.json");
        assert_eq!(c2.leave_after, 12);
        assert_eq!(c2.join_after, 40);
        assert_eq!(c2.shards, 3);
    }

    #[test]
    fn cli_overrides_apply() {
        let raw: Vec<String> = [
            "--experts=6",
            "--expert-steps=10",
            "--seed=7",
            "--threads=3",
            "--batch-size=8",
            "--max-wait-us=1500",
            "--max-conns=3",
            "--high-water=77",
            "--async",
            "--resume",
            "--checkpoint-dir=ck",
            "--checkpoint-every=5",
            "--snapshot-every=2",
            "--chaos-spec=faults.json",
            "--leave-after=9",
            "--join-after=30",
            "--shards=2",
            "--replicas=4",
            "--replication=2",
            "--rebalance-every=12",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &[]).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.pipeline.n_experts, 6);
        assert_eq!(c.pipeline.expert_steps, 10);
        assert_eq!(c.pipeline.seed, 7);
        assert_eq!(c.pipeline.threads, 3);
        assert_eq!(c.serve_batch_size, 8);
        assert_eq!(c.serve_max_wait_us, 1500);
        assert_eq!(c.net_max_conns, 3);
        assert_eq!(c.net_high_water, 77);
        assert_eq!(c.serve_replicas, 4);
        assert_eq!(c.serve_replication, 2);
        assert_eq!(c.serve_rebalance_every, 12);
        assert!(c.train_async);
        assert!(c.resume);
        assert_eq!(c.checkpoint_dir, "ck");
        assert_eq!(c.checkpoint_every, 5);
        assert_eq!(c.snapshot_every, 2);
        assert_eq!(c.chaos_spec, "faults.json");
        assert_eq!(c.leave_after, 9);
        assert_eq!(c.join_after, 30);
        assert_eq!(c.shards, 2);
    }

    #[test]
    fn from_file_missing_is_error() {
        assert!(ExperimentConfig::from_file("/nope/missing.json").is_err());
    }
}
