//! Batched prefix scoring: the score matrix `nll[seq][router]` behind
//! every assignment (Eq. 4). Pads the tail batch to the compiled batch
//! shape (repeating the last row *by reference* — no clones) and discards
//! the padding rows.
//!
//! Transfer discipline: each token batch is uploaded to the device **once**
//! and fanned across all E routers, and router parameters are served from
//! the engine's `(state, version)` device cache — so a B-batch × E-router
//! score matrix moves B token uploads instead of the seed path's B×E token
//! + B×E parameter uploads.
//!
//! Concurrency: the E routers score independently (each touches only its
//! own `TrainState` and the `Sync` engine), so
//! [`score_matrix_rows_threaded`] uploads token batches in bounded
//! windows and fans one task per router per window across a worker pool —
//! the pool spawns once per window (not once per batch) and device
//! residency stays bounded no matter how many rows are scored. Results
//! are written back by router index, so the parallel path is
//! bit-identical to the sequential one.

use anyhow::Result;

use crate::data::Sequence;
use crate::runtime::engine::tokens_literal;
use crate::runtime::parallel::{default_threads, run_fallible};
use crate::runtime::{DeviceBuffer, Engine, TrainState, VariantMeta};

/// `(start, real_rows)` spans that tile `n` items into `bs`-sized batches;
/// the final span may be short (the caller pads it to the compiled shape).
pub(crate) fn batch_spans(n: usize, bs: usize) -> Vec<(usize, usize)> {
    let mut spans = Vec::with_capacity(n.div_ceil(bs.max(1)));
    let mut start = 0;
    while start < n {
        let real = (n - start).min(bs);
        spans.push((start, real));
        start += real;
    }
    spans
}

/// Pad `batch` to `bs` rows by repeating the last row **by reference**
/// (no token clones); the caller discards the padding rows' outputs.
/// No-op on an empty batch or one already at/above `bs`.
pub(crate) fn pad_batch<'a>(mut batch: Vec<&'a [u32]>, bs: usize) -> Vec<&'a [u32]> {
    if let Some(&pad) = batch.last() {
        while batch.len() < bs {
            batch.push(pad);
        }
    }
    batch
}

/// Owned `m`-token prefix of a row that is not already exactly `m` long:
/// longer rows are truncated, shorter rows are right-padded by repeating
/// their last token (an empty row pads with token 0). Short requests —
/// rows with fewer than `m` tokens — therefore score under the compiled
/// `prefix_nll_{m}` shape instead of erroring on the literal build.
pub(crate) fn pad_prefix_row(row: &[u32], m: usize) -> Vec<u32> {
    let take = m.min(row.len());
    let mut out = Vec::with_capacity(m);
    out.extend_from_slice(&row[..take]);
    let fill = row.last().copied().unwrap_or(0);
    out.resize(m, fill);
    out
}

/// Score all sequences' `m`-token prefixes under every router.
/// Returns `nll[seq][router]` (summed prefix NLL — lower is better).
/// Routers fan across [`default_threads`] workers; use
/// [`score_matrix_threaded`] for an explicit worker count.
pub fn score_matrix(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    score_matrix_threaded(engine, routers, meta, seqs, m, default_threads())
}

/// [`score_matrix`] with an explicit worker count for the per-batch
/// router fan-out. `threads <= 1` is the sequential reference path.
pub fn score_matrix_threaded(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(m)).collect();
    score_matrix_rows_threaded(engine, routers, meta, &rows, m, threads)
}

/// [`score_matrix`] over borrowed token rows (each row is the `m`-token
/// prefix to score; rows of any other length are normalized via
/// [`pad_prefix_row`]). This is the allocation-light entry the serving
/// loop uses — requests never get wrapped into `Sequence` clones.
/// Routers are fanned across [`default_threads`] workers; use
/// [`score_matrix_rows_threaded`] for an explicit worker count.
pub fn score_matrix_rows(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    score_matrix_rows_threaded(engine, routers, meta, rows, m, default_threads())
}

/// [`score_matrix_rows`] with an explicit worker count for the per-batch
/// router fan-out. `threads <= 1` is the sequential reference path;
/// results are bit-identical at any worker count.
pub fn score_matrix_rows_threaded(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    // normalize row lengths: owned padded/truncated copies only where a
    // row is not already exactly m tokens
    let normalized: Vec<Option<Vec<u32>>> = rows
        .iter()
        .map(|r| (r.len() != m).then(|| pad_prefix_row(r, m)))
        .collect();
    let rows: Vec<&[u32]> = rows
        .iter()
        .zip(&normalized)
        .map(|(r, p)| p.as_deref().unwrap_or(r))
        .collect();

    // Spans are processed in fixed-size windows: a window's token batches
    // upload once up front (each shared device-resident by all E routers)
    // and are dropped before the next window starts, so peak device
    // residency is bounded at SPAN_WINDOW * prefix_batch rows no matter
    // how large the scored corpus is, while the worker pool spawns once
    // per window — not once per span. Each router scores every span of
    // the window against its own state, so results are bit-identical at
    // any worker count.
    const SPAN_WINDOW: usize = 16;
    let bs = meta.prefix_batch;
    let mut out = vec![vec![0.0f32; routers.len()]; rows.len()];
    for window in batch_spans(rows.len(), bs).chunks(SPAN_WINDOW) {
        let uploads: Vec<DeviceBuffer> = window
            .iter()
            .map(|&(start, real)| {
                let batch = pad_batch(rows[start..start + real].to_vec(), bs);
                engine.upload(&tokens_literal(&batch, m)?)
            })
            .collect::<Result<_>>()?;
        let tasks: Vec<_> = routers
            .iter()
            .map(|router| {
                let uploads = &uploads;
                move || -> Result<Vec<Vec<f32>>> {
                    uploads
                        .iter()
                        .map(|tokens| router.prefix_nll_device(engine, tokens, meta, m))
                        .collect()
                }
            })
            .collect();
        for (r, span_scores) in run_fallible(tasks, threads)?.into_iter().enumerate() {
            for (&(start, real), scores) in window.iter().zip(span_scores) {
                for (i, &s) in scores.iter().take(real).enumerate() {
                    out[start + i][r] = s;
                }
            }
        }
    }
    Ok(out)
}

/// Routing purity: fraction of sequences whose assigned expert is the
/// plurality expert for their ground-truth domain. A diagnostic of how
/// well prefix-likelihood routing discovers the latent domains.
pub fn routing_purity(assignment: &[usize], seqs: &[Sequence], n_experts: usize) -> f64 {
    use std::collections::HashMap;
    if seqs.is_empty() {
        return 0.0;
    }
    // majority expert per domain
    let mut table: HashMap<usize, Vec<usize>> = HashMap::new();
    for (s, &e) in assignment.iter().enumerate() {
        table
            .entry(seqs[s].domain)
            .or_insert_with(|| vec![0; n_experts])[e] += 1;
    }
    let majority: HashMap<usize, usize> = table
        .iter()
        .map(|(&d, counts)| {
            let best = counts
                .iter()
                .enumerate()
                .max_by_key(|&(_, c)| *c)
                .map(|(e, _)| e)
                .unwrap_or(0);
            (d, best)
        })
        .collect();
    let hits = assignment
        .iter()
        .enumerate()
        .filter(|&(s, &e)| majority[&seqs[s].domain] == e)
        .count();
    hits as f64 / seqs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(domain: usize) -> Sequence {
        Sequence {
            tokens: vec![0; 8],
            domain,
        }
    }

    #[test]
    fn purity_perfect_partition() {
        let seqs = vec![seq(0), seq(0), seq(1), seq(1)];
        let assign = vec![0, 0, 1, 1];
        assert_eq!(routing_purity(&assign, &seqs, 2), 1.0);
    }

    #[test]
    fn purity_half_split_is_half() {
        // each domain's sequences alternate between experts 0 and 1 -> the
        // majority expert covers exactly half of each domain.
        let seqs: Vec<_> = (0..96).map(|i| seq(i % 4)).collect();
        let assign: Vec<usize> = (0..96).map(|i| (i / 4) % 2).collect();
        let p = routing_purity(&assign, &seqs, 2);
        assert!((p - 0.5).abs() < 1e-9, "{p}");
    }

    #[test]
    fn purity_empty() {
        assert_eq!(routing_purity(&[], &[], 2), 0.0);
    }

    #[test]
    fn batch_spans_tile_exactly() {
        // aligned
        assert_eq!(batch_spans(8, 4), vec![(0, 4), (4, 4)]);
        // misaligned tail is short, never padded here
        assert_eq!(batch_spans(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        // fewer items than one batch
        assert_eq!(batch_spans(3, 32), vec![(0, 3)]);
        // empty input -> no spans
        assert!(batch_spans(0, 4).is_empty());
    }

    #[test]
    fn pad_prefix_row_handles_short_exact_long_and_empty() {
        // len < m: right-padded with the last token
        assert_eq!(pad_prefix_row(&[5, 6], 4), vec![5, 6, 6, 6]);
        // len == m: identity copy
        assert_eq!(pad_prefix_row(&[1, 2, 3], 3), vec![1, 2, 3]);
        // len > m: truncated to the m-token prefix
        assert_eq!(pad_prefix_row(&[1, 2, 3, 4, 5], 3), vec![1, 2, 3]);
        // empty row: padded with token 0
        assert_eq!(pad_prefix_row(&[], 2), vec![0, 0]);
    }

    #[test]
    fn pad_batch_repeats_last_row_by_reference() {
        let a: &[u32] = &[1, 2];
        let b: &[u32] = &[3, 4];
        let padded = pad_batch(vec![a, b], 5);
        assert_eq!(padded, vec![a, b, b, b, b]);
        // already full or over: untouched
        assert_eq!(pad_batch(vec![a, b], 2), vec![a, b]);
        assert_eq!(pad_batch(vec![a, b], 1), vec![a, b]);
        // empty stays empty (nothing to repeat)
        assert!(pad_batch(Vec::new(), 3).is_empty());
    }

    #[test]
    fn batch_spans_cover_all_indices_once() {
        for n in [1usize, 5, 31, 32, 33, 97] {
            let spans = batch_spans(n, 32);
            let mut seen = vec![false; n];
            for (start, real) in spans {
                for i in start..start + real {
                    assert!(!seen[i], "index {i} covered twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "n={n} not fully covered");
        }
    }
}
