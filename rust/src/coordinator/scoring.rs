//! Batched prefix scoring: the score matrix `nll[seq][router]` behind
//! every assignment (Eq. 4). Pads the tail batch to the compiled batch
//! shape (repeating the last row *by reference* — no clones) and discards
//! the padding rows.
//!
//! Transfer discipline: each token batch is uploaded to the device **once**
//! and fanned across all E routers, and router parameters are served from
//! the engine's `(state, version)` device cache — so a B-batch × E-router
//! score matrix moves B token uploads instead of the seed path's B×E token
//! + B×E parameter uploads.

use anyhow::Result;

use crate::data::Sequence;
use crate::runtime::engine::tokens_literal;
use crate::runtime::{Engine, TrainState, VariantMeta};

/// `(start, real_rows)` spans that tile `n` items into `bs`-sized batches;
/// the final span may be short (the caller pads it to the compiled shape).
pub(crate) fn batch_spans(n: usize, bs: usize) -> Vec<(usize, usize)> {
    let mut spans = Vec::with_capacity(n.div_ceil(bs.max(1)));
    let mut start = 0;
    while start < n {
        let real = (n - start).min(bs);
        spans.push((start, real));
        start += real;
    }
    spans
}

/// Score all sequences' `m`-token prefixes under every router.
/// Returns `nll[seq][router]` (summed prefix NLL — lower is better).
pub fn score_matrix(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(m)).collect();
    score_matrix_rows(engine, routers, meta, &rows, m)
}

/// [`score_matrix`] over borrowed token rows (each row is the `m`-token
/// prefix to score). This is the allocation-free entry the serving loop
/// uses — requests never get wrapped into `Sequence` clones.
pub fn score_matrix_rows(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = vec![vec![0.0f32; routers.len()]; rows.len()];
    let bs = meta.prefix_batch;
    for (start, real) in batch_spans(rows.len(), bs) {
        let mut batch: Vec<&[u32]> = rows[start..start + real].to_vec();
        // pad to the compiled batch shape by repeating the last row (by
        // reference; padding outputs are discarded below)
        let pad = batch[real - 1];
        while batch.len() < bs {
            batch.push(pad);
        }
        // one token upload per batch, shared by every router
        let tokens = engine.upload(&tokens_literal(&batch, m)?)?;
        for (r, router) in routers.iter().enumerate() {
            let scores = router.prefix_nll_device(engine, &tokens, meta, m)?;
            for (i, &s) in scores.iter().take(real).enumerate() {
                out[start + i][r] = s;
            }
        }
    }
    Ok(out)
}

/// Routing purity: fraction of sequences whose assigned expert is the
/// plurality expert for their ground-truth domain. A diagnostic of how
/// well prefix-likelihood routing discovers the latent domains.
pub fn routing_purity(assignment: &[usize], seqs: &[Sequence], n_experts: usize) -> f64 {
    use std::collections::HashMap;
    if seqs.is_empty() {
        return 0.0;
    }
    // majority expert per domain
    let mut table: HashMap<usize, Vec<usize>> = HashMap::new();
    for (s, &e) in assignment.iter().enumerate() {
        table
            .entry(seqs[s].domain)
            .or_insert_with(|| vec![0; n_experts])[e] += 1;
    }
    let majority: HashMap<usize, usize> = table
        .iter()
        .map(|(&d, counts)| {
            let best = counts
                .iter()
                .enumerate()
                .max_by_key(|&(_, c)| *c)
                .map(|(e, _)| e)
                .unwrap_or(0);
            (d, best)
        })
        .collect();
    let hits = assignment
        .iter()
        .enumerate()
        .filter(|&(s, &e)| majority[&seqs[s].domain] == e)
        .count();
    hits as f64 / seqs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(domain: usize) -> Sequence {
        Sequence {
            tokens: vec![0; 8],
            domain,
        }
    }

    #[test]
    fn purity_perfect_partition() {
        let seqs = vec![seq(0), seq(0), seq(1), seq(1)];
        let assign = vec![0, 0, 1, 1];
        assert_eq!(routing_purity(&assign, &seqs, 2), 1.0);
    }

    #[test]
    fn purity_half_split_is_half() {
        // each domain's sequences alternate between experts 0 and 1 -> the
        // majority expert covers exactly half of each domain.
        let seqs: Vec<_> = (0..96).map(|i| seq(i % 4)).collect();
        let assign: Vec<usize> = (0..96).map(|i| (i / 4) % 2).collect();
        let p = routing_purity(&assign, &seqs, 2);
        assert!((p - 0.5).abs() < 1e-9, "{p}");
    }

    #[test]
    fn purity_empty() {
        assert_eq!(routing_purity(&[], &[], 2), 0.0);
    }

    #[test]
    fn batch_spans_tile_exactly() {
        // aligned
        assert_eq!(batch_spans(8, 4), vec![(0, 4), (4, 4)]);
        // misaligned tail is short, never padded here
        assert_eq!(batch_spans(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        // fewer items than one batch
        assert_eq!(batch_spans(3, 32), vec![(0, 3)]);
        // empty input -> no spans
        assert!(batch_spans(0, 4).is_empty());
    }

    #[test]
    fn batch_spans_cover_all_indices_once() {
        for n in [1usize, 5, 31, 32, 33, 97] {
            let spans = batch_spans(n, 32);
            let mut seen = vec![false; n];
            for (start, real) in spans {
                for i in start..start + real {
                    assert!(!seen[i], "index {i} covered twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "n={n} not fully covered");
        }
    }
}
