//! Batched prefix scoring: the score matrix `nll[seq][router]` behind
//! every assignment (Eq. 4). Pads the tail batch to the compiled batch
//! shape and discards the padding rows.

use anyhow::Result;

use crate::data::Sequence;
use crate::runtime::{Engine, TrainState, VariantMeta};

/// Score all sequences' `m`-token prefixes under every router.
/// Returns `nll[seq][router]` (summed prefix NLL — lower is better).
pub fn score_matrix(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    let mut out = vec![vec![0.0f32; routers.len()]; seqs.len()];
    let bs = meta.prefix_batch;
    let mut batch: Vec<Vec<u32>> = Vec::with_capacity(bs);
    let mut batch_idx: Vec<usize> = Vec::with_capacity(bs);

    let flush = |engine: &Engine,
                     batch: &mut Vec<Vec<u32>>,
                     batch_idx: &mut Vec<usize>,
                     out: &mut Vec<Vec<f32>>|
     -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let real = batch.len();
        // pad to the compiled batch shape by repeating the last row
        while batch.len() < bs {
            batch.push(batch[real - 1].clone());
        }
        for (r, router) in routers.iter().enumerate() {
            let scores = router.prefix_nll(engine, batch, meta, m)?;
            for (i, &s) in scores.iter().take(real).enumerate() {
                out[batch_idx[i]][r] = s;
            }
        }
        batch.clear();
        batch_idx.clear();
        Ok(())
    };

    for (i, s) in seqs.iter().enumerate() {
        batch.push(s.prefix(m).to_vec());
        batch_idx.push(i);
        if batch.len() == bs {
            flush(engine, &mut batch, &mut batch_idx, &mut out)?;
        }
    }
    flush(engine, &mut batch, &mut batch_idx, &mut out)?;
    Ok(out)
}

/// Routing purity: fraction of sequences whose assigned expert is the
/// plurality expert for their ground-truth domain. A diagnostic of how
/// well prefix-likelihood routing discovers the latent domains.
pub fn routing_purity(assignment: &[usize], seqs: &[Sequence], n_experts: usize) -> f64 {
    use std::collections::HashMap;
    if seqs.is_empty() {
        return 0.0;
    }
    // majority expert per domain
    let mut table: HashMap<usize, Vec<usize>> = HashMap::new();
    for (s, &e) in assignment.iter().enumerate() {
        table
            .entry(seqs[s].domain)
            .or_insert_with(|| vec![0; n_experts])[e] += 1;
    }
    let majority: HashMap<usize, usize> = table
        .iter()
        .map(|(&d, counts)| {
            let best = counts
                .iter()
                .enumerate()
                .max_by_key(|&(_, c)| *c)
                .map(|(e, _)| e)
                .unwrap_or(0);
            (d, best)
        })
        .collect();
    let hits = assignment
        .iter()
        .enumerate()
        .filter(|&(s, &e)| majority[&seqs[s].domain] == e)
        .count();
    hits as f64 / seqs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(domain: usize) -> Sequence {
        Sequence {
            tokens: vec![0; 8],
            domain,
        }
    }

    #[test]
    fn purity_perfect_partition() {
        let seqs = vec![seq(0), seq(0), seq(1), seq(1)];
        let assign = vec![0, 0, 1, 1];
        assert_eq!(routing_purity(&assign, &seqs, 2), 1.0);
    }

    #[test]
    fn purity_half_split_is_half() {
        // each domain's sequences alternate between experts 0 and 1 -> the
        // majority expert covers exactly half of each domain.
        let seqs: Vec<_> = (0..96).map(|i| seq(i % 4)).collect();
        let assign: Vec<usize> = (0..96).map(|i| (i / 4) % 2).collect();
        let p = routing_purity(&assign, &seqs, 2);
        assert!((p - 0.5).abs() < 1e-9, "{p}");
    }

    #[test]
    fn purity_empty() {
        assert_eq!(routing_purity(&[], &[], 2), 0.0);
    }
}
