//! Batched prefix scoring: the score matrix `nll[seq][router]` behind
//! every assignment (Eq. 4). Pads the tail batch to the compiled batch
//! shape (repeating the last row *by reference* — no clones) and discards
//! the padding rows.
//!
//! Transfer discipline: each token batch is uploaded to the device **once**
//! and fanned across all E routers, and router parameters are served from
//! the engine's `(state, version)` device cache — so a B-batch × E-router
//! score matrix moves B token uploads instead of the seed path's B×E token
//! + B×E parameter uploads.
//!
//! Launch discipline: when the manifest carries a fused
//! `prefix_nll_all_{m}` entry ([`VariantMeta::fused_prefix_entry`], from
//! `aot.py --fused`), [`score_matrix_rows_threaded`] dispatches to
//! [`score_matrix_rows_fused`]: the routers' parameters are stacked into
//! one device-resident `[E, P]` tensor (re-uploaded only when some
//! router's version bumps) and each token batch is scored under the whole
//! set in **one** execution returning the `[prefix_batch, E]` slab — so a
//! B-sequence matrix costs `ceil(B / prefix_batch)` launches instead of
//! `E × ceil(B / prefix_batch)`, and the per-batch dispatch/readback
//! overhead no longer grows with E. Router sets wider than the compiled
//! width score in fused chunks; the last chunk pads by repeating its
//! final router and the dead columns are discarded like token-padding
//! rows. [`score_matrix_rows_fanout`] remains the per-router reference
//! path (and the automatic fallback for pre-fused manifests) and is
//! bit-identical to the fused path.
//!
//! Concurrency: the E routers score independently (each touches only its
//! own `TrainState` and the `Sync` engine), so the fan-out path uploads
//! token batches in bounded windows and fans one task per router per
//! window across a worker pool — the pool spawns once per window (not
//! once per batch) and device residency stays bounded no matter how many
//! rows are scored; the fused path fans one task per (router-chunk ×
//! batch) instead. Either way results are written back to disjoint
//! regions by index, so parallel output is bit-identical to sequential.

use anyhow::{Context, Result};

use crate::data::Sequence;
use crate::runtime::engine::{tokens_literal, to_f32_vec, Arg};
use crate::runtime::parallel::{default_threads, run_fallible};
use crate::runtime::{stacked_params_buffer, DeviceBuffer, Engine, TrainState, VariantMeta};

/// `(start, real_rows)` spans that tile `n` items into `bs`-sized batches;
/// the final span may be short (the caller pads it to the compiled shape).
/// A degenerate `bs == 0` (a corrupt manifest's batch shape) is treated
/// as 1 — the loop below would otherwise produce zero-width spans forever.
pub(crate) fn batch_spans(n: usize, bs: usize) -> Vec<(usize, usize)> {
    let bs = bs.max(1);
    let mut spans = Vec::with_capacity(n.div_ceil(bs));
    let mut start = 0;
    while start < n {
        let real = (n - start).min(bs);
        spans.push((start, real));
        start += real;
    }
    spans
}

/// Pad `batch` to `bs` rows by repeating the last row **by reference**
/// (no token clones); the caller discards the padding rows' outputs.
/// No-op on an empty batch or one already at/above `bs`.
pub(crate) fn pad_batch<'a>(mut batch: Vec<&'a [u32]>, bs: usize) -> Vec<&'a [u32]> {
    if let Some(&pad) = batch.last() {
        while batch.len() < bs {
            batch.push(pad);
        }
    }
    batch
}

/// Owned `m`-token prefix of a row that is not already exactly `m` long:
/// longer rows are truncated, shorter rows are right-padded by repeating
/// their last token (an empty row pads with token 0). Short requests —
/// rows with fewer than `m` tokens — therefore score under the compiled
/// `prefix_nll_{m}` shape instead of erroring on the literal build.
pub(crate) fn pad_prefix_row(row: &[u32], m: usize) -> Vec<u32> {
    let take = m.min(row.len());
    let mut out = Vec::with_capacity(m);
    out.extend_from_slice(&row[..take]);
    let fill = row.last().copied().unwrap_or(0);
    out.resize(m, fill);
    out
}

/// Score all sequences' `m`-token prefixes under every router.
/// Returns `nll[seq][router]` (summed prefix NLL — lower is better).
/// Routers fan across [`default_threads`] workers; use
/// [`score_matrix_threaded`] for an explicit worker count.
pub fn score_matrix(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    score_matrix_threaded(engine, routers, meta, seqs, m, default_threads())
}

/// [`score_matrix`] with an explicit worker count for the per-batch
/// router fan-out. `threads <= 1` is the sequential reference path.
pub fn score_matrix_threaded(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    seqs: &[Sequence],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.prefix(m)).collect();
    score_matrix_rows_threaded(engine, routers, meta, &rows, m, threads)
}

/// [`score_matrix`] over borrowed token rows (each row is the `m`-token
/// prefix to score; rows of any other length are normalized via
/// [`pad_prefix_row`]). This is the allocation-light entry the serving
/// loop uses — requests never get wrapped into `Sequence` clones.
/// Routers are fanned across [`default_threads`] workers; use
/// [`score_matrix_rows_threaded`] for an explicit worker count.
pub fn score_matrix_rows(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
) -> Result<Vec<Vec<f32>>> {
    score_matrix_rows_threaded(engine, routers, meta, rows, m, default_threads())
}

/// [`score_matrix_rows`] with an explicit worker count. `threads <= 1`
/// is the sequential reference path; results are bit-identical at any
/// worker count.
///
/// Dispatch: when the manifest carries a fused `prefix_nll_all_{m}`
/// entry, scoring runs through [`score_matrix_rows_fused`] — one kernel
/// launch per token batch instead of one per (router, batch); otherwise
/// (pre-fused manifests) it falls back to the bit-identical per-router
/// [`score_matrix_rows_fanout`]. Every caller — serve waves, the
/// continuous-batching scheduler's admission waves, EM E-steps, routed
/// eval — picks the fused path up automatically through here.
pub fn score_matrix_rows_threaded(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    if meta.fused_prefix_entry(m).is_some() && !routers.is_empty() {
        score_matrix_rows_fused(engine, routers, meta, rows, m, threads)
    } else {
        score_matrix_rows_fanout(engine, routers, meta, rows, m, threads)
    }
}

/// Normalize row lengths: owned padded/truncated copies only where a row
/// is not already exactly `m` tokens. The returned backing storage must
/// outlive the borrowed row slice built from it.
fn normalize_rows(rows: &[&[u32]], m: usize) -> Vec<Option<Vec<u32>>> {
    rows.iter()
        .map(|r| (r.len() != m).then(|| pad_prefix_row(r, m)))
        .collect()
}

/// Token batches of a span window, each uploaded to the device once.
fn upload_window(
    engine: &Engine,
    rows: &[&[u32]],
    window: &[(usize, usize)],
    bs: usize,
    m: usize,
) -> Result<Vec<DeviceBuffer>> {
    window
        .iter()
        .map(|&(start, real)| {
            let batch = pad_batch(rows[start..start + real].to_vec(), bs);
            engine.upload(&tokens_literal(&batch, m)?)
        })
        .collect()
}

/// Spans are processed in fixed-size windows: a window's token batches
/// upload once up front (each shared device-resident by every execution
/// that scores it) and are dropped before the next window starts, so peak
/// device residency is bounded at `SPAN_WINDOW * prefix_batch` rows no
/// matter how large the scored corpus is, while the worker pool spawns
/// once per window — not once per span. The fused eval dispatcher
/// ([`super::inference::eval_nll_groups`]) windows its launches under the
/// same constant for the same residency bound.
pub(crate) const SPAN_WINDOW: usize = 16;

/// The per-router reference path: each router scores every token batch in
/// its own execution (`E × ceil(rows / prefix_batch)` launches). This is
/// the bit-exact fallback for manifests without fused entries and the
/// reference the fused path is verified against.
pub fn score_matrix_rows_fanout(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    let normalized = normalize_rows(rows, m);
    let rows: Vec<&[u32]> = rows
        .iter()
        .zip(&normalized)
        .map(|(r, p)| p.as_deref().unwrap_or(r))
        .collect();

    // Each router scores every span of the window against its own state,
    // so results are bit-identical at any worker count. The bs clamp
    // matches batch_spans' degenerate-manifest guard, so spans, padding,
    // and batch shapes stay consistent even at prefix_batch == 0.
    let bs = meta.prefix_batch.max(1);
    let mut out = vec![vec![0.0f32; routers.len()]; rows.len()];
    for window in batch_spans(rows.len(), bs).chunks(SPAN_WINDOW) {
        let uploads = upload_window(engine, &rows, window, bs, m)?;
        let tasks: Vec<_> = routers
            .iter()
            .map(|router| {
                let uploads = &uploads;
                move || -> Result<Vec<Vec<f32>>> {
                    uploads
                        .iter()
                        .map(|tokens| router.prefix_nll_device(engine, tokens, meta, m))
                        .collect()
                }
            })
            .collect();
        for (r, span_scores) in run_fallible(tasks, threads)?.into_iter().enumerate() {
            for (&(start, real), scores) in window.iter().zip(span_scores) {
                for (i, &s) in scores.iter().take(real).enumerate() {
                    out[start + i][r] = s;
                }
            }
        }
    }
    Ok(out)
}

/// The fused all-routers path: the router set is stacked into one
/// device-resident `[E, P]` tensor ([`stacked_params_buffer`] — uploaded
/// once per router-set version) and each token batch is scored under the
/// whole stack by a single `prefix_nll_all_{m}` execution returning the
/// `[prefix_batch, E]` NLL slab. Launches per score matrix:
/// `ceil(routers / fused_width) × ceil(rows / prefix_batch)` — with the
/// router count at or under the compiled width, exactly one per token
/// batch.
///
/// Router sets wider than the compiled `fused_experts` score in chunks;
/// a short final chunk pads by repeating its last router (the stacked
/// tensor must fill the compiled `[E, P]` shape) and the dead columns
/// are discarded exactly like token-padding rows. Each (chunk, batch)
/// task writes a disjoint block of the matrix, so the parallel output is
/// bit-identical to sequential — and to [`score_matrix_rows_fanout`],
/// column for column.
pub fn score_matrix_rows_fused(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    rows: &[&[u32]],
    m: usize,
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    let entry = meta.fused_prefix_entry(m).with_context(|| {
        format!(
            "no fused prefix_nll_all_{m} entry compiled for {} — \
             re-run `make artifacts` (aot.py --fused) or use the fan-out path",
            meta.name
        )
    })?;
    let width = meta.fused_experts;
    let mut out = vec![vec![0.0f32; routers.len()]; rows.len()];
    if routers.is_empty() || rows.is_empty() {
        return Ok(out);
    }

    let normalized = normalize_rows(rows, m);
    let rows: Vec<&[u32]> = rows
        .iter()
        .zip(&normalized)
        .map(|(r, p)| p.as_deref().unwrap_or(r))
        .collect();

    // (column offset, real width, stacked [width, P] params) per chunk;
    // the stack is cached per ordered (state_id, version) list, so this
    // re-uploads only when some member's parameters changed
    let chunks: Vec<(usize, usize, DeviceBuffer)> = routers
        .chunks(width)
        .enumerate()
        .map(|(c, members)| -> Result<(usize, usize, DeviceBuffer)> {
            let mut padded: Vec<&TrainState> = members.iter().collect();
            let last = *padded.last().expect("chunks are non-empty");
            padded.resize(width, last);
            let stack = stacked_params_buffer(engine, &padded)?;
            Ok((c * width, members.len(), stack))
        })
        .collect::<Result<_>>()?;

    // clamp as in the fan-out path: spans, padding, and the slab-size
    // check below must all agree on the effective batch shape
    let bs = meta.prefix_batch.max(1);
    let entry = entry.as_str();
    for window in batch_spans(rows.len(), bs).chunks(SPAN_WINDOW) {
        let uploads = upload_window(engine, &rows, window, bs, m)?;
        // one task per (router chunk × token batch): every task is one
        // fused execution writing a disjoint block of the matrix
        let mut tasks = Vec::with_capacity(chunks.len() * uploads.len());
        let mut blocks = Vec::with_capacity(tasks.capacity());
        for (c, (_, real_e, stack)) in chunks.iter().enumerate() {
            for (w, tokens) in uploads.iter().enumerate() {
                tasks.push(move || -> Result<Vec<f32>> {
                    let slab = engine.run_buffers_fused(
                        &meta.name,
                        entry,
                        &[Arg::Dev(stack), Arg::Dev(tokens)],
                        *real_e,
                    )?;
                    to_f32_vec(slab.first().context("prefix_nll_all empty")?)
                });
                blocks.push((c, w));
            }
        }
        for ((c, w), slab) in blocks.into_iter().zip(run_fallible(tasks, threads)?) {
            let (col0, real_e, _) = &chunks[c];
            let (col0, real_e) = (*col0, *real_e);
            let (start, real) = window[w];
            anyhow::ensure!(
                slab.len() == bs * width,
                "fused entry returned {} scores for a [{bs}, {width}] slab",
                slab.len()
            );
            // slab is the row-major [prefix_batch, width] matrix: request
            // i's score under chunk-member j at [i * width + j]
            for i in 0..real {
                for j in 0..real_e {
                    out[start + i][col0 + j] = slab[i * width + j];
                }
            }
        }
    }
    Ok(out)
}

/// Routing purity: fraction of sequences whose assigned expert is the
/// plurality expert for their ground-truth domain. A diagnostic of how
/// well prefix-likelihood routing discovers the latent domains.
pub fn routing_purity(assignment: &[usize], seqs: &[Sequence], n_experts: usize) -> f64 {
    use std::collections::HashMap;
    if seqs.is_empty() {
        return 0.0;
    }
    // majority expert per domain
    let mut table: HashMap<usize, Vec<usize>> = HashMap::new();
    for (s, &e) in assignment.iter().enumerate() {
        table
            .entry(seqs[s].domain)
            .or_insert_with(|| vec![0; n_experts])[e] += 1;
    }
    let majority: HashMap<usize, usize> = table
        .iter()
        .map(|(&d, counts)| {
            let best = counts
                .iter()
                .enumerate()
                .max_by_key(|&(_, c)| *c)
                .map(|(e, _)| e)
                .unwrap_or(0);
            (d, best)
        })
        .collect();
    let hits = assignment
        .iter()
        .enumerate()
        .filter(|&(s, &e)| majority[&seqs[s].domain] == e)
        .count();
    hits as f64 / seqs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(domain: usize) -> Sequence {
        Sequence {
            tokens: vec![0; 8],
            domain,
        }
    }

    #[test]
    fn purity_perfect_partition() {
        let seqs = vec![seq(0), seq(0), seq(1), seq(1)];
        let assign = vec![0, 0, 1, 1];
        assert_eq!(routing_purity(&assign, &seqs, 2), 1.0);
    }

    #[test]
    fn purity_half_split_is_half() {
        // each domain's sequences alternate between experts 0 and 1 -> the
        // majority expert covers exactly half of each domain.
        let seqs: Vec<_> = (0..96).map(|i| seq(i % 4)).collect();
        let assign: Vec<usize> = (0..96).map(|i| (i / 4) % 2).collect();
        let p = routing_purity(&assign, &seqs, 2);
        assert!((p - 0.5).abs() < 1e-9, "{p}");
    }

    #[test]
    fn purity_empty() {
        assert_eq!(routing_purity(&[], &[], 2), 0.0);
    }

    #[test]
    fn batch_spans_tile_exactly() {
        // aligned
        assert_eq!(batch_spans(8, 4), vec![(0, 4), (4, 4)]);
        // misaligned tail is short, never padded here
        assert_eq!(batch_spans(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        // fewer items than one batch
        assert_eq!(batch_spans(3, 32), vec![(0, 3)]);
        // empty input -> no spans
        assert!(batch_spans(0, 4).is_empty());
    }

    #[test]
    fn batch_spans_zero_batch_size_terminates() {
        // bs = 0 used to yield zero-width spans forever (start never
        // advanced); it now degrades to one-row spans and still covers
        // every index exactly once
        assert_eq!(batch_spans(3, 0), vec![(0, 1), (1, 1), (2, 1)]);
        assert!(batch_spans(0, 0).is_empty());
        assert_eq!(batch_spans(1, 0), vec![(0, 1)]);
    }

    #[test]
    fn pad_prefix_row_handles_short_exact_long_and_empty() {
        // len < m: right-padded with the last token
        assert_eq!(pad_prefix_row(&[5, 6], 4), vec![5, 6, 6, 6]);
        // len == m: identity copy
        assert_eq!(pad_prefix_row(&[1, 2, 3], 3), vec![1, 2, 3]);
        // len > m: truncated to the m-token prefix
        assert_eq!(pad_prefix_row(&[1, 2, 3, 4, 5], 3), vec![1, 2, 3]);
        // empty row: padded with token 0
        assert_eq!(pad_prefix_row(&[], 2), vec![0, 0]);
    }

    #[test]
    fn pad_batch_repeats_last_row_by_reference() {
        let a: &[u32] = &[1, 2];
        let b: &[u32] = &[3, 4];
        let padded = pad_batch(vec![a, b], 5);
        assert_eq!(padded, vec![a, b, b, b, b]);
        // already full or over: untouched
        assert_eq!(pad_batch(vec![a, b], 2), vec![a, b]);
        assert_eq!(pad_batch(vec![a, b], 1), vec![a, b]);
        // empty stays empty (nothing to repeat)
        assert!(pad_batch(Vec::new(), 3).is_empty());
    }

    #[test]
    fn batch_spans_cover_all_indices_once() {
        for n in [1usize, 5, 31, 32, 33, 97] {
            let spans = batch_spans(n, 32);
            let mut seen = vec![false; n];
            for (start, real) in spans {
                for i in start..start + real {
                    assert!(!seen[i], "index {i} covered twice");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "n={n} not fully covered");
        }
    }
}
