//! Continuous-batching serve: a cross-wave request queue with admission
//! scheduling (the ROADMAP's "vLLM-style" open item).
//!
//! The closed-wave loop in [`super::inference`] realizes the paper's
//! serving economy — one tiny-router score, one expert forward — only one
//! batch at a time: requests arriving mid-wave wait for the next call,
//! and a wave's slowest expert group idles every worker. This module
//! inverts that control flow: a [`run_server`] scheduler owns the
//! batches, and callers merely submit requests as they arrive.
//!
//! # Admission / dispatch state machine
//!
//! A request moves through four states, each owned by exactly one queue
//! or thread:
//!
//! ```text
//!  submitted ──▶ arrivals (WorkQueue)        client threads push
//!      │
//!      ▼  scheduler thread: pops arrivals, routes them in small
//!  admitted     admission waves (one batched router score per wave),
//!      │        appends each request to its expert's pending batch
//!      ▼
//!  dispatched ─▶ dispatch (WorkQueue)        pending batch leaves when
//!      │            • it reaches `batch_size`          (full)
//!      │            • its oldest member waited `max_wait` (linger)
//!      │            • the server is draining at shutdown  (drain)
//!      ▼
//!  completed    worker threads pop batches, run the expert forward,
//!               write each response into its submission-order slot
//! ```
//!
//! With `replicas > 1` the single dispatch queue becomes a replica fleet
//! (see [`super::replica`]): dispatch consults the expert→replica
//! placement map and pushes the batch onto the least-loaded live holder's
//! own lane queue, and one worker per replica (engine-per-device) drains
//! its lane:
//!
//! ```text
//!  dispatched ─▶ placement lookup ─▶ replica lane queues (one per
//!      │         (PlacementMap)       replica; least-loaded live holder)
//!      ▼
//!  completed    replica r's worker pops lane r only — per-replica
//!               executed-row accounting is exact
//! ```
//!
//! Placement rebalances online from the scheduler's own route histogram
//! ([`SchedStats::route_histogram`]) every `rebalance_every` admission
//! waves; each move is audited as a [`CommKind::ReplicaSync`] ledger
//! event carrying the exact expert parameter bytes. `replicas <= 1` is
//! the untouched single-queue reference path, and replica choice cannot
//! change a response (NLL is a pure function of `(expert, rows)` and the
//! batch is composed before the replica is picked), so triples stay
//! bit-identical across any replica count / placement / rebalance
//! schedule — asserted by `rust/tests/replica.rs`.
//!
//! Workers pull from the dispatch queue the moment they free up
//! ([`SchedStats::slots_refilled`] counts pulls that never blocked), so a
//! straggling expert batch delays only its own worker — the property the
//! closed-wave path lacks.
//!
//! # Determinism contract
//!
//! A response's `(id, expert, nll)` triple is a pure function of the
//! request's tokens: per-row router scores and per-row expert NLLs are
//! independent of how rows are batched (asserted by the tail-padding and
//! batching identity tests of PR 1/2). Therefore **any** arrival order,
//! worker count, `batch_size`, or `max_wait` yields the same triple per
//! request as the sequential closed-wave reference — only the timing
//! fields and the batch boundaries vary. `rust/tests/server.rs` asserts
//! this against [`super::serve_threaded`] at `threads = 1`.
//!
//! # Locking order (matching the `runtime/engine.rs` convention)
//!
//! * `arrivals` / `dispatch` — each a [`WorkQueue`] whose internal lock
//!   is never held across routing, execution, or the other queue's lock.
//! * `responses` (`Mutex`) — completion slots; taken by workers after
//!   execution, never while holding a queue lock.
//! * `stats` (`Mutex`) — counter updates; always the innermost lock.
//! * `error` — first-failure slot (`AtomicBool` + `Mutex`); the flag is
//!   checked lock-free, the slot lock is only taken to record or take
//!   the error, never nested under anything else.
//! * `Fleet::place` (`Mutex`, replicated mode only) — the placement map
//!   plus the move/sync ledger. Ordering rules: it is **never nested**
//!   with any other lock — never held across a lane-queue push, the
//!   `stats` lock, or backend execution (the scheduler clones the
//!   holder list out, drops the lock, then dispatches; rebalance reads
//!   the histogram under `stats`, releases it, and only then takes
//!   `place`). Workers never touch it at all — they only update their
//!   own lane's relaxed atomics — so placement reads/writes stay a
//!   scheduler-thread affair exactly like the pending batches.
//!
//! Pending per-expert batches and their linger deadlines live entirely on
//! the scheduler thread and need no lock at all — and so does the
//! prefix-routing memo: the scheduler memoizes normalized-prefix → expert
//! per admission (keyed by the padded prefix row the router actually
//! scores, so repeat prefixes skip the batched router score entirely —
//! [`SchedStats::route_cache_hits`]), and drops the memo whenever the
//! backend's router fingerprint moves (any router version bump). Routing
//! is a pure function of the normalized prefix and the router parameters,
//! so replaying a memoized expert is bit-identical to re-scoring.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::comm::{CommKind, CommLedger};
use super::inference::{amortized_micros, eval_nll_all, Mixture, Request, Response};
use super::replica::{PlacementMap, ReplicaLane, ReplicaReport, ReplicaSet};
use super::scoring::pad_prefix_row;
use crate::runtime::parallel::{resolve_threads, Pop, PushOutcome, WorkQueue};
use crate::runtime::Engine;

/// What the scheduler needs from the model side. The production
/// implementation is [`MixtureBackend`]; tests substitute deterministic
/// stubs so the queue/admission mechanics are testable without compiled
/// artifacts (tier-1).
pub trait ServeBackend: Sync {
    fn n_experts(&self) -> usize;
    /// Route a batch of token rows to expert indices (one admission wave).
    fn route(&self, rows: &[&[u32]], threads: usize) -> Result<Vec<usize>>;
    /// Full-sequence NLL of `rows` under expert `expert` (one dispatched
    /// batch).
    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>>;

    /// Memoization key of a request's routing decision: the **normalized**
    /// prefix row [`route`](ServeBackend::route) actually scores, or
    /// `None` (the default) to disable memoization for this backend. Two
    /// token rows with the same key MUST route identically — routing is a
    /// pure function of the normalized prefix — so the scheduler may
    /// replay a memoized expert instead of scoring the prefix again.
    fn route_memo_key(&self, _row: &[u32]) -> Option<Vec<u32>> {
        None
    }

    /// Fingerprint of the parameters behind
    /// [`route`](ServeBackend::route): the scheduler drops every memoized
    /// route whenever this value changes (e.g. any router's version
    /// bumps). Only consulted when `route_memo_key` returns keys.
    fn router_fingerprint(&self) -> u64 {
        0
    }

    /// [`exec_nll`](ServeBackend::exec_nll) on engine replica `replica`
    /// (replicated serving; `replica` is always a valid fleet index). The
    /// default forwards to `exec_nll`: NLL is a pure function of
    /// `(expert, rows)`, so any override MUST return bit-identical values
    /// on every replica — that purity is the whole determinism contract
    /// of replicated serving.
    fn exec_nll_replica(&self, _replica: usize, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        self.exec_nll(expert, rows)
    }

    /// Bytes one placement move ships: the full parameter set a replica
    /// pulls when it becomes a new holder of an expert (audited per move
    /// as [`CommKind::ReplicaSync`]). Default `0` for model-free stubs.
    fn expert_param_bytes(&self) -> u64 {
        0
    }
}

/// The real backend: router scoring + expert execution over a trained
/// [`Mixture`].
pub struct MixtureBackend<'a> {
    pub engine: &'a Engine,
    pub mixture: &'a Mixture,
    /// Routing prefix length (the paper's `m`).
    pub prefix_len: usize,
}

impl ServeBackend for MixtureBackend<'_> {
    fn n_experts(&self) -> usize {
        self.mixture.n_experts()
    }

    fn route(&self, rows: &[&[u32]], threads: usize) -> Result<Vec<usize>> {
        self.mixture
            .route_rows_threaded(self.engine, rows, self.prefix_len, threads)
    }

    fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
        eval_nll_all(
            self.engine,
            &self.mixture.experts[expert],
            &self.mixture.expert_meta,
            rows,
        )
    }

    /// The padded `prefix_len`-token prefix row — exactly what
    /// [`Mixture::route_rows_threaded`] hands the scorer, so equal keys
    /// imply equal score-matrix rows and therefore equal routes.
    fn route_memo_key(&self, row: &[u32]) -> Option<Vec<u32>> {
        Some(pad_prefix_row(row, self.prefix_len))
    }

    /// f32 parameters of one expert — what a new holder pulls on a
    /// placement move.
    fn expert_param_bytes(&self) -> u64 {
        self.mixture.expert_meta.param_count as u64 * 4
    }

    /// Hash of the routers' ordered `(state_id, version)` pairs: any
    /// router training step / checkpoint load / clone swap changes it.
    fn router_fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        for r in &self.mixture.routers {
            (r.state_id(), r.version()).hash(&mut h);
        }
        h.finish()
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Per-expert dispatch threshold: a pending batch is dispatched the
    /// moment it holds this many requests. `0` means unbounded — batches
    /// leave only on linger expiry or drain.
    pub batch_size: usize,
    /// Linger: a *partial* pending batch is dispatched once its oldest
    /// member has waited this long. `u64::MAX` disables the timer
    /// (partial batches then wait for fill or drain).
    pub max_wait_us: u64,
    /// Max requests routed per admission wave (`0` = unbounded: each wave
    /// takes every arrival queued at that moment).
    pub admission_max: usize,
    /// Worker threads executing dispatched batches (also the router
    /// fan-out width inside an admission wave); `0` = auto. With
    /// `replicas > 1` the executing pool is one worker per replica
    /// instead (engine-per-device); `threads` then only sizes the router
    /// fan-out.
    pub threads: usize,
    /// Engine replicas behind the dispatch queue. `0`/`1` = the single
    /// dispatch-queue reference path, bit-exact with pre-replica serving.
    pub replicas: usize,
    /// Hot-expert replication floor (see [`super::replica`]): `1`
    /// disables replication (pure partitioning); `k > 1` gives every hot
    /// expert at least `k` holders, escalated up to `replicas` by demand.
    pub replication: usize,
    /// Rebalance the placement map from [`SchedStats::route_histogram`]
    /// every this many admission waves (`0` = keep the initial placement
    /// for the whole run). Ignored when `replicas <= 1`.
    pub rebalance_every: usize,
}

impl ServerConfig {
    /// Continuous-batching defaults: dispatch at `batch_size`, linger
    /// `max_wait_us`, admission waves capped at `batch_size` (or 32 when
    /// unbounded).
    pub fn continuous(batch_size: usize, max_wait_us: u64, threads: usize) -> Self {
        ServerConfig {
            batch_size,
            max_wait_us,
            admission_max: if batch_size == 0 { 32 } else { batch_size },
            threads,
            replicas: 1,
            replication: 1,
            rebalance_every: 0,
        }
    }

    /// Replica-fleet knobs on top of any base config (`replicas <= 1`
    /// restores the single-queue reference path).
    pub fn with_replicas(mut self, replicas: usize, replication: usize, rebalance_every: usize) -> Self {
        self.replicas = replicas;
        self.replication = replication;
        self.rebalance_every = rebalance_every;
        self
    }

    /// The closed-wave configuration [`super::serve_threaded`] wraps: one
    /// admission wave over everything submitted, no size/linger dispatch
    /// — every expert group leaves as a single batch at drain, exactly
    /// like the classic wave loop.
    pub fn closed_wave(threads: usize) -> Self {
        ServerConfig {
            batch_size: 0,
            max_wait_us: u64::MAX,
            admission_max: 0,
            threads,
            replicas: 1,
            replication: 1,
            rebalance_every: 0,
        }
    }
}

/// Scheduler counters (the serving analogue of
/// [`EngineStats`](crate::runtime::EngineStats)).
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    /// Requests handed to [`ServerClient::submit`] / `submit_wave` and
    /// accepted (shed requests are counted in `shed`, not here).
    pub submitted: usize,
    /// Requests refused by [`ServerClient::try_submit`] because the
    /// arrival queue stood at or past the caller's high-water mark (load
    /// shed; the wire front-end answers these with a structured 429-style
    /// line).
    pub shed: usize,
    /// Requests routed (equals `submitted` on a clean run).
    pub admitted: usize,
    /// Admission waves processed — at most one batched router-scoring
    /// call each (a fully-memoized wave skips the call entirely).
    pub admission_waves: usize,
    /// Requests whose route was replayed from the prefix-routing memo
    /// instead of scored: each hit removes the request's rows from the
    /// wave's batched router score.
    pub route_cache_hits: usize,
    /// Expert batches pushed to the dispatch queue, by trigger.
    pub batches_dispatched: usize,
    pub full_batches: usize,
    pub linger_batches: usize,
    pub drain_batches: usize,
    /// Worker pulls that found a batch already waiting (the freed slot
    /// was refilled without blocking).
    pub slots_refilled: usize,
    /// Requests answered.
    pub completed: usize,
    /// Dispatch-queue depth summed at each dispatch (for
    /// [`SchedStats::mean_queue_depth`]).
    pub depth_sum: usize,
    pub depth_samples: usize,
    /// Admitted requests per routed expert — the scheduler's own route
    /// histogram, and the input replica placement rebalances from. Sized
    /// lazily to `n_experts` on the first admission (empty on a
    /// zero-request run).
    pub route_histogram: Vec<usize>,
    /// Replica-fleet accounting when `cfg.replicas > 1`; `None` on the
    /// single-queue reference path.
    pub replica: Option<ReplicaReport>,
}

impl SchedStats {
    /// Mean dispatch-queue depth observed at dispatch time: how much work
    /// was waiting for a free worker slot, on average.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.depth_samples == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.depth_samples as f64
        }
    }
}

/// A submitted request waiting for admission.
struct Arrival {
    seq: usize,
    submit_t: Instant,
    req: Request,
}

/// An admitted (routed) request waiting in its expert's pending batch.
struct Admitted {
    seq: usize,
    /// Arrival-queue wait: submission → admission (routing start).
    pre_route_wait: Duration,
    /// When this request's admission wave finished routing — the pending
    /// + dispatch-queue wait is measured from here, so `queue_micros`
    /// never double-counts the routing span `route_us` covers.
    routed_t: Instant,
    route_us: u128,
    req: Request,
}

/// One dispatched expert batch.
struct Batch {
    expert: usize,
    items: Vec<Admitted>,
}

/// Scheduler-thread-local prefix-routing memo: normalized prefix row →
/// routed expert, valid for one router fingerprint. Bounded by
/// [`ROUTE_MEMO_CAP`] entries — at the cap the whole memo is dropped
/// (steady-state serving re-warms it within a wave or two, and a plain
/// clear keeps the replay path allocation- and bookkeeping-free).
struct RouteMemo {
    fingerprint: u64,
    map: HashMap<Vec<u32>, usize>,
}

/// Memo capacity: at the routing-bench shape (m = 32, 4-byte tokens) this
/// bounds the memo at ~8 MiB of key data.
const ROUTE_MEMO_CAP: usize = 1 << 16;

/// First-failure slot: the flag is checked lock-free on hot paths.
#[derive(Default)]
struct ErrSlot {
    set: AtomicBool,
    err: Mutex<Option<anyhow::Error>>,
}

impl ErrSlot {
    fn is_set(&self) -> bool {
        self.set.load(Ordering::Relaxed)
    }

    fn record(&self, e: anyhow::Error) {
        let mut slot = self.err.lock().expect("error slot poisoned");
        if slot.is_none() {
            *slot = Some(e);
        }
        self.set.store(true, Ordering::Relaxed);
    }

    fn take(&self) -> Option<anyhow::Error> {
        self.err.lock().expect("error slot poisoned").take()
    }
}

/// Outcome of a depth-bounded [`ServerClient::try_submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// The request entered the arrival queue.
    Accepted,
    /// The arrival queue stood at or past the high-water mark: the
    /// request was refused without consuming a sequence slot, and
    /// [`SchedStats::shed`] was bumped.
    Shed,
    /// The server is shutting down; the request was dropped.
    Closed,
}

/// The handle a [`run_server`] driver submits requests through.
pub struct ServerClient<'q> {
    arrivals: &'q WorkQueue<Arrival>,
    next_seq: AtomicUsize,
    stats: &'q Mutex<SchedStats>,
}

impl ServerClient<'_> {
    /// Submit one request. Returns `false` if the server is already
    /// shutting down (the request is dropped).
    pub fn submit(&self, req: Request) -> bool {
        self.submit_wave(vec![req])
    }

    /// Submit a batch atomically: the scheduler admits all of it in one
    /// wave (given capacity) — this is what keeps the closed-wave wrapper
    /// a single score-matrix call.
    pub fn submit_wave(&self, reqs: Vec<Request>) -> bool {
        let now = Instant::now();
        let items: Vec<Arrival> = reqs
            .into_iter()
            .map(|req| Arrival {
                seq: self.next_seq.fetch_add(1, Ordering::Relaxed),
                submit_t: now,
                req,
            })
            .collect();
        self.arrivals.push_all(items)
    }

    /// Submit one request **only if** the arrival queue holds fewer than
    /// `high_water` entries — the load-shedding entry point the wire
    /// front-end uses. A shed request never consumes a sequence slot
    /// (the `Arrival` is constructed only on admission, so
    /// [`run_server`]'s hole check stays exact) and is counted in
    /// [`SchedStats::shed`]. `high_water == 0` sheds everything.
    pub fn try_submit(&self, req: Request, high_water: usize) -> SubmitOutcome {
        let submit_t = Instant::now();
        match self.arrivals.push_with_unless_above(high_water, || Arrival {
            seq: self.next_seq.fetch_add(1, Ordering::Relaxed),
            submit_t,
            req,
        }) {
            PushOutcome::Pushed => SubmitOutcome::Accepted,
            PushOutcome::Shed => {
                self.stats.lock().expect("stats poisoned").shed += 1;
                SubmitOutcome::Shed
            }
            PushOutcome::Closed => SubmitOutcome::Closed,
        }
    }

    /// Arrival-queue depth right now (the probe behind shedding
    /// decisions and the serve bench's offered-load sweep).
    pub fn queued(&self) -> usize {
        self.arrivals.len()
    }

    /// Requests submitted so far.
    pub fn submitted(&self) -> usize {
        self.next_seq.load(Ordering::Relaxed)
    }
}

/// Closes a queue when dropped, so a panicking thread cannot strand its
/// consumers in a blocking `pop`.
struct CloseOnDrop<'q, T>(&'q WorkQueue<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Placement state of a replicated run: the expert→replica map plus the
/// rebalance/sync audit. See the module header's locking order — this
/// lock is never nested with any other.
struct FleetPlace {
    map: PlacementMap,
    /// Admission waves seen (the rebalance cadence counter).
    waves: usize,
    /// Rebalance epochs run (also the `step` on ReplicaSync events).
    epochs: usize,
    moves: usize,
    fallbacks: usize,
    ledger: CommLedger,
}

/// The replica fleet a replicated run dispatches into: one lane per
/// engine replica plus the placement map.
struct Fleet {
    set: ReplicaSet<Batch>,
    place: Mutex<FleetPlace>,
    replication: usize,
    rebalance_every: usize,
    expert_param_bytes: u64,
}

impl Fleet {
    fn new(
        replicas: usize,
        replication: usize,
        rebalance_every: usize,
        n_experts: usize,
        expert_param_bytes: u64,
    ) -> Self {
        Fleet {
            set: ReplicaSet::new(replicas),
            place: Mutex::new(FleetPlace {
                map: PlacementMap::initial(n_experts, replicas, replication),
                waves: 0,
                epochs: 0,
                moves: 0,
                fallbacks: 0,
                ledger: CommLedger::default(),
            }),
            replication,
            rebalance_every,
            expert_param_bytes,
        }
    }

    fn lock_place(&self) -> std::sync::MutexGuard<'_, FleetPlace> {
        self.place.lock().expect("placement poisoned")
    }

    /// Route one dispatched batch to the least-loaded live holder of its
    /// expert. Returns the chosen lane's pre-push queue depth (the
    /// `mean_queue_depth` sample). An emergency fallback (every mapped
    /// holder dead) promotes the chosen replica to a holder and audits
    /// the implied parameter sync as a move.
    fn dispatch(&self, batch: Batch) -> Option<usize> {
        let expert = batch.expert;
        let holders: Vec<usize> = {
            // clone the (tiny) holder list out: the placement lock must
            // not be held across the lane push
            self.lock_place().map.holders(expert).to_vec()
        };
        let rows = batch.items.len();
        let pick = self.set.dispatch(&holders, rows, batch).ok()?;
        if pick.fallback {
            let mut p = self.lock_place();
            p.fallbacks += 1;
            if p.map.insert_holder(expert, pick.replica) {
                p.moves += 1;
                let (epoch, bytes) = (p.epochs as u64, self.expert_param_bytes);
                p.ledger.record_replica_sync(pick.replica, bytes, epoch);
            }
        }
        Some(pick.depth)
    }

    /// Scheduler hook after each admission wave: every `rebalance_every`
    /// waves, recompute placement from the route histogram and audit each
    /// move as a [`CommKind::ReplicaSync`] event of exactly
    /// `expert_param_bytes` — so ledger bytes reconcile in closed form
    /// against the move count.
    fn maybe_rebalance(&self, stats: &Mutex<SchedStats>) {
        if self.rebalance_every == 0 {
            return;
        }
        {
            let mut p = self.lock_place();
            p.waves += 1;
            if p.waves % self.rebalance_every != 0 {
                return;
            }
        } // released: never nest the placement lock under/over `stats`
        let histogram = stats
            .lock()
            .expect("stats poisoned")
            .route_histogram
            .clone();
        let mut p = self.lock_place();
        let (map, moves) = p.map.rebalanced(&histogram, self.replication);
        p.epochs += 1;
        let epoch = p.epochs as u64;
        for mv in &moves {
            p.ledger
                .record_replica_sync(mv.to_replica, self.expert_param_bytes, epoch);
        }
        p.moves += moves.len();
        p.map = map;
    }

    fn report(&self) -> ReplicaReport {
        let p = self.lock_place();
        ReplicaReport {
            replicas: self.set.n_replicas(),
            replication: self.replication,
            rebalances: p.epochs,
            moves: p.moves,
            sync_bytes: p.ledger.kind_bytes(CommKind::ReplicaSync),
            fallback_dispatches: p.fallbacks,
            executed_rows: self.set.executed_rows(),
            executed_batches: self.set.executed_batches(),
            ledger: p.ledger.clone(),
        }
    }
}

/// Where dispatched batches go: the single reference queue, or the
/// replica fleet.
enum Dispatch<'q> {
    Single(&'q WorkQueue<Batch>),
    Fleet(&'q Fleet),
}

impl Dispatch<'_> {
    fn close(&self) {
        match self {
            Dispatch::Single(q) => q.close(),
            Dispatch::Fleet(f) => f.set.close_all(),
        }
    }
}

/// Closes every dispatch queue when dropped, so a panicking scheduler
/// cannot strand workers in a blocking `pop` (fleet analogue of
/// [`CloseOnDrop`]).
struct CloseDispatchOnDrop<'a, 'q>(&'a Dispatch<'q>);

impl Drop for CloseDispatchOnDrop<'_, '_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Run the continuous-batching server over `backend` for the lifetime of
/// `driver`: the driver submits requests through the [`ServerClient`]
/// (streaming them in, sleeping between waves, whatever the workload
/// demands); when it returns, the server drains — every pending batch is
/// dispatched, every response collected — and the call returns the
/// responses **in submission order** plus the scheduler counters and the
/// driver's own result.
///
/// Internally: `threads` workers pull from the dispatch queue, one
/// scheduler thread owns admission and dispatch, and the driver runs on
/// the calling thread. Any routing/execution error shuts the server down
/// and is returned after the scope joins (first failure wins).
pub fn run_server<B, R, F>(
    backend: &B,
    cfg: &ServerConfig,
    driver: F,
) -> Result<(Vec<Response>, SchedStats, R)>
where
    B: ServeBackend,
    R: Send,
    F: FnOnce(&ServerClient) -> R + Send,
{
    let responses: Mutex<Vec<Option<Response>>> = Mutex::new(Vec::new());
    let (stats, driver_out) = run_server_streaming(
        backend,
        cfg,
        |seq, resp| {
            let mut out = responses.lock().expect("responses poisoned");
            if out.len() <= seq {
                out.resize_with(seq + 1, || None);
            }
            out[seq] = Some(resp);
        },
        driver,
    )?;
    let slots = responses.into_inner().expect("responses poisoned");
    let mut out = Vec::with_capacity(stats.submitted);
    for (seq, slot) in slots.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| anyhow!("request at submission index {seq} was never answered"))?);
    }
    if out.len() != stats.submitted {
        bail!(
            "{} of {} submitted requests were never answered",
            stats.submitted - out.len(),
            stats.submitted
        );
    }
    Ok((out, stats, driver_out))
}

/// [`run_server`] with responses **streamed** instead of collected: the
/// moment a worker finishes a batch, `sink(seq, response)` fires once per
/// request (`seq` is the submission index [`ServerClient`] assigned) — no
/// response waits for drain, which is what lets the wire front-end
/// ([`super::net`]) answer each client as its request completes. The sink
/// runs on worker threads, possibly several at once (hence `Sync`), and
/// should be brief: it sits between a finished batch and the worker's
/// next pull.
///
/// Everything else matches [`run_server`]: the driver runs on the calling
/// thread, drain on driver return answers everything admitted, and the
/// first backend error shuts the server down and is returned after the
/// scope joins. Delivery is exactly-once per admitted request on a clean
/// run; on an error run the sink may have seen any subset.
pub fn run_server_streaming<B, R, F, S>(
    backend: &B,
    cfg: &ServerConfig,
    sink: S,
    driver: F,
) -> Result<(SchedStats, R)>
where
    B: ServeBackend,
    R: Send,
    F: FnOnce(&ServerClient) -> R + Send,
    S: Fn(usize, Response) + Sync,
{
    let threads = resolve_threads(cfg.threads).max(1);
    let replicas = cfg.replicas.max(1);
    let arrivals: WorkQueue<Arrival> = WorkQueue::new();
    // replicas=1 keeps the single shared dispatch queue (the bit-exact
    // reference path); replicas>1 swaps in the fleet's per-replica lanes
    let single: WorkQueue<Batch> = WorkQueue::new();
    let fleet = (replicas > 1).then(|| {
        Fleet::new(
            replicas,
            cfg.replication.max(1),
            cfg.rebalance_every,
            backend.n_experts(),
            backend.expert_param_bytes(),
        )
    });
    let dispatch = match fleet.as_ref() {
        Some(f) => Dispatch::Fleet(f),
        None => Dispatch::Single(&single),
    };
    let stats: Mutex<SchedStats> = Mutex::new(SchedStats::default());
    let error = ErrSlot::default();
    let client = ServerClient {
        arrivals: &arrivals,
        next_seq: AtomicUsize::new(0),
        stats: &stats,
    };

    let driver_out = std::thread::scope(|s| {
        // move-closure-friendly aliases (the spawns below capture per-
        // replica indices by value, so they must not move the shared
        // structures themselves)
        let (arrivals_r, sink_r, stats_r, error_r) = (&arrivals, &sink, &stats, &error);
        match &dispatch {
            Dispatch::Single(q) => {
                for _ in 0..threads {
                    let q = *q;
                    s.spawn(move || worker_loop(backend, arrivals_r, q, sink_r, stats_r, error_r));
                }
            }
            Dispatch::Fleet(f) => {
                // engine-per-device: exactly one worker drains each lane
                for r in 0..f.set.n_replicas() {
                    let f = *f;
                    s.spawn(move || {
                        replica_worker_loop(
                            backend,
                            r,
                            f.set.lane(r),
                            arrivals_r,
                            sink_r,
                            stats_r,
                            error_r,
                        )
                    });
                }
            }
        }
        s.spawn(|| scheduler_loop(backend, cfg, threads, &arrivals, &dispatch, &stats, &error));
        // the driver runs on the calling thread; closing `arrivals` (on
        // return *or* unwind) is what lets the scheduler drain and exit
        let _close = CloseOnDrop(&arrivals);
        driver(&client)
    });

    if let Some(e) = error.take() {
        return Err(e);
    }
    let submitted = client.submitted();
    let mut stats = stats.into_inner().expect("stats poisoned");
    stats.submitted = submitted;
    if let Some(f) = fleet {
        stats.replica = Some(f.report());
    }
    Ok((stats, driver_out))
}

/// The admission/dispatch loop (one thread). Pending per-expert batches
/// and their linger deadlines are plain locals — only this thread touches
/// them.
fn scheduler_loop<B: ServeBackend>(
    backend: &B,
    cfg: &ServerConfig,
    threads: usize,
    arrivals: &WorkQueue<Arrival>,
    dispatch: &Dispatch<'_>,
    stats: &Mutex<SchedStats>,
    error: &ErrSlot,
) {
    // a panicking or erroring scheduler must still release the workers
    let _close = CloseDispatchOnDrop(dispatch);
    let ne = backend.n_experts();
    let batch_size = if cfg.batch_size == 0 {
        usize::MAX
    } else {
        cfg.batch_size
    };
    let admission_max = if cfg.admission_max == 0 {
        usize::MAX
    } else {
        cfg.admission_max
    };
    let linger = if cfg.max_wait_us == u64::MAX {
        None
    } else {
        Some(Duration::from_micros(cfg.max_wait_us))
    };
    let mut pending: Vec<Vec<Admitted>> = (0..ne).map(|_| Vec::new()).collect();
    // linger deadline of the oldest member of each non-empty pending batch
    let mut deadline: Vec<Option<Instant>> = vec![None; ne];
    // prefix-routing memo: scheduler-local, revalidated per wave
    let mut memo = RouteMemo {
        fingerprint: backend.router_fingerprint(),
        map: HashMap::new(),
    };

    loop {
        if error.is_set() {
            return; // _close releases the workers; run_server reports
        }
        let next_deadline = deadline.iter().flatten().min().copied();
        let first = match next_deadline {
            None => match arrivals.pop() {
                Some(a) => Some(a),
                None => break, // closed + drained: final flush below
            },
            Some(d) => {
                let now = Instant::now();
                if d <= now {
                    None // expired: flush without waiting for arrivals
                } else {
                    match arrivals.pop_timeout(d - now) {
                        Pop::Item(a) => Some(a),
                        Pop::TimedOut => None,
                        Pop::Closed => break,
                    }
                }
            }
        };

        if let Some(first) = first {
            // admission wave: the woken arrival plus whatever else is
            // already queued, up to the admission cap
            let mut wave = vec![first];
            wave.extend(arrivals.drain_up_to(admission_max.saturating_sub(1)));
            if let Err(e) = admit(
                backend,
                wave,
                threads,
                batch_size,
                linger,
                &mut memo,
                &mut pending,
                &mut deadline,
                dispatch,
                stats,
            ) {
                error.record(e);
                // fail fast: refuse further submissions so a streaming
                // driver sees `submit` return false instead of feeding a
                // dead server until its stream runs out
                arrivals.close();
                return;
            }
            if let Dispatch::Fleet(f) = dispatch {
                f.maybe_rebalance(stats);
            }
        }
        flush_expired(&mut pending, &mut deadline, dispatch, stats);
    }

    // drain: everything still pending leaves as partial batches
    for e in 0..ne {
        if !pending[e].is_empty() {
            deadline[e] = None;
            let items = std::mem::take(&mut pending[e]);
            dispatch_batch(e, items, DispatchKind::Drain, dispatch, stats);
        }
    }
}

#[derive(Clone, Copy)]
enum DispatchKind {
    Full,
    Linger,
    Drain,
}

/// Route one admission wave — replaying memoized prefixes and batch-
/// scoring only the misses — and file each request into its expert's
/// pending batch, dispatching any batch that reaches `batch_size`.
#[allow(clippy::too_many_arguments)]
fn admit<B: ServeBackend>(
    backend: &B,
    wave: Vec<Arrival>,
    threads: usize,
    batch_size: usize,
    linger: Option<Duration>,
    memo: &mut RouteMemo,
    pending: &mut [Vec<Admitted>],
    deadline: &mut [Option<Instant>],
    dispatch: &Dispatch<'_>,
    stats: &Mutex<SchedStats>,
) -> Result<()> {
    let ne = pending.len();
    // any router version bump invalidates every memoized route
    let fp = backend.router_fingerprint();
    if fp != memo.fingerprint {
        memo.map.clear();
        memo.fingerprint = fp;
    }
    let mut keys: Vec<Option<Vec<u32>>> = wave
        .iter()
        .map(|a| backend.route_memo_key(&a.req.tokens))
        .collect();
    let mut routes: Vec<Option<usize>> = keys
        .iter()
        .map(|k| k.as_ref().and_then(|k| memo.map.get(k).copied()))
        .collect();
    let hits = routes.iter().flatten().count();
    let misses: Vec<usize> = (0..wave.len()).filter(|&i| routes[i].is_none()).collect();
    let t0 = Instant::now();
    if !misses.is_empty() {
        let rows: Vec<&[u32]> = misses
            .iter()
            .map(|&i| wave[i].req.tokens.as_slice())
            .collect();
        let scored = backend.route(&rows, threads)?;
        if scored.len() != rows.len() {
            bail!(
                "backend routed {} of {} admitted requests",
                scored.len(),
                rows.len()
            );
        }
        for (&i, &e) in misses.iter().zip(&scored) {
            routes[i] = Some(e);
            if let Some(k) = keys[i].take() {
                if memo.map.len() >= ROUTE_MEMO_CAP {
                    memo.map.clear();
                }
                memo.map.insert(k, e);
            }
        }
    }
    let routed_t = Instant::now();
    let route_us = amortized_micros(routed_t - t0, wave.len());
    {
        let mut st = stats.lock().expect("stats poisoned");
        st.admission_waves += 1;
        st.admitted += wave.len();
        st.route_cache_hits += hits;
        // per-expert route counts feed the fleet's online rebalance;
        // out-of-range routes are rejected below, so skip them here
        if st.route_histogram.len() < ne {
            st.route_histogram.resize(ne, 0);
        }
        for e in routes.iter().flatten() {
            if *e < ne {
                st.route_histogram[*e] += 1;
            }
        }
    }
    for (a, e) in wave.into_iter().zip(routes) {
        let e = e.expect("every admission route resolved above");
        if e >= ne {
            bail!(
                "route index {e} out of range for {ne} experts (request id {})",
                a.req.id
            );
        }
        pending[e].push(Admitted {
            seq: a.seq,
            pre_route_wait: t0.saturating_duration_since(a.submit_t),
            routed_t,
            route_us,
            req: a.req,
        });
        while pending[e].len() >= batch_size {
            let items: Vec<Admitted> = pending[e].drain(..batch_size).collect();
            dispatch_batch(e, items, DispatchKind::Full, dispatch, stats);
        }
        // the linger window is anchored at the oldest survivor's own
        // admission time, NOT Instant::now(): restarting from "now" after
        // a full-batch dispatch would hand a surviving request a fresh
        // full window on top of what it already waited (~2x max_wait_us
        // worst case)
        deadline[e] = linger_deadline(&pending[e], linger);
    }
    Ok(())
}

/// Linger deadline of a pending batch: the oldest member's admission time
/// plus the linger window, or `None` for an empty batch / no timer.
/// `checked_add`: an absurdly large (but non-MAX) linger degrades to "no
/// timer" instead of panicking on `Instant` overflow.
fn linger_deadline(pending: &[Admitted], linger: Option<Duration>) -> Option<Instant> {
    let oldest = pending.first()?;
    linger.and_then(|l| oldest.routed_t.checked_add(l))
}

/// Dispatch every pending batch whose linger deadline has passed.
fn flush_expired(
    pending: &mut [Vec<Admitted>],
    deadline: &mut [Option<Instant>],
    dispatch: &Dispatch<'_>,
    stats: &Mutex<SchedStats>,
) {
    let now = Instant::now();
    for e in 0..pending.len() {
        if matches!(deadline[e], Some(d) if d <= now) {
            deadline[e] = None;
            let items = std::mem::take(&mut pending[e]);
            if !items.is_empty() {
                dispatch_batch(e, items, DispatchKind::Linger, dispatch, stats);
            }
        }
    }
}

fn dispatch_batch(
    expert: usize,
    items: Vec<Admitted>,
    kind: DispatchKind,
    dispatch: &Dispatch<'_>,
    stats: &Mutex<SchedStats>,
) {
    // sample the backlog BEFORE pushing: an idle pool reads 0, not a
    // self-inflicted 1 (the fleet samples the chosen lane's own depth)
    let depth = match dispatch {
        Dispatch::Single(q) => {
            let depth = q.len();
            q.push(Batch { expert, items });
            depth
        }
        // a fully closed fleet (shutdown race) drops the batch exactly
        // like a closed single queue would
        Dispatch::Fleet(f) => f.dispatch(Batch { expert, items }).unwrap_or(0),
    };
    let mut st = stats.lock().expect("stats poisoned");
    st.batches_dispatched += 1;
    match kind {
        DispatchKind::Full => st.full_batches += 1,
        DispatchKind::Linger => st.linger_batches += 1,
        DispatchKind::Drain => st.drain_batches += 1,
    }
    st.depth_sum += depth;
    st.depth_samples += 1;
}

/// One worker: pull dispatched batches until the queue closes, execute
/// them, hand each response to the sink with its submission index. On a
/// backend failure the worker records the first error and closes
/// `arrivals`, so a streaming driver fails fast (its next `submit`
/// returns false) instead of feeding a server that will drop everything.
fn worker_loop<B: ServeBackend, S: Fn(usize, Response) + Sync>(
    backend: &B,
    arrivals: &WorkQueue<Arrival>,
    dispatch: &WorkQueue<Batch>,
    sink: &S,
    stats: &Mutex<SchedStats>,
    error: &ErrSlot,
) {
    let mut finished_one = false;
    loop {
        let batch = match dispatch.try_pop() {
            Some(b) => {
                if finished_one {
                    // the freed slot was refilled without blocking
                    stats.lock().expect("stats poisoned").slots_refilled += 1;
                }
                b
            }
            None => match dispatch.pop() {
                Some(b) => b,
                None => return,
            },
        };
        if error.is_set() {
            finished_one = true;
            continue; // shutting down: drop the batch, keep draining
        }
        execute_batch(backend, 0, batch, arrivals, sink, stats, error);
        finished_one = true;
    }
}

/// Replica `replica`'s worker: drains its own lane only, keeping the
/// lane's queued/in-flight/executed row counters exact so the
/// dispatcher's load signal and the per-replica balance accounting stay
/// truthful. Same shutdown behavior as [`worker_loop`].
fn replica_worker_loop<B: ServeBackend, S: Fn(usize, Response) + Sync>(
    backend: &B,
    replica: usize,
    lane: &ReplicaLane<Batch>,
    arrivals: &WorkQueue<Arrival>,
    sink: &S,
    stats: &Mutex<SchedStats>,
    error: &ErrSlot,
) {
    let mut finished_one = false;
    loop {
        let batch = match lane.queue.try_pop() {
            Some(b) => {
                if finished_one {
                    stats.lock().expect("stats poisoned").slots_refilled += 1;
                }
                b
            }
            None => match lane.queue.pop() {
                Some(b) => b,
                None => return,
            },
        };
        let rows = batch.items.len();
        lane.begin(rows);
        if error.is_set() {
            lane.abort(rows);
            finished_one = true;
            continue; // shutting down: drop the batch, keep draining
        }
        if execute_batch(backend, replica, batch, arrivals, sink, stats, error) {
            lane.complete(rows);
        } else {
            lane.abort(rows);
        }
        finished_one = true;
    }
}

/// Execute one dispatched batch on `replica` and sink its responses.
/// Returns whether execution succeeded; on failure the first error is
/// recorded and `arrivals` is closed so a streaming driver fails fast.
fn execute_batch<B: ServeBackend, S: Fn(usize, Response) + Sync>(
    backend: &B,
    replica: usize,
    batch: Batch,
    arrivals: &WorkQueue<Arrival>,
    sink: &S,
    stats: &Mutex<SchedStats>,
    error: &ErrSlot,
) -> bool {
    let rows: Vec<&[u32]> = batch.items.iter().map(|a| a.req.tokens.as_slice()).collect();
    let t0 = Instant::now();
    match backend.exec_nll_replica(replica, batch.expert, &rows) {
        Err(e) => {
            error.record(e);
            arrivals.close();
            false
        }
        Ok(nll) if nll.len() != rows.len() => {
            error.record(anyhow!(
                "backend returned {} NLLs for a {}-row batch",
                nll.len(),
                rows.len()
            ));
            arrivals.close();
            false
        }
        Ok(nll) => {
            let exec_us = amortized_micros(t0.elapsed(), rows.len());
            for (item, &v) in batch.items.iter().zip(&nll) {
                // queue time = arrival-queue wait + pending/dispatch
                // wait; the routing span in between belongs to
                // route_micros, so total_micros never double-counts
                let queued = item.pre_route_wait
                    + t0.saturating_duration_since(item.routed_t);
                sink(
                    item.seq,
                    Response {
                        id: item.req.id,
                        expert: batch.expert,
                        nll: v,
                        queue_micros: queued.as_micros(),
                        route_micros: item.route_us,
                        exec_micros: exec_us,
                    },
                );
            }
            stats.lock().expect("stats poisoned").completed += batch.items.len();
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic model-free backend: route by first token, NLL is a
    /// pure function of (expert, tokens) — so triples are comparable
    /// across any batching.
    struct StubBackend {
        n: usize,
    }

    impl ServeBackend for StubBackend {
        fn n_experts(&self) -> usize {
            self.n
        }
        fn route(&self, rows: &[&[u32]], _threads: usize) -> Result<Vec<usize>> {
            Ok(rows
                .iter()
                .map(|r| r.first().copied().unwrap_or(0) as usize % self.n)
                .collect())
        }
        fn exec_nll(&self, expert: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
            Ok(rows
                .iter()
                .map(|r| expert as f32 * 1000.0 + r.iter().sum::<u32>() as f32)
                .collect())
        }
    }

    fn req(id: u64, tokens: Vec<u32>) -> Request {
        Request { id, tokens }
    }

    #[test]
    fn responses_come_back_in_submission_order() {
        let backend = StubBackend { n: 3 };
        let cfg = ServerConfig::continuous(2, 1000, 2);
        let reqs: Vec<Request> = (0..7).map(|i| req(100 + i, vec![i as u32, 5])).collect();
        let (out, stats, ()) = run_server(&backend, &cfg, |c| {
            for r in &reqs {
                c.submit(r.clone());
            }
        })
        .unwrap();
        assert_eq!(out.len(), 7);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id, 100 + i as u64, "submission order broken at {i}");
            assert_eq!(r.expert, i % 3);
            assert_eq!(r.nll, (i % 3) as f32 * 1000.0 + (i as u32 + 5) as f32);
        }
        assert_eq!(stats.submitted, 7);
        assert_eq!(stats.admitted, 7);
        assert_eq!(stats.completed, 7);
    }

    #[test]
    fn closed_wave_config_admits_one_wave_and_drains_groups() {
        let backend = StubBackend { n: 2 };
        let cfg = ServerConfig::closed_wave(2);
        let reqs: Vec<Request> = (0..6).map(|i| req(i, vec![i as u32; 3])).collect();
        let (out, stats, ()) = run_server(&backend, &cfg, |c| {
            c.submit_wave(reqs.clone());
        })
        .unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(stats.admission_waves, 1, "one atomic wave = one score call");
        // 2 experts, both non-empty: each leaves as a single drain batch
        assert_eq!(stats.batches_dispatched, 2);
        assert_eq!(stats.drain_batches, 2);
        assert_eq!(stats.full_batches + stats.linger_batches, 0);
    }

    #[test]
    fn linger_deadline_is_anchored_at_admission_not_at_dispatch() {
        // Regression for the survivor-linger bug: after a full-batch
        // dispatch the deadline used to restart from Instant::now(),
        // handing survivors a fresh window on top of what they had
        // already waited (~2x max_wait_us). The deadline must be the
        // oldest survivor's own admission time plus the window.
        let linger = Some(Duration::from_millis(50));
        let routed_t = Instant::now()
            .checked_sub(Duration::from_millis(40))
            .unwrap_or_else(Instant::now);
        let survivor = Admitted {
            seq: 3,
            pre_route_wait: Duration::ZERO,
            routed_t,
            route_us: 0,
            req: req(1, vec![1, 2]),
        };
        let d = linger_deadline(std::slice::from_ref(&survivor), linger)
            .expect("non-empty batch with a timer has a deadline");
        assert_eq!(d, routed_t + Duration::from_millis(50));
        // the pre-fix anchor (now + linger) would land ~40ms later
        assert!(
            d < Instant::now() + Duration::from_millis(50),
            "deadline restarted from now instead of the survivor's admission"
        );
        // empty batch: no deadline
        assert!(linger_deadline(&[], linger).is_none());
        // absurd linger degrades to "no timer" instead of overflowing
        let huge = Some(Duration::from_secs(u64::MAX));
        assert!(linger_deadline(std::slice::from_ref(&survivor), huge).is_none());
    }

    #[test]
    fn try_submit_sheds_at_high_water_without_burning_sequence_slots() {
        let backend = StubBackend { n: 2 };
        let cfg = ServerConfig::continuous(2, 1000, 1);
        let (out, stats, accepted) = run_server(&backend, &cfg, |c| {
            // high_water 0 sheds everything
            assert_eq!(c.try_submit(req(9, vec![1]), 0), SubmitOutcome::Shed);
            let mut accepted = 0;
            for i in 0..5u64 {
                if c.try_submit(req(i, vec![i as u32]), 1024) == SubmitOutcome::Accepted {
                    accepted += 1;
                }
            }
            accepted
        })
        .unwrap();
        assert_eq!(accepted, 5);
        assert_eq!(out.len(), 5, "shed request must not leave a response hole");
        assert_eq!(stats.shed, 1);
        assert_eq!(
            stats.submitted, 5,
            "a shed request must not consume a sequence slot"
        );
        assert_eq!(stats.completed, 5);
    }

    #[test]
    fn streaming_sink_sees_every_response_exactly_once() {
        let backend = StubBackend { n: 3 };
        let cfg = ServerConfig::continuous(2, 500, 2);
        let seen: Mutex<Vec<(usize, u64, usize, f32)>> = Mutex::new(Vec::new());
        let (stats, ()) = run_server_streaming(
            &backend,
            &cfg,
            |seq, r| {
                seen.lock().unwrap().push((seq, r.id, r.expert, r.nll));
            },
            |c| {
                for i in 0..9u64 {
                    c.submit(req(200 + i, vec![i as u32, 7]));
                }
            },
        )
        .unwrap();
        let mut seen = seen.into_inner().unwrap();
        seen.sort_by_key(|&(seq, ..)| seq);
        assert_eq!(seen.len(), 9);
        for (i, &(seq, id, expert, nll)) in seen.iter().enumerate() {
            assert_eq!(seq, i, "every submission index answered exactly once");
            assert_eq!(id, 200 + i as u64);
            assert_eq!(expert, i % 3);
            assert_eq!(nll, (i % 3) as f32 * 1000.0 + (i as u32 + 7) as f32);
        }
        assert_eq!(stats.completed, 9);
    }

    #[test]
    fn replicated_dispatch_matches_the_single_queue_reference() {
        let backend = StubBackend { n: 3 };
        let reqs: Vec<Request> = (0..24).map(|i| req(300 + i, vec![i as u32, 9])).collect();
        let run = |cfg: &ServerConfig| {
            let (out, stats, ()) = run_server(&backend, cfg, |c| {
                for r in &reqs {
                    c.submit(r.clone());
                }
            })
            .unwrap();
            let mut triples: Vec<(u64, usize, u32)> =
                out.iter().map(|r| (r.id, r.expert, r.nll.to_bits())).collect();
            triples.sort_unstable();
            (triples, stats)
        };
        let (reference, ref_stats) = run(&ServerConfig::continuous(2, 1000, 2));
        assert!(ref_stats.replica.is_none(), "replicas=1 must not build a fleet");
        let (fleet, stats) = run(&ServerConfig::continuous(2, 1000, 2).with_replicas(3, 2, 1));
        assert_eq!(fleet, reference, "replica choice changed a triple");
        let rep = stats.replica.expect("replicated run reports fleet stats");
        assert_eq!(rep.replicas, 3);
        assert_eq!(rep.executed_rows.iter().sum::<usize>(), stats.completed);
        assert_eq!(
            rep.sync_bytes,
            rep.moves as u64 * backend.expert_param_bytes(),
            "ledger bytes must reconcile against placement moves"
        );
        // the route histogram feeds the rebalance: every admit counted
        assert_eq!(stats.route_histogram.iter().sum::<usize>(), stats.admitted);
    }

    #[test]
    fn route_out_of_range_is_a_structured_error() {
        struct BadRouter;
        impl ServeBackend for BadRouter {
            fn n_experts(&self) -> usize {
                2
            }
            fn route(&self, rows: &[&[u32]], _t: usize) -> Result<Vec<usize>> {
                Ok(vec![9; rows.len()])
            }
            fn exec_nll(&self, _e: usize, rows: &[&[u32]]) -> Result<Vec<f32>> {
                Ok(vec![0.0; rows.len()])
            }
        }
        let err = run_server(&BadRouter, &ServerConfig::continuous(2, 100, 1), |c| {
            c.submit(req(42, vec![1, 2]));
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("route index 9"), "{msg}");
        assert!(msg.contains("2 experts"), "{msg}");
        assert!(msg.contains("request id 42"), "{msg}");
    }

    #[test]
    fn exec_error_shuts_down_and_propagates() {
        struct FailingExec;
        impl ServeBackend for FailingExec {
            fn n_experts(&self) -> usize {
                2
            }
            fn route(&self, rows: &[&[u32]], _t: usize) -> Result<Vec<usize>> {
                Ok(vec![0; rows.len()])
            }
            fn exec_nll(&self, _e: usize, _rows: &[&[u32]]) -> Result<Vec<f32>> {
                bail!("device lost")
            }
        }
        let err = run_server(&FailingExec, &ServerConfig::continuous(1, 100, 2), |c| {
            for i in 0..4 {
                c.submit(req(i, vec![0, 1]));
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("device lost"), "{err}");
    }
}
