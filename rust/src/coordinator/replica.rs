//! Multi-replica expert serving: the placement and dispatch layer between
//! admission and the worker pool (the ROADMAP's "multi-replica expert
//! sharding" open item).
//!
//! At millions of users a single engine owning every expert makes the hot
//! expert the serving bottleneck — the router concentrates traffic on few
//! experts *because* specialization works. This module models a fleet of
//! N engine replicas (engine-per-device; the stub backend keeps the whole
//! fleet tier-1-testable):
//!
//! * [`PlacementMap`] — which replicas hold which expert. Every expert has
//!   at least one holder; **hot** experts are replicated onto several.
//! * [`ReplicaSet`] — one work lane per replica (own queue + load
//!   counters) and a least-loaded dispatcher: each dispatched batch goes
//!   to the cheapest *live* replica holding its expert, load measured as
//!   queued rows + in-flight rows.
//! * [`ReplicaReport`] — per-replica executed-row accounting plus the
//!   rebalance/sync audit the serve path surfaces through `SchedStats`.
//!
//! # Replication semantics: a floor, escalated by demand
//!
//! The `replication` knob is the **minimum holder count for hot experts**,
//! not a cap. An expert whose histogram load exceeds its fair share
//! (`total / replicas`) gets
//!
//! ```text
//! copies = min(replicas, max(replication, ceil(load / fair_share)))
//! ```
//!
//! holders; a cold expert gets exactly one. `replication == 1` disables
//! replication entirely (pure partitioning). The escalation term is what
//! makes heavy skew balanceable: with 4 replicas and 70% of traffic on
//! one expert, a hard cap of 2 copies could never get per-replica load
//! under 35% vs 15% (2.33x); escalating the hot expert to 3 holders lands
//! every replica between ~23% and 30% (≤ 1.3x).
//!
//! # Determinism
//!
//! Replica choice can never change a response: expert NLL is a pure
//! function of `(expert, rows)` and batch composition is decided *before*
//! the replica is picked, so the `(id, expert, nll)` triple set is
//! identical for any replica count, placement, or rebalance schedule —
//! `rust/tests/replica.rs` asserts this against the replicas=1 reference.
//! Load counters are read racily by design (they only steer balance), and
//! equal-load ties rotate round-robin so an idle fleet still spreads a
//! hot expert across all of its holders.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use super::comm::CommLedger;
use crate::runtime::parallel::WorkQueue;

/// One placement move: `to_replica` becomes a (new) holder of `expert`
/// and must sync the expert's parameters — audited through the comm
/// ledger as a [`super::comm::CommKind::ReplicaSync`] event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementMove {
    pub expert: usize,
    pub to_replica: usize,
}

/// Expert → replica placement: `holders[e]` is the sorted, non-empty set
/// of replica indices serving expert `e`.
#[derive(Clone, Debug)]
pub struct PlacementMap {
    replicas: usize,
    holders: Vec<Vec<usize>>,
}

/// Holder count for an expert under the floor-plus-escalation rule (see
/// the module header). `total == 0` never reaches here (rebalance is a
/// no-op on an empty histogram).
fn copies_for(load: usize, total: usize, replicas: usize, replication: usize) -> usize {
    if replication <= 1 || replicas <= 1 || load == 0 {
        return 1;
    }
    let fair = total as f64 / replicas as f64;
    if (load as f64) <= fair {
        1
    } else {
        let demand = ((load as f64) / fair).ceil() as usize;
        demand.max(replication).min(replicas)
    }
}

impl PlacementMap {
    /// Placement before any traffic has been observed: with no histogram
    /// there are no hot experts yet, so every expert gets
    /// `min(replication, replicas)` holders, assigned round-robin.
    pub fn initial(n_experts: usize, replicas: usize, replication: usize) -> Self {
        let replicas = replicas.max(1);
        let copies = replication.clamp(1, replicas);
        let mut cursor = 0usize;
        let holders = (0..n_experts)
            .map(|_| {
                let mut h: Vec<usize> = (0..copies)
                    .map(|_| {
                        let r = cursor % replicas;
                        cursor += 1;
                        r
                    })
                    .collect();
                h.sort_unstable();
                h
            })
            .collect();
        PlacementMap { replicas, holders }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas
    }

    pub fn n_experts(&self) -> usize {
        self.holders.len()
    }

    /// Replicas holding `expert` (sorted, never empty).
    pub fn holders(&self, expert: usize) -> &[usize] {
        &self.holders[expert]
    }

    /// Add `replica` as a holder of `expert` (the emergency path when
    /// every mapped holder is dead). Returns `true` if it was new — the
    /// caller audits the implied parameter sync.
    pub fn insert_holder(&mut self, expert: usize, replica: usize) -> bool {
        let h = &mut self.holders[expert];
        match h.binary_search(&replica) {
            Ok(_) => false,
            Err(i) => {
                h.insert(i, replica);
                true
            }
        }
    }

    /// Recompute placement from a route histogram (`histogram[e]` =
    /// requests admitted for expert `e`). Returns the new map plus the
    /// moves (new holders only — dropping a copy ships no bytes), so the
    /// comm ledger's replica-sync traffic reconciles in closed form:
    /// `sync_bytes == moves.len() * expert_param_bytes`.
    ///
    /// Deterministic greedy: experts in descending load order (ties by
    /// index) each place `copies_for(load)` holders, one at a time, on
    /// the replica with the least accumulated load share that doesn't
    /// already hold the expert — preferring current holders on exact ties
    /// so a steady histogram converges to zero moves.
    pub fn rebalanced(&self, histogram: &[usize], replication: usize) -> (PlacementMap, Vec<PlacementMove>) {
        let ne = self.holders.len();
        let load = |e: usize| histogram.get(e).copied().unwrap_or(0);
        let total: usize = (0..ne).map(load).sum();
        if total == 0 || self.replicas <= 1 {
            return (self.clone(), Vec::new());
        }
        let mut order: Vec<usize> = (0..ne).collect();
        order.sort_by(|&a, &b| load(b).cmp(&load(a)).then(a.cmp(&b)));
        let mut acc = vec![0.0f64; self.replicas];
        let mut holders: Vec<Vec<usize>> = vec![Vec::new(); ne];
        for e in order {
            let copies = copies_for(load(e), total, self.replicas, replication);
            let share = load(e) as f64 / copies as f64;
            for _ in 0..copies {
                let r = (0..self.replicas)
                    .filter(|r| !holders[e].contains(r))
                    .min_by(|&a, &b| {
                        acc[a]
                            .total_cmp(&acc[b])
                            .then_with(|| {
                                let held = |r: usize| usize::from(!self.holders[e].contains(&r));
                                held(a).cmp(&held(b))
                            })
                            .then(a.cmp(&b))
                    })
                    .expect("copies <= replicas leaves a candidate");
                holders[e].push(r);
                acc[r] += share;
            }
            holders[e].sort_unstable();
        }
        let mut moves = Vec::new();
        for (e, new) in holders.iter().enumerate() {
            for &r in new {
                if !self.holders[e].contains(&r) {
                    moves.push(PlacementMove { expert: e, to_replica: r });
                }
            }
        }
        (
            PlacementMap {
                replicas: self.replicas,
                holders,
            },
            moves,
        )
    }
}

/// One replica's work lane: its own dispatch queue plus the load/audit
/// counters. Queued/in-flight counts are the dispatcher's load signal;
/// executed counts feed [`ReplicaReport`]. All atomics are `Relaxed` —
/// they steer balance and report totals, they synchronize nothing.
pub struct ReplicaLane<T> {
    pub queue: WorkQueue<T>,
    queued_rows: AtomicUsize,
    inflight_rows: AtomicUsize,
    executed_rows: AtomicUsize,
    executed_batches: AtomicUsize,
    live: AtomicBool,
}

impl<T> ReplicaLane<T> {
    fn new() -> Self {
        ReplicaLane {
            queue: WorkQueue::new(),
            queued_rows: AtomicUsize::new(0),
            inflight_rows: AtomicUsize::new(0),
            executed_rows: AtomicUsize::new(0),
            executed_batches: AtomicUsize::new(0),
            live: AtomicBool::new(true),
        }
    }

    /// The dispatcher's load signal: rows waiting in this lane's queue
    /// plus rows currently executing on the replica.
    pub fn load(&self) -> usize {
        self.queued_rows.load(Ordering::Relaxed) + self.inflight_rows.load(Ordering::Relaxed)
    }

    /// Worker-side: a popped batch of `rows` rows starts executing.
    pub fn begin(&self, rows: usize) {
        self.queued_rows.fetch_sub(rows, Ordering::Relaxed);
        self.inflight_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Worker-side: the batch finished successfully.
    pub fn complete(&self, rows: usize) {
        self.inflight_rows.fetch_sub(rows, Ordering::Relaxed);
        self.executed_rows.fetch_add(rows, Ordering::Relaxed);
        self.executed_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Worker-side: the batch was dropped (error drain) — in-flight rows
    /// leave without counting as executed.
    pub fn abort(&self, rows: usize) {
        self.inflight_rows.fetch_sub(rows, Ordering::Relaxed);
    }

    pub fn executed_rows(&self) -> usize {
        self.executed_rows.load(Ordering::Relaxed)
    }

    pub fn executed_batches(&self) -> usize {
        self.executed_batches.load(Ordering::Relaxed)
    }

    pub fn is_live(&self) -> bool {
        self.live.load(Ordering::Relaxed)
    }

    /// Mark the replica dead/alive (chaos hooks and unit tests; the serve
    /// path keeps every replica live).
    pub fn set_live(&self, live: bool) {
        self.live.store(live, Ordering::Relaxed);
    }
}

/// Outcome of one [`ReplicaSet::dispatch`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DispatchPick {
    /// The lane the batch went to.
    pub replica: usize,
    /// That lane's queue depth (batches) sampled before the push — the
    /// scheduler's `mean_queue_depth` sample, same convention as the
    /// single-queue path.
    pub depth: usize,
    /// `true` when no live holder existed and the batch fell back to the
    /// least-loaded live replica *outside* the placement — the caller
    /// must promote that replica to a holder and audit the sync.
    pub fallback: bool,
}

/// The replica fleet: one [`ReplicaLane`] per engine replica.
pub struct ReplicaSet<T> {
    lanes: Vec<ReplicaLane<T>>,
    /// Rotates equal-load tie-breaking so an idle fleet round-robins a
    /// hot expert across all of its holders instead of pinning the
    /// lowest index.
    rotation: AtomicUsize,
}

impl<T> ReplicaSet<T> {
    pub fn new(replicas: usize) -> Self {
        ReplicaSet {
            lanes: (0..replicas.max(1)).map(|_| ReplicaLane::new()).collect(),
            rotation: AtomicUsize::new(0),
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane(&self, replica: usize) -> &ReplicaLane<T> {
        &self.lanes[replica]
    }

    /// Route one batch of `rows` rows to the least-loaded live replica in
    /// `holders` (ties rotate). Falls back to the least-loaded live
    /// replica overall when every holder is dead, and returns `None` only
    /// when no replica is live at all (the batch is handed back in that
    /// case — the caller owns the failure).
    pub fn dispatch(&self, holders: &[usize], rows: usize, item: T) -> Result<DispatchPick, T> {
        let n = self.lanes.len();
        let rot = self.rotation.fetch_add(1, Ordering::Relaxed);
        // rotate over candidate-list *position*, not replica index: a
        // holder set that is a strict subset of the fleet would otherwise
        // favor whichever index the modular wrap lands on (e.g. holders
        // {0,1,2} of 4 send half of all equal-load ties to replica 0)
        let pick_from = |cands: &[usize]| -> Option<usize> {
            let m = cands.len();
            cands
                .iter()
                .enumerate()
                .min_by_key(|&(i, &r)| (self.lanes[r].load(), (i + m - rot % m) % m))
                .map(|(_, &r)| r)
        };
        let live_holders: Vec<usize> = holders
            .iter()
            .copied()
            .filter(|&r| r < n && self.lanes[r].is_live())
            .collect();
        let (replica, fallback) = match pick_from(&live_holders) {
            Some(r) => (r, false),
            None => {
                let live: Vec<usize> =
                    (0..n).filter(|&r| self.lanes[r].is_live()).collect();
                match pick_from(&live) {
                    Some(r) => (r, true),
                    None => return Err(item),
                }
            }
        };
        let lane = &self.lanes[replica];
        let depth = lane.queue.len();
        lane.queued_rows.fetch_add(rows, Ordering::Relaxed);
        if !lane.queue.push(item) {
            // closed (shutdown): the item was dropped by the queue
            lane.queued_rows.fetch_sub(rows, Ordering::Relaxed);
        }
        Ok(DispatchPick {
            replica,
            depth,
            fallback,
        })
    }

    /// Close every lane queue (drain/shutdown; idempotent).
    pub fn close_all(&self) {
        for lane in &self.lanes {
            lane.queue.close();
        }
    }

    pub fn executed_rows(&self) -> Vec<usize> {
        self.lanes.iter().map(|l| l.executed_rows()).collect()
    }

    pub fn executed_batches(&self) -> Vec<usize> {
        self.lanes.iter().map(|l| l.executed_batches()).collect()
    }
}

/// Replica-fleet accounting surfaced through `SchedStats::replica` after
/// a replicated serve run.
#[derive(Clone, Debug, Default)]
pub struct ReplicaReport {
    /// Engine replicas in the fleet.
    pub replicas: usize,
    /// The configured hot-expert replication floor.
    pub replication: usize,
    /// Rebalance epochs that ran (histogram recomputations, with or
    /// without resulting moves).
    pub rebalances: usize,
    /// Placement moves applied (new holders only), including emergency
    /// fallback promotions.
    pub moves: usize,
    /// Exact replica-sync bytes audited — always `moves * expert_param_bytes`.
    pub sync_bytes: u64,
    /// Dispatches that found every mapped holder dead and fell back.
    pub fallback_dispatches: usize,
    /// Rows executed per replica — the balance acceptance signal.
    pub executed_rows: Vec<usize>,
    /// Batches executed per replica.
    pub executed_batches: Vec<usize>,
    /// The full replica-sync ledger (one `ReplicaSync` event per move).
    pub ledger: CommLedger,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_placement_spreads_copies_round_robin() {
        let p = PlacementMap::initial(4, 4, 2);
        assert_eq!(p.n_replicas(), 4);
        assert_eq!(p.n_experts(), 4);
        // cursor walk: e0 {0,1}, e1 {2,3}, e2 {0,1}, e3 {2,3}
        assert_eq!(p.holders(0), &[0, 1]);
        assert_eq!(p.holders(1), &[2, 3]);
        assert_eq!(p.holders(2), &[0, 1]);
        assert_eq!(p.holders(3), &[2, 3]);
        // replication is clamped to the fleet size, and never below 1
        let p = PlacementMap::initial(2, 3, 9);
        assert!(p.holders(0).len() == 3 && p.holders(1).len() == 3);
        let p = PlacementMap::initial(3, 2, 0);
        for e in 0..3 {
            assert_eq!(p.holders(e).len(), 1);
        }
    }

    #[test]
    fn rebalance_is_a_noop_on_an_empty_histogram() {
        let p = PlacementMap::initial(3, 2, 2);
        let (q, moves) = p.rebalanced(&[0, 0, 0], 2);
        assert!(moves.is_empty());
        for e in 0..3 {
            assert_eq!(q.holders(e), p.holders(e));
        }
    }

    #[test]
    fn replication_one_is_pure_partitioning() {
        let p = PlacementMap::initial(4, 4, 1);
        let (q, _) = p.rebalanced(&[70, 10, 10, 10], 1);
        for e in 0..4 {
            assert_eq!(q.holders(e).len(), 1, "replication=1 never replicates");
        }
    }

    #[test]
    fn hot_expert_escalates_past_the_replication_floor() {
        // 70% on expert 0, fair share 25%: floor 2 escalates to
        // ceil(70/25) = 3 holders; cold experts keep exactly 1.
        let p = PlacementMap::initial(4, 4, 2);
        let hist = [70usize, 10, 10, 10];
        let (q, moves) = p.rebalanced(&hist, 2);
        assert_eq!(q.holders(0).len(), 3);
        for e in 1..4 {
            assert_eq!(q.holders(e).len(), 1);
        }
        // implied per-replica load (each expert splits evenly over its
        // holders) lands within the 2x acceptance bound
        let mut per = [0.0f64; 4];
        for e in 0..4 {
            let share = hist[e] as f64 / q.holders(e).len() as f64;
            for &r in q.holders(e) {
                per[r] += share;
            }
        }
        let (min, max) = per
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        assert!(min > 0.0, "no replica may sit idle: {per:?}");
        assert!(max / min <= 2.0, "imbalance {:.2}x: {per:?}", max / min);
        // every move lands in the new map and none was already held
        for mv in &moves {
            assert!(q.holders(mv.expert).contains(&mv.to_replica));
            assert!(!p.holders(mv.expert).contains(&mv.to_replica));
        }
    }

    #[test]
    fn rebalance_converges_to_zero_moves_on_a_steady_histogram() {
        let p = PlacementMap::initial(4, 4, 2);
        let hist = [70usize, 10, 10, 10];
        let (q, first) = p.rebalanced(&hist, 2);
        assert!(!first.is_empty(), "skew must move something off the initial map");
        let (r, second) = q.rebalanced(&hist, 2);
        assert!(second.is_empty(), "steady histogram re-moved: {second:?}");
        for e in 0..4 {
            assert_eq!(r.holders(e), q.holders(e));
        }
    }

    #[test]
    fn dispatch_picks_the_least_loaded_holder() {
        let set: ReplicaSet<u32> = ReplicaSet::new(3);
        // preload lane 0 with 8 rows, lane 2 with 2 rows
        set.dispatch(&[0], 8, 1).unwrap();
        set.dispatch(&[2], 2, 2).unwrap();
        let pick = set.dispatch(&[0, 2], 4, 3).unwrap();
        assert_eq!(pick.replica, 2);
        assert!(!pick.fallback);
        assert_eq!(set.lane(2).load(), 6);
        // depth sampled before the push: lane 2 already held one batch
        assert_eq!(pick.depth, 1);
    }

    #[test]
    fn equal_load_ties_rotate_across_holders() {
        // instant execution leaves every lane at load 0; the rotation
        // must still spread a hot expert over all of its holders
        let set: ReplicaSet<u32> = ReplicaSet::new(4);
        let mut seen = [0usize; 4];
        for i in 0..12 {
            let pick = set.dispatch(&[0, 1, 2], 1, i).unwrap();
            seen[pick.replica] += 1;
            // drain immediately: back to all-zero loads
            let lane = set.lane(pick.replica);
            lane.queue.try_pop().unwrap();
            lane.begin(1);
            lane.complete(1);
        }
        assert_eq!(seen[3], 0, "non-holder must never be picked");
        for r in 0..3 {
            assert_eq!(seen[r], 4, "ties must round-robin: {seen:?}");
        }
        assert_eq!(set.executed_rows(), vec![4, 4, 4, 0]);
    }

    #[test]
    fn dead_holders_fall_back_to_a_live_replica() {
        let set: ReplicaSet<u32> = ReplicaSet::new(3);
        set.lane(0).set_live(false);
        set.lane(1).set_live(false);
        let pick = set.dispatch(&[0, 1], 1, 7).unwrap();
        assert_eq!(pick.replica, 2);
        assert!(pick.fallback);
        // a whole-fleet outage hands the batch back
        set.lane(2).set_live(false);
        assert_eq!(set.dispatch(&[0, 1], 1, 8).unwrap_err(), 8);
    }

    #[test]
    fn lane_accounting_balances() {
        let set: ReplicaSet<u32> = ReplicaSet::new(1);
        let lane = set.lane(0);
        set.dispatch(&[0], 5, 1).unwrap();
        set.dispatch(&[0], 3, 2).unwrap();
        assert_eq!(lane.load(), 8);
        lane.queue.try_pop().unwrap();
        lane.begin(5);
        assert_eq!(lane.load(), 8, "in-flight rows still count as load");
        lane.complete(5);
        assert_eq!(lane.load(), 3);
        lane.queue.try_pop().unwrap();
        lane.begin(3);
        lane.abort(3);
        assert_eq!(lane.load(), 0);
        assert_eq!(lane.executed_rows(), 5);
        assert_eq!(lane.executed_batches(), 1);
    }

    #[test]
    fn insert_holder_is_idempotent() {
        let mut p = PlacementMap::initial(2, 3, 1);
        let r = (p.holders(0)[0] + 1) % 3;
        assert!(p.insert_holder(0, r));
        assert!(!p.insert_holder(0, r));
        assert!(p.holders(0).windows(2).all(|w| w[0] < w[1]), "holders stay sorted");
    }
}
