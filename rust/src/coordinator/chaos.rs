//! Deterministic fault injection for the elastic trainer.
//!
//! A [`FaultPlan`] is a *schedule* of faults — node kills, transient
//! backend errors, slow-node stalls, dropped snapshot deliveries, and
//! delayed publishes — keyed on per-node **local step counts** and
//! snapshot **versions**, never on wall-clock time. That makes every
//! chaos run replayable: the same plan against the same seeds produces
//! the same kills at the same stream positions regardless of thread
//! count or machine speed.
//!
//! Plans come from two places and meet in one format:
//!
//! * [`FaultPlan::generate`] draws a plan from an [`Rng`] seed and a
//!   [`PlanShape`] (how many of each fault, over how many steps) — the
//!   chaos tests iterate fixed seeds this way.
//! * [`FaultPlan::from_json`] / [`FaultPlan::to_json`] round-trip the
//!   schedule through the repo's JSON so a failing seed can be exported,
//!   edited, and replayed exactly via `--chaos-spec plan.json`.
//!
//! The plan itself is immutable after construction; *consumed* state
//! (which kills already fired, how many transient failures remain) lives
//! behind a mutex so one `Arc<FaultPlan>` can be shared across all node
//! workers. Consumption is what makes kills one-shot: a replacement node
//! adopting a checkpoint resumes at the very step its predecessor was
//! killed at, and must not be killed again by the same spec.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Kill node `node` at the top of its local step `at_step` (before the
/// step trains — a kill at a checkpoint boundary therefore loses zero
/// steps and the adopted replacement resumes bit-identically).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub node: usize,
    pub at_step: u64,
}

/// Fail node `node`'s step `at_step` with a transient error `failures`
/// times before letting it through — exercises the retry/backoff path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransientSpec {
    pub node: usize,
    pub at_step: u64,
    pub failures: u32,
}

/// Stall node `node` for `micros` before its local step `at_step` (a
/// slow node; correctness must not depend on relative node speed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallSpec {
    pub node: usize,
    pub at_step: u64,
    pub micros: u64,
}

/// Drop the delivery of snapshot `version` to node `node`: the node
/// keeps routing against the last snapshot it actually received. Drops
/// affect *adoption timing only* — the ledger records the broadcast
/// against every live subscriber because the publisher did send it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropSpec {
    pub node: usize,
    pub version: u64,
}

/// Delay the publish of snapshot `version` until the run's total trained
/// steps reach `min_total_steps` (a slow router leader).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PublishGate {
    pub version: u64,
    pub min_total_steps: u64,
}

/// How many of each fault [`FaultPlan::generate`] should draw, and the
/// step/version ranges to draw them over.
#[derive(Clone, Copy, Debug)]
pub struct PlanShape {
    pub nodes: usize,
    /// Local steps each node will run; fault steps are drawn in `[1, steps_per_node)`.
    pub steps_per_node: u64,
    pub kills: usize,
    pub transients: usize,
    pub stalls: usize,
    pub drops: usize,
    pub publish_gates: usize,
    /// Snapshot versions the run will publish; drops/gates draw in `[1, versions]`.
    pub snapshot_versions: u64,
}

/// Marker error for an injected (or backend-signalled) transient fault.
/// The elastic trainer retries steps whose error chain downcasts to this
/// type; anything else is terminal for the node (structured
/// `NodeFailed`, never a panic).
#[derive(Debug, Clone, Copy)]
pub struct TransientFault {
    pub node: usize,
    pub step: u64,
}

impl std::fmt::Display for TransientFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transient backend fault (node {}, step {})",
            self.node, self.step
        )
    }
}

impl std::error::Error for TransientFault {}

/// `true` when `err`'s chain contains a [`TransientFault`] — the retry
/// predicate used by the elastic node loop.
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|e| e.downcast_ref::<TransientFault>().is_some())
}

/// Per-plan consumed state (which one-shot faults already fired).
#[derive(Debug, Default)]
struct Consumed {
    kills: Vec<bool>,
    transient_left: Vec<u32>,
    stalls: Vec<bool>,
}

/// A deterministic, replayable schedule of injected faults. See the
/// module docs for the construction/consumption contract.
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub kills: Vec<KillSpec>,
    pub transients: Vec<TransientSpec>,
    pub stalls: Vec<StallSpec>,
    pub drops: Vec<DropSpec>,
    pub publish_gates: Vec<PublishGate>,
    consumed: Mutex<Consumed>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The empty plan: no faults ever fire.
    pub fn none() -> Self {
        FaultPlan::from_specs(0, vec![], vec![], vec![], vec![], vec![])
    }

    fn from_specs(
        seed: u64,
        kills: Vec<KillSpec>,
        transients: Vec<TransientSpec>,
        stalls: Vec<StallSpec>,
        drops: Vec<DropSpec>,
        publish_gates: Vec<PublishGate>,
    ) -> Self {
        let consumed = Consumed {
            kills: vec![false; kills.len()],
            transient_left: transients.iter().map(|t| t.failures).collect(),
            stalls: vec![false; stalls.len()],
        };
        FaultPlan {
            seed,
            kills,
            transients,
            stalls,
            drops,
            publish_gates,
            consumed: Mutex::new(consumed),
        }
    }

    /// Draw a plan from a seed. Fault steps land in `[1, steps_per_node)`
    /// so every node trains at least one step before anything fires, and
    /// kills are drawn over distinct nodes when possible (killing the
    /// same node twice at different steps is legal but makes a thinner
    /// test).
    pub fn generate(seed: u64, shape: &PlanShape) -> Self {
        assert!(shape.nodes > 0, "plan needs at least one node");
        let mut rng = Rng::new(seed ^ 0xC4A0_5CAF_F01D_ED01);
        let step_hi = shape.steps_per_node.max(2);
        let mut draw_step = |rng: &mut Rng| rng.range_u64(1, step_hi);
        let kill_nodes = {
            let k = shape.kills.min(shape.nodes);
            let mut picked = rng.sample_indices(shape.nodes, k);
            // if more kills than nodes were requested, wrap around
            while picked.len() < shape.kills {
                picked.push(rng.usize_below(shape.nodes));
            }
            picked
        };
        let kills = kill_nodes
            .into_iter()
            .map(|node| KillSpec {
                node,
                at_step: draw_step(&mut rng),
            })
            .collect();
        let transients = (0..shape.transients)
            .map(|_| TransientSpec {
                node: rng.usize_below(shape.nodes),
                at_step: draw_step(&mut rng),
                failures: 1 + rng.below(2) as u32,
            })
            .collect();
        let stalls = (0..shape.stalls)
            .map(|_| StallSpec {
                node: rng.usize_below(shape.nodes),
                at_step: draw_step(&mut rng),
                micros: rng.range_u64(100, 2_000),
            })
            .collect();
        let vers_hi = shape.snapshot_versions.max(1);
        let drops = (0..shape.drops)
            .map(|_| DropSpec {
                node: rng.usize_below(shape.nodes),
                version: rng.range_u64(1, vers_hi + 1),
            })
            .collect();
        let publish_gates = (0..shape.publish_gates)
            .map(|_| PublishGate {
                version: rng.range_u64(1, vers_hi + 1),
                min_total_steps: rng.range_u64(1, step_hi * shape.nodes as u64),
            })
            .collect();
        FaultPlan::from_specs(seed, kills, transients, stalls, drops, publish_gates)
    }

    /// Forget all consumed state, making every one-shot fault live again
    /// (replay the identical schedule against a fresh run).
    pub fn reset(&self) {
        let mut c = self.lock();
        c.kills.iter_mut().for_each(|k| *k = false);
        c.stalls.iter_mut().for_each(|s| *s = false);
        for (left, spec) in c.transient_left.iter_mut().zip(&self.transients) {
            *left = spec.failures;
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Consumed> {
        self.consumed.lock().expect("fault plan poisoned")
    }

    /// One-shot kill query: `true` exactly once per matching [`KillSpec`]
    /// (a replacement resuming at the kill step is not re-killed).
    pub fn take_kill(&self, node: usize, step: u64) -> bool {
        let mut c = self.lock();
        for (i, k) in self.kills.iter().enumerate() {
            if !c.kills[i] && k.node == node && k.at_step == step {
                c.kills[i] = true;
                return true;
            }
        }
        false
    }

    /// Transient-failure query: `true` while the matching spec still has
    /// failures left (each query consumes one), then `false` — so a
    /// retrying node succeeds after `failures` attempts.
    pub fn transient_failure(&self, node: usize, step: u64) -> bool {
        let mut c = self.lock();
        for (i, t) in self.transients.iter().enumerate() {
            if c.transient_left[i] > 0 && t.node == node && t.at_step == step {
                c.transient_left[i] -= 1;
                return true;
            }
        }
        false
    }

    /// One-shot stall query: the injected delay in microseconds (0 = no
    /// stall scheduled here).
    pub fn take_stall_micros(&self, node: usize, step: u64) -> u64 {
        let mut c = self.lock();
        for (i, s) in self.stalls.iter().enumerate() {
            if !c.stalls[i] && s.node == node && s.at_step == step {
                c.stalls[i] = true;
                return s.micros;
            }
        }
        0
    }

    /// Pure query: is the delivery of `version` to `node` dropped?
    pub fn drops_delivery(&self, node: usize, version: u64) -> bool {
        self.drops
            .iter()
            .any(|d| d.node == node && d.version == version)
    }

    /// Pure query: the total-step threshold `version`'s publish must wait
    /// for (`None` = publish immediately).
    pub fn publish_gate(&self, version: u64) -> Option<u64> {
        self.publish_gates
            .iter()
            .find(|g| g.version == version)
            .map(|g| g.min_total_steps)
    }

    /// `true` when no fault of any kind is scheduled.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.transients.is_empty()
            && self.stalls.is_empty()
            && self.drops.is_empty()
            && self.publish_gates.is_empty()
    }

    // ---------------- JSON spec ----------------

    pub fn to_json(&self) -> Json {
        let kills = self
            .kills
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("node", Json::num(k.node as f64)),
                    ("at_step", Json::num(k.at_step as f64)),
                ])
            })
            .collect();
        let transients = self
            .transients
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("node", Json::num(t.node as f64)),
                    ("at_step", Json::num(t.at_step as f64)),
                    ("failures", Json::num(t.failures as f64)),
                ])
            })
            .collect();
        let stalls = self
            .stalls
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("node", Json::num(s.node as f64)),
                    ("at_step", Json::num(s.at_step as f64)),
                    ("micros", Json::num(s.micros as f64)),
                ])
            })
            .collect();
        let drops = self
            .drops
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("node", Json::num(d.node as f64)),
                    ("version", Json::num(d.version as f64)),
                ])
            })
            .collect();
        let gates = self
            .publish_gates
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("version", Json::num(g.version as f64)),
                    ("min_total_steps", Json::num(g.min_total_steps as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("kills", Json::Arr(kills)),
            ("transients", Json::Arr(transients)),
            ("stalls", Json::Arr(stalls)),
            ("drops", Json::Arr(drops)),
            ("publish_gates", Json::Arr(gates)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        fn field(j: &Json, key: &str) -> Result<u64> {
            j.get(key)
                .and_then(|v| v.as_i64())
                .filter(|&v| v >= 0)
                .map(|v| v as u64)
                .with_context(|| format!("chaos spec: missing/invalid field '{key}'"))
        }
        fn entries<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
            match j.get(key) {
                None => Ok(&[]),
                Some(v) => v
                    .as_arr()
                    .with_context(|| format!("chaos spec: '{key}' must be an array")),
            }
        }
        if j.as_obj().is_none() {
            bail!("chaos spec: top level must be an object");
        }
        let seed = j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let mut kills = Vec::new();
        for e in entries(j, "kills")? {
            kills.push(KillSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
            });
        }
        let mut transients = Vec::new();
        for e in entries(j, "transients")? {
            transients.push(TransientSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
                failures: field(e, "failures")? as u32,
            });
        }
        let mut stalls = Vec::new();
        for e in entries(j, "stalls")? {
            stalls.push(StallSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
                micros: field(e, "micros")?,
            });
        }
        let mut drops = Vec::new();
        for e in entries(j, "drops")? {
            drops.push(DropSpec {
                node: field(e, "node")? as usize,
                version: field(e, "version")?,
            });
        }
        let mut publish_gates = Vec::new();
        for e in entries(j, "publish_gates")? {
            publish_gates.push(PublishGate {
                version: field(e, "version")?,
                min_total_steps: field(e, "min_total_steps")?,
            });
        }
        Ok(FaultPlan::from_specs(
            seed,
            kills,
            transients,
            stalls,
            drops,
            publish_gates,
        ))
    }

    /// Parse a plan from JSON text (`--chaos-spec` file contents).
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("chaos spec: {e}"))?;
        FaultPlan::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> PlanShape {
        PlanShape {
            nodes: 4,
            steps_per_node: 12,
            kills: 2,
            transients: 2,
            stalls: 1,
            drops: 2,
            publish_gates: 1,
            snapshot_versions: 3,
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = FaultPlan::generate(7, &shape());
        let b = FaultPlan::generate(7, &shape());
        let c = FaultPlan::generate(8, &shape());
        assert_eq!(a.kills, b.kills);
        assert_eq!(a.transients, b.transients);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.drops, b.drops);
        assert_eq!(a.publish_gates, b.publish_gates);
        assert_ne!(
            (a.kills.clone(), a.drops.clone()),
            (c.kills.clone(), c.drops.clone()),
            "different seeds should draw different plans"
        );
    }

    #[test]
    fn generated_faults_respect_shape_bounds() {
        for seed in 0..20 {
            let p = FaultPlan::generate(seed, &shape());
            assert_eq!(p.kills.len(), 2);
            let kill_nodes: Vec<usize> = p.kills.iter().map(|k| k.node).collect();
            let mut dedup = kill_nodes.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), kill_nodes.len(), "kills over distinct nodes");
            for k in &p.kills {
                assert!(k.node < 4 && (1..12).contains(&k.at_step));
            }
            for t in &p.transients {
                assert!(t.node < 4 && (1..12).contains(&t.at_step));
                assert!((1..=2).contains(&t.failures));
            }
            for s in &p.stalls {
                assert!((100..2_000).contains(&s.micros));
            }
            for d in &p.drops {
                assert!(d.node < 4 && (1..=3).contains(&d.version));
            }
            for g in &p.publish_gates {
                assert!((1..=3).contains(&g.version));
                assert!(g.min_total_steps >= 1);
            }
        }
    }

    #[test]
    fn kill_fires_exactly_once() {
        let p = FaultPlan::from_specs(
            0,
            vec![KillSpec { node: 1, at_step: 5 }],
            vec![],
            vec![],
            vec![],
            vec![],
        );
        assert!(!p.take_kill(1, 4));
        assert!(!p.take_kill(0, 5));
        assert!(p.take_kill(1, 5));
        // the adopted replacement resumes at the same step: no re-kill
        assert!(!p.take_kill(1, 5));
        p.reset();
        assert!(p.take_kill(1, 5));
    }

    #[test]
    fn transient_exhausts_after_n_failures() {
        let p = FaultPlan::from_specs(
            0,
            vec![],
            vec![TransientSpec {
                node: 0,
                at_step: 3,
                failures: 2,
            }],
            vec![],
            vec![],
            vec![],
        );
        assert!(p.transient_failure(0, 3));
        assert!(p.transient_failure(0, 3));
        assert!(!p.transient_failure(0, 3), "third attempt must succeed");
        assert!(!p.transient_failure(0, 4));
    }

    #[test]
    fn stall_drop_and_gate_queries() {
        let p = FaultPlan::from_specs(
            0,
            vec![],
            vec![],
            vec![StallSpec {
                node: 2,
                at_step: 1,
                micros: 750,
            }],
            vec![DropSpec { node: 0, version: 2 }],
            vec![PublishGate {
                version: 2,
                min_total_steps: 9,
            }],
        );
        assert_eq!(p.take_stall_micros(2, 1), 750);
        assert_eq!(p.take_stall_micros(2, 1), 0, "stalls are one-shot");
        assert!(p.drops_delivery(0, 2));
        assert!(!p.drops_delivery(1, 2));
        assert!(p.drops_delivery(0, 2), "drop queries are pure");
        assert_eq!(p.publish_gate(2), Some(9));
        assert_eq!(p.publish_gate(1), None);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let p = FaultPlan::generate(41, &shape());
        let text = p.to_json().to_string_pretty();
        let q = FaultPlan::from_json_str(&text).unwrap();
        assert_eq!(p.seed, q.seed);
        assert_eq!(p.kills, q.kills);
        assert_eq!(p.transients, q.transients);
        assert_eq!(p.stalls, q.stalls);
        assert_eq!(p.drops, q.drops);
        assert_eq!(p.publish_gates, q.publish_gates);
    }

    #[test]
    fn json_missing_sections_default_empty() {
        let p = FaultPlan::from_json_str(r#"{"kills": [{"node": 0, "at_step": 2}]}"#).unwrap();
        assert_eq!(p.kills.len(), 1);
        assert!(p.transients.is_empty() && p.drops.is_empty());
        assert!(!p.is_empty());
        assert!(FaultPlan::from_json_str("{}").unwrap().is_empty());
        assert!(FaultPlan::from_json_str("[1,2]").is_err());
        assert!(FaultPlan::from_json_str(r#"{"kills": [{"node": 0}]}"#).is_err());
        assert!(FaultPlan::from_json_str("not json").is_err());
    }

    #[test]
    fn transient_marker_downcasts_through_context() {
        let err = anyhow::Error::new(TransientFault { node: 1, step: 4 })
            .context("train_step failed");
        assert!(is_transient(&err));
        assert!(!is_transient(&anyhow::anyhow!("disk on fire")));
    }
}
