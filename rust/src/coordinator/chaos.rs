//! Deterministic fault injection for the elastic trainer.
//!
//! A [`FaultPlan`] is a *schedule* of faults — node kills, transient
//! backend errors, slow-node stalls, dropped snapshot deliveries, and
//! delayed publishes — keyed on per-node **local step counts** and
//! snapshot **versions**, never on wall-clock time. That makes every
//! chaos run replayable: the same plan against the same seeds produces
//! the same kills at the same stream positions regardless of thread
//! count or machine speed.
//!
//! Plans come from two places and meet in one format:
//!
//! * [`FaultPlan::generate`] draws a plan from an [`Rng`] seed and a
//!   [`PlanShape`] (how many of each fault, over how many steps) — the
//!   chaos tests iterate fixed seeds this way.
//! * [`FaultPlan::from_json`] / [`FaultPlan::to_json`] round-trip the
//!   schedule through the repo's JSON so a failing seed can be exported,
//!   edited, and replayed exactly via `--chaos-spec plan.json`.
//!
//! The plan itself is immutable after construction; *consumed* state
//! (which kills already fired, how many transient failures remain) lives
//! behind a mutex so one `Arc<FaultPlan>` can be shared across all node
//! workers. Consumption is what makes kills one-shot: a replacement node
//! adopting a checkpoint resumes at the very step its predecessor was
//! killed at, and must not be killed again by the same spec.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Kill node `node` at the top of its local step `at_step` (before the
/// step trains — a kill at a checkpoint boundary therefore loses zero
/// steps and the adopted replacement resumes bit-identically).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub node: usize,
    pub at_step: u64,
}

/// Fail node `node`'s step `at_step` with a transient error `failures`
/// times before letting it through — exercises the retry/backoff path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransientSpec {
    pub node: usize,
    pub at_step: u64,
    pub failures: u32,
}

/// Stall node `node` for `micros` before its local step `at_step` (a
/// slow node; correctness must not depend on relative node speed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallSpec {
    pub node: usize,
    pub at_step: u64,
    pub micros: u64,
}

/// Drop the delivery of snapshot `version` to node `node`: the node
/// keeps routing against the last snapshot it actually received. Drops
/// affect *adoption timing only* — the ledger records the broadcast
/// against every live subscriber because the publisher did send it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropSpec {
    pub node: usize,
    pub version: u64,
}

/// Delay the publish of snapshot `version` until the run's total trained
/// steps reach `min_total_steps` (a slow router leader).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PublishGate {
    pub version: u64,
    pub min_total_steps: u64,
}

/// Cut shard `shard` off the cross-shard exchange for `rounds` EM rounds
/// starting at `from_round` (inclusive): the partitioned shard neither
/// sends nor receives cross-shard publishes while cut off, keeps routing
/// against its stale held copies, and catches up through the delayed-
/// Nesterov merge path when the partition heals. Keyed on EM rounds, not
/// wall-clock, so replays are exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPartitionSpec {
    pub shard: usize,
    pub from_round: u64,
    pub rounds: u64,
}

/// Kill shard `shard`'s router leader at EM round `at_round`: the next
/// surviving member is promoted and adopts the leader's checkpoint (a
/// [`crate::coordinator::comm::CommKind::ShardAdopt`] transfer). The
/// round's publish still happens — re-derived deterministically by the
/// promoted member — so leader loss perturbs accounting, never math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaderLossSpec {
    pub shard: usize,
    pub at_round: u64,
}

/// Kill *every* seat of shard `shard` at local step `at_step`: the whole
/// shard is re-adopted from its members' checkpoints (steps past the
/// last checkpoint are re-done and counted in `steps_lost`), with the
/// recovery transfers audited as `ShardAdopt` instead of in-shard
/// `CheckpointAdopt` events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardKillSpec {
    pub shard: usize,
    pub at_step: u64,
}

/// How many of each fault [`FaultPlan::generate`] should draw, and the
/// step/version ranges to draw them over.
#[derive(Clone, Copy, Debug)]
pub struct PlanShape {
    pub nodes: usize,
    /// Local steps each node will run; fault steps are drawn in `[1, steps_per_node)`.
    pub steps_per_node: u64,
    pub kills: usize,
    pub transients: usize,
    pub stalls: usize,
    pub drops: usize,
    pub publish_gates: usize,
    /// Snapshot versions the run will publish; drops/gates draw in `[1, versions]`.
    pub snapshot_versions: u64,
    /// Shards in the fleet; shard faults draw their shard in `[0, shards)`.
    pub shards: usize,
    pub partitions: usize,
    pub leader_losses: usize,
    pub shard_kills: usize,
    /// EM rounds the run will train; shard faults draw rounds in `[1, em_rounds]`.
    pub em_rounds: u64,
}

impl Default for PlanShape {
    fn default() -> Self {
        PlanShape {
            nodes: 1,
            steps_per_node: 2,
            kills: 0,
            transients: 0,
            stalls: 0,
            drops: 0,
            publish_gates: 0,
            snapshot_versions: 1,
            shards: 1,
            partitions: 0,
            leader_losses: 0,
            shard_kills: 0,
            em_rounds: 1,
        }
    }
}

/// Marker error for an injected (or backend-signalled) transient fault.
/// The elastic trainer retries steps whose error chain downcasts to this
/// type; anything else is terminal for the node (structured
/// `NodeFailed`, never a panic).
#[derive(Debug, Clone, Copy)]
pub struct TransientFault {
    pub node: usize,
    pub step: u64,
}

impl std::fmt::Display for TransientFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transient backend fault (node {}, step {})",
            self.node, self.step
        )
    }
}

impl std::error::Error for TransientFault {}

/// `true` when `err`'s chain contains a [`TransientFault`] — the retry
/// predicate used by the elastic node loop.
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.chain()
        .any(|e| e.downcast_ref::<TransientFault>().is_some())
}

/// Per-plan consumed state (which one-shot faults already fired).
#[derive(Debug, Default)]
struct Consumed {
    kills: Vec<bool>,
    transient_left: Vec<u32>,
    stalls: Vec<bool>,
    leader_losses: Vec<bool>,
}

/// A deterministic, replayable schedule of injected faults. See the
/// module docs for the construction/consumption contract.
#[derive(Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub kills: Vec<KillSpec>,
    pub transients: Vec<TransientSpec>,
    pub stalls: Vec<StallSpec>,
    pub drops: Vec<DropSpec>,
    pub publish_gates: Vec<PublishGate>,
    pub partitions: Vec<ShardPartitionSpec>,
    pub leader_losses: Vec<LeaderLossSpec>,
    pub shard_kills: Vec<ShardKillSpec>,
    consumed: Mutex<Consumed>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The empty plan: no faults ever fire.
    pub fn none() -> Self {
        FaultPlan::from_specs(0, vec![], vec![], vec![], vec![], vec![])
    }

    pub(crate) fn from_specs(
        seed: u64,
        kills: Vec<KillSpec>,
        transients: Vec<TransientSpec>,
        stalls: Vec<StallSpec>,
        drops: Vec<DropSpec>,
        publish_gates: Vec<PublishGate>,
    ) -> Self {
        let consumed = Consumed {
            kills: vec![false; kills.len()],
            transient_left: transients.iter().map(|t| t.failures).collect(),
            stalls: vec![false; stalls.len()],
            leader_losses: vec![],
        };
        FaultPlan {
            seed,
            kills,
            transients,
            stalls,
            drops,
            publish_gates,
            partitions: vec![],
            leader_losses: vec![],
            shard_kills: vec![],
            consumed: Mutex::new(consumed),
        }
    }

    /// Attach the shard-level fault schedule (builder-style so the
    /// node-level constructor keeps its shape).
    fn with_shard_faults(
        mut self,
        partitions: Vec<ShardPartitionSpec>,
        leader_losses: Vec<LeaderLossSpec>,
        shard_kills: Vec<ShardKillSpec>,
    ) -> Self {
        self.consumed
            .get_mut()
            .expect("fault plan poisoned")
            .leader_losses = vec![false; leader_losses.len()];
        self.partitions = partitions;
        self.leader_losses = leader_losses;
        self.shard_kills = shard_kills;
        self
    }

    /// Draw a plan from a seed. Fault steps land in `[1, steps_per_node)`
    /// so every node trains at least one step before anything fires, and
    /// kills are drawn over distinct nodes when possible (killing the
    /// same node twice at different steps is legal but makes a thinner
    /// test).
    pub fn generate(seed: u64, shape: &PlanShape) -> Self {
        assert!(shape.nodes > 0, "plan needs at least one node");
        let mut rng = Rng::new(seed ^ 0xC4A0_5CAF_F01D_ED01);
        let step_hi = shape.steps_per_node.max(2);
        let mut draw_step = |rng: &mut Rng| rng.range_u64(1, step_hi);
        let kill_nodes = {
            let k = shape.kills.min(shape.nodes);
            let mut picked = rng.sample_indices(shape.nodes, k);
            // if more kills than nodes were requested, wrap around
            while picked.len() < shape.kills {
                picked.push(rng.usize_below(shape.nodes));
            }
            picked
        };
        let kills = kill_nodes
            .into_iter()
            .map(|node| KillSpec {
                node,
                at_step: draw_step(&mut rng),
            })
            .collect();
        let transients = (0..shape.transients)
            .map(|_| TransientSpec {
                node: rng.usize_below(shape.nodes),
                at_step: draw_step(&mut rng),
                failures: 1 + rng.below(2) as u32,
            })
            .collect();
        let stalls = (0..shape.stalls)
            .map(|_| StallSpec {
                node: rng.usize_below(shape.nodes),
                at_step: draw_step(&mut rng),
                micros: rng.range_u64(100, 2_000),
            })
            .collect();
        let vers_hi = shape.snapshot_versions.max(1);
        let drops = (0..shape.drops)
            .map(|_| DropSpec {
                node: rng.usize_below(shape.nodes),
                version: rng.range_u64(1, vers_hi + 1),
            })
            .collect();
        let publish_gates = (0..shape.publish_gates)
            .map(|_| PublishGate {
                version: rng.range_u64(1, vers_hi + 1),
                min_total_steps: rng.range_u64(1, step_hi * shape.nodes as u64),
            })
            .collect();
        // shard faults draw after the node faults, so plans with zero
        // shard clauses reproduce pre-shard plans bit-identically
        let shards = shape.shards.max(1);
        let round_hi = shape.em_rounds.max(1);
        let partitions = (0..shape.partitions)
            .map(|_| ShardPartitionSpec {
                shard: rng.usize_below(shards),
                from_round: rng.range_u64(1, round_hi + 1),
                rounds: rng.range_u64(1, 3),
            })
            .collect();
        let leader_losses = (0..shape.leader_losses)
            .map(|_| LeaderLossSpec {
                shard: rng.usize_below(shards),
                at_round: rng.range_u64(1, round_hi + 1),
            })
            .collect();
        let shard_kills = (0..shape.shard_kills)
            .map(|_| ShardKillSpec {
                shard: rng.usize_below(shards),
                at_step: draw_step(&mut rng),
            })
            .collect();
        FaultPlan::from_specs(seed, kills, transients, stalls, drops, publish_gates)
            .with_shard_faults(partitions, leader_losses, shard_kills)
    }

    /// Forget all consumed state, making every one-shot fault live again
    /// (replay the identical schedule against a fresh run).
    pub fn reset(&self) {
        let mut c = self.lock();
        c.kills.iter_mut().for_each(|k| *k = false);
        c.stalls.iter_mut().for_each(|s| *s = false);
        c.leader_losses.iter_mut().for_each(|l| *l = false);
        for (left, spec) in c.transient_left.iter_mut().zip(&self.transients) {
            *left = spec.failures;
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Consumed> {
        self.consumed.lock().expect("fault plan poisoned")
    }

    /// One-shot kill query: `true` exactly once per matching [`KillSpec`]
    /// (a replacement resuming at the kill step is not re-killed).
    pub fn take_kill(&self, node: usize, step: u64) -> bool {
        self.take_kill_indexed(node, step).is_some()
    }

    /// Like [`FaultPlan::take_kill`], but returns *which* spec fired —
    /// the fleet layer tags some kill indices as shard kills so the
    /// recovery path can audit them as `ShardAdopt` instead of in-shard
    /// `CheckpointAdopt` transfers.
    pub fn take_kill_indexed(&self, node: usize, step: u64) -> Option<usize> {
        let mut c = self.lock();
        for (i, k) in self.kills.iter().enumerate() {
            if !c.kills[i] && k.node == node && k.at_step == step {
                c.kills[i] = true;
                return Some(i);
            }
        }
        None
    }

    /// Transient-failure query: `true` while the matching spec still has
    /// failures left (each query consumes one), then `false` — so a
    /// retrying node succeeds after `failures` attempts.
    pub fn transient_failure(&self, node: usize, step: u64) -> bool {
        let mut c = self.lock();
        for (i, t) in self.transients.iter().enumerate() {
            if c.transient_left[i] > 0 && t.node == node && t.at_step == step {
                c.transient_left[i] -= 1;
                return true;
            }
        }
        false
    }

    /// One-shot stall query: the injected delay in microseconds (0 = no
    /// stall scheduled here).
    pub fn take_stall_micros(&self, node: usize, step: u64) -> u64 {
        let mut c = self.lock();
        for (i, s) in self.stalls.iter().enumerate() {
            if !c.stalls[i] && s.node == node && s.at_step == step {
                c.stalls[i] = true;
                return s.micros;
            }
        }
        0
    }

    /// Pure query: is the delivery of `version` to `node` dropped?
    pub fn drops_delivery(&self, node: usize, version: u64) -> bool {
        self.drops
            .iter()
            .any(|d| d.node == node && d.version == version)
    }

    /// Pure query: the total-step threshold `version`'s publish must wait
    /// for (`None` = publish immediately).
    pub fn publish_gate(&self, version: u64) -> Option<u64> {
        self.publish_gates
            .iter()
            .find(|g| g.version == version)
            .map(|g| g.min_total_steps)
    }

    /// Pure query: is shard `shard` cut off the cross-shard exchange at
    /// EM round `round`? Partitioned shards neither send nor receive —
    /// the cut is symmetric, like a real network partition.
    pub fn partition_blocks(&self, shard: usize, round: u64) -> bool {
        self.partitions.iter().any(|p| {
            p.shard == shard && round >= p.from_round && round < p.from_round.saturating_add(p.rounds)
        })
    }

    /// One-shot leader-loss query: `true` exactly once per matching
    /// [`LeaderLossSpec`] (promotion must not repeat on replay within
    /// one run; [`FaultPlan::reset`] re-arms it).
    pub fn take_leader_loss(&self, shard: usize, round: u64) -> bool {
        let mut c = self.lock();
        for (i, l) in self.leader_losses.iter().enumerate() {
            if !c.leader_losses[i] && l.shard == shard && l.at_round == round {
                c.leader_losses[i] = true;
                return true;
            }
        }
        false
    }

    /// Pure query: the local step at which every seat of `shard` dies
    /// (`None` = the shard is never killed). The fleet layer expands
    /// this into per-member kill specs tagged for `ShardAdopt` audit.
    pub fn shard_kill_step(&self, shard: usize) -> Option<u64> {
        self.shard_kills
            .iter()
            .find(|k| k.shard == shard)
            .map(|k| k.at_step)
    }

    /// `true` when no fault of any kind is scheduled.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.transients.is_empty()
            && self.stalls.is_empty()
            && self.drops.is_empty()
            && self.publish_gates.is_empty()
            && self.partitions.is_empty()
            && self.leader_losses.is_empty()
            && self.shard_kills.is_empty()
    }

    // ---------------- JSON spec ----------------

    pub fn to_json(&self) -> Json {
        let kills = self
            .kills
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("node", Json::num(k.node as f64)),
                    ("at_step", Json::num(k.at_step as f64)),
                ])
            })
            .collect();
        let transients = self
            .transients
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("node", Json::num(t.node as f64)),
                    ("at_step", Json::num(t.at_step as f64)),
                    ("failures", Json::num(t.failures as f64)),
                ])
            })
            .collect();
        let stalls = self
            .stalls
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("node", Json::num(s.node as f64)),
                    ("at_step", Json::num(s.at_step as f64)),
                    ("micros", Json::num(s.micros as f64)),
                ])
            })
            .collect();
        let drops = self
            .drops
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("node", Json::num(d.node as f64)),
                    ("version", Json::num(d.version as f64)),
                ])
            })
            .collect();
        let gates = self
            .publish_gates
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("version", Json::num(g.version as f64)),
                    ("min_total_steps", Json::num(g.min_total_steps as f64)),
                ])
            })
            .collect();
        let partitions = self
            .partitions
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("shard", Json::num(p.shard as f64)),
                    ("from_round", Json::num(p.from_round as f64)),
                    ("rounds", Json::num(p.rounds as f64)),
                ])
            })
            .collect();
        let leader_losses = self
            .leader_losses
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("shard", Json::num(l.shard as f64)),
                    ("at_round", Json::num(l.at_round as f64)),
                ])
            })
            .collect();
        let shard_kills = self
            .shard_kills
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("shard", Json::num(k.shard as f64)),
                    ("at_step", Json::num(k.at_step as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("kills", Json::Arr(kills)),
            ("transients", Json::Arr(transients)),
            ("stalls", Json::Arr(stalls)),
            ("drops", Json::Arr(drops)),
            ("publish_gates", Json::Arr(gates)),
            ("partitions", Json::Arr(partitions)),
            ("leader_losses", Json::Arr(leader_losses)),
            ("shard_kills", Json::Arr(shard_kills)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        fn field(j: &Json, key: &str) -> Result<u64> {
            j.get(key)
                .and_then(|v| v.as_i64())
                .filter(|&v| v >= 0)
                .map(|v| v as u64)
                .with_context(|| format!("chaos spec: missing/invalid field '{key}'"))
        }
        fn entries<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
            match j.get(key) {
                None => Ok(&[]),
                Some(v) => v
                    .as_arr()
                    .with_context(|| format!("chaos spec: '{key}' must be an array")),
            }
        }
        if j.as_obj().is_none() {
            bail!("chaos spec: top level must be an object");
        }
        let seed = j.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let mut kills = Vec::new();
        for e in entries(j, "kills")? {
            kills.push(KillSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
            });
        }
        let mut transients = Vec::new();
        for e in entries(j, "transients")? {
            transients.push(TransientSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
                failures: field(e, "failures")? as u32,
            });
        }
        let mut stalls = Vec::new();
        for e in entries(j, "stalls")? {
            stalls.push(StallSpec {
                node: field(e, "node")? as usize,
                at_step: field(e, "at_step")?,
                micros: field(e, "micros")?,
            });
        }
        let mut drops = Vec::new();
        for e in entries(j, "drops")? {
            drops.push(DropSpec {
                node: field(e, "node")? as usize,
                version: field(e, "version")?,
            });
        }
        let mut publish_gates = Vec::new();
        for e in entries(j, "publish_gates")? {
            publish_gates.push(PublishGate {
                version: field(e, "version")?,
                min_total_steps: field(e, "min_total_steps")?,
            });
        }
        let mut partitions = Vec::new();
        for e in entries(j, "partitions")? {
            partitions.push(ShardPartitionSpec {
                shard: field(e, "shard")? as usize,
                from_round: field(e, "from_round")?,
                rounds: field(e, "rounds")?,
            });
        }
        let mut leader_losses = Vec::new();
        for e in entries(j, "leader_losses")? {
            leader_losses.push(LeaderLossSpec {
                shard: field(e, "shard")? as usize,
                at_round: field(e, "at_round")?,
            });
        }
        let mut shard_kills = Vec::new();
        for e in entries(j, "shard_kills")? {
            shard_kills.push(ShardKillSpec {
                shard: field(e, "shard")? as usize,
                at_step: field(e, "at_step")?,
            });
        }
        Ok(FaultPlan::from_specs(
            seed,
            kills,
            transients,
            stalls,
            drops,
            publish_gates,
        )
        .with_shard_faults(partitions, leader_losses, shard_kills))
    }

    /// Parse a plan from JSON text (`--chaos-spec` file contents).
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("chaos spec: {e}"))?;
        FaultPlan::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> PlanShape {
        PlanShape {
            nodes: 4,
            steps_per_node: 12,
            kills: 2,
            transients: 2,
            stalls: 1,
            drops: 2,
            publish_gates: 1,
            snapshot_versions: 3,
            ..PlanShape::default()
        }
    }

    fn sharded_shape() -> PlanShape {
        PlanShape {
            shards: 3,
            partitions: 2,
            leader_losses: 1,
            shard_kills: 1,
            em_rounds: 4,
            ..shape()
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = FaultPlan::generate(7, &shape());
        let b = FaultPlan::generate(7, &shape());
        let c = FaultPlan::generate(8, &shape());
        assert_eq!(a.kills, b.kills);
        assert_eq!(a.transients, b.transients);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.drops, b.drops);
        assert_eq!(a.publish_gates, b.publish_gates);
        assert_ne!(
            (a.kills.clone(), a.drops.clone()),
            (c.kills.clone(), c.drops.clone()),
            "different seeds should draw different plans"
        );
    }

    #[test]
    fn generated_faults_respect_shape_bounds() {
        for seed in 0..20 {
            let p = FaultPlan::generate(seed, &shape());
            assert_eq!(p.kills.len(), 2);
            let kill_nodes: Vec<usize> = p.kills.iter().map(|k| k.node).collect();
            let mut dedup = kill_nodes.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), kill_nodes.len(), "kills over distinct nodes");
            for k in &p.kills {
                assert!(k.node < 4 && (1..12).contains(&k.at_step));
            }
            for t in &p.transients {
                assert!(t.node < 4 && (1..12).contains(&t.at_step));
                assert!((1..=2).contains(&t.failures));
            }
            for s in &p.stalls {
                assert!((100..2_000).contains(&s.micros));
            }
            for d in &p.drops {
                assert!(d.node < 4 && (1..=3).contains(&d.version));
            }
            for g in &p.publish_gates {
                assert!((1..=3).contains(&g.version));
                assert!(g.min_total_steps >= 1);
            }
        }
    }

    #[test]
    fn kill_fires_exactly_once() {
        let p = FaultPlan::from_specs(
            0,
            vec![KillSpec { node: 1, at_step: 5 }],
            vec![],
            vec![],
            vec![],
            vec![],
        );
        assert!(!p.take_kill(1, 4));
        assert!(!p.take_kill(0, 5));
        assert!(p.take_kill(1, 5));
        // the adopted replacement resumes at the same step: no re-kill
        assert!(!p.take_kill(1, 5));
        p.reset();
        assert!(p.take_kill(1, 5));
    }

    #[test]
    fn transient_exhausts_after_n_failures() {
        let p = FaultPlan::from_specs(
            0,
            vec![],
            vec![TransientSpec {
                node: 0,
                at_step: 3,
                failures: 2,
            }],
            vec![],
            vec![],
            vec![],
        );
        assert!(p.transient_failure(0, 3));
        assert!(p.transient_failure(0, 3));
        assert!(!p.transient_failure(0, 3), "third attempt must succeed");
        assert!(!p.transient_failure(0, 4));
    }

    #[test]
    fn stall_drop_and_gate_queries() {
        let p = FaultPlan::from_specs(
            0,
            vec![],
            vec![],
            vec![StallSpec {
                node: 2,
                at_step: 1,
                micros: 750,
            }],
            vec![DropSpec { node: 0, version: 2 }],
            vec![PublishGate {
                version: 2,
                min_total_steps: 9,
            }],
        );
        assert_eq!(p.take_stall_micros(2, 1), 750);
        assert_eq!(p.take_stall_micros(2, 1), 0, "stalls are one-shot");
        assert!(p.drops_delivery(0, 2));
        assert!(!p.drops_delivery(1, 2));
        assert!(p.drops_delivery(0, 2), "drop queries are pure");
        assert_eq!(p.publish_gate(2), Some(9));
        assert_eq!(p.publish_gate(1), None);
    }

    #[test]
    fn shard_faults_generate_within_bounds() {
        for seed in 0..20 {
            let p = FaultPlan::generate(seed, &sharded_shape());
            assert_eq!(p.partitions.len(), 2);
            assert_eq!(p.leader_losses.len(), 1);
            assert_eq!(p.shard_kills.len(), 1);
            for part in &p.partitions {
                assert!(part.shard < 3 && (1..=4).contains(&part.from_round));
                assert!((1..=2).contains(&part.rounds));
            }
            for l in &p.leader_losses {
                assert!(l.shard < 3 && (1..=4).contains(&l.at_round));
            }
            for k in &p.shard_kills {
                assert!(k.shard < 3 && (1..12).contains(&k.at_step));
            }
        }
    }

    #[test]
    fn zero_shard_clauses_leave_node_faults_unchanged() {
        // shard faults draw after node faults: a shard-free shape must
        // reproduce the pre-shard plan for the same seed exactly
        let a = FaultPlan::generate(7, &shape());
        let b = FaultPlan::generate(
            7,
            &PlanShape {
                shards: 4,
                em_rounds: 9,
                ..shape()
            },
        );
        assert_eq!(a.kills, b.kills);
        assert_eq!(a.transients, b.transients);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.drops, b.drops);
        assert_eq!(a.publish_gates, b.publish_gates);
        assert!(b.partitions.is_empty() && b.shard_kills.is_empty());
    }

    #[test]
    fn partition_blocks_is_pure_and_round_windowed() {
        let p = FaultPlan::none().with_shard_faults(
            vec![ShardPartitionSpec {
                shard: 1,
                from_round: 2,
                rounds: 2,
            }],
            vec![],
            vec![],
        );
        assert!(!p.partition_blocks(1, 1));
        assert!(p.partition_blocks(1, 2));
        assert!(p.partition_blocks(1, 3));
        assert!(!p.partition_blocks(1, 4));
        assert!(!p.partition_blocks(0, 2));
        assert!(p.partition_blocks(1, 2), "partition queries are pure");
        assert!(!p.is_empty());
    }

    #[test]
    fn leader_loss_fires_once_and_rearms_on_reset() {
        let p = FaultPlan::none().with_shard_faults(
            vec![],
            vec![LeaderLossSpec { shard: 0, at_round: 3 }],
            vec![],
        );
        assert!(!p.take_leader_loss(0, 2));
        assert!(!p.take_leader_loss(1, 3));
        assert!(p.take_leader_loss(0, 3));
        assert!(!p.take_leader_loss(0, 3), "leader loss is one-shot");
        p.reset();
        assert!(p.take_leader_loss(0, 3));
    }

    #[test]
    fn shard_kill_step_and_indexed_kill() {
        let p = FaultPlan::from_specs(
            0,
            vec![
                KillSpec { node: 0, at_step: 4 },
                KillSpec { node: 1, at_step: 4 },
            ],
            vec![],
            vec![],
            vec![],
            vec![],
        )
        .with_shard_faults(vec![], vec![], vec![ShardKillSpec { shard: 1, at_step: 4 }]);
        assert_eq!(p.shard_kill_step(1), Some(4));
        assert_eq!(p.shard_kill_step(0), None);
        assert_eq!(p.take_kill_indexed(1, 4), Some(1));
        assert_eq!(p.take_kill_indexed(1, 4), None, "indexed kills are one-shot");
        assert!(p.take_kill(0, 4), "take_kill delegates to the indexed path");
        assert_eq!(p.take_kill_indexed(0, 4), None);
        p.reset();
        assert_eq!(p.take_kill_indexed(0, 4), Some(0));
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let p = FaultPlan::generate(41, &sharded_shape());
        let text = p.to_json().to_string_pretty();
        let q = FaultPlan::from_json_str(&text).unwrap();
        assert_eq!(p.seed, q.seed);
        assert_eq!(p.kills, q.kills);
        assert_eq!(p.transients, q.transients);
        assert_eq!(p.stalls, q.stalls);
        assert_eq!(p.drops, q.drops);
        assert_eq!(p.publish_gates, q.publish_gates);
        assert_eq!(p.partitions, q.partitions);
        assert_eq!(p.leader_losses, q.leader_losses);
        assert_eq!(p.shard_kills, q.shard_kills);
    }

    #[test]
    fn json_missing_sections_default_empty() {
        let p = FaultPlan::from_json_str(r#"{"kills": [{"node": 0, "at_step": 2}]}"#).unwrap();
        assert_eq!(p.kills.len(), 1);
        assert!(p.transients.is_empty() && p.drops.is_empty());
        assert!(!p.is_empty());
        assert!(FaultPlan::from_json_str("{}").unwrap().is_empty());
        assert!(FaultPlan::from_json_str("[1,2]").is_err());
        assert!(FaultPlan::from_json_str(r#"{"kills": [{"node": 0}]}"#).is_err());
        assert!(FaultPlan::from_json_str("not json").is_err());
    }

    #[test]
    fn transient_marker_downcasts_through_context() {
        let err = anyhow::Error::new(TransientFault { node: 1, step: 4 })
            .context("train_step failed");
        assert!(is_transient(&err));
        assert!(!is_transient(&anyhow::anyhow!("disk on fire")));
    }
}
