//! Router EM training (Algorithm 1, lines 1–10).
//!
//! Alternates:
//!   * **M-step** — each router does SGD on its currently-assigned data
//!     segment (Eq. 9), independently ("no need to talk");
//!   * **E-step** — a fresh chunk of N sequences is scored by every
//!     router (an all-gather of scores on a real cluster — recorded in the
//!     [`CommLedger`]) and re-partitioned with balanced assignment.
//!
//! Round 0 uses random assignments. Every router is a "node"; the only
//! inter-node traffic is the score exchange.
//!
//! The M-step is embarrassingly parallel — each router trains on its own
//! segment and never reads another's state — so the routers fan across
//! `cfg.threads` workers (the E-step's score matrix parallelizes per
//! router internally). Results are identical at any worker count: each
//! router's trajectory depends only on its own init and segment.

use anyhow::Result;

use super::assignment::{balanced_assign, Assignment};
use super::comm::CommLedger;
use super::scoring::{routing_purity, score_matrix_threaded};
use crate::data::{Sequence, SequenceGen};
use crate::metrics::RunLog;
use crate::runtime::parallel::run_fallible;
use crate::runtime::{Engine, TrainState, VariantMeta};
use crate::util::rng::Rng;

/// Configuration of the router EM loop.
#[derive(Clone, Debug)]
pub struct EmConfig {
    /// Number of routers E (= number of experts).
    pub n_routers: usize,
    /// EM rounds T.
    pub rounds: usize,
    /// Fresh sequences per round N.
    pub chunk_size: usize,
    /// SGD steps per router per round.
    pub steps_per_round: usize,
    /// Routing prefix length M used for scoring during training.
    pub prefix_len: usize,
    /// Base RNG seed (router init + data order).
    pub seed: u64,
    /// Worker threads for the M-step router fan-out (0 = auto, see
    /// [`crate::runtime::parallel::resolve_threads`]).
    pub threads: usize,
}

impl Default for EmConfig {
    fn default() -> Self {
        EmConfig {
            n_routers: 4,
            rounds: 4,
            chunk_size: 256,
            steps_per_round: 24,
            prefix_len: 32,
            seed: 17,
            threads: 0,
        }
    }
}

/// Result of router training: the routers plus diagnostics per round.
pub struct TrainedRouters {
    pub routers: Vec<TrainState>,
    pub meta: VariantMeta,
    pub purity_per_round: Vec<f64>,
    pub mean_score_per_round: Vec<f64>,
}

/// Train `cfg.n_routers` routers of `variant` with EM.
///
/// `gen` supplies "fresh sequences from the dataset"; `ledger` records the
/// per-round score all-gather.
pub fn train_routers(
    engine: &Engine,
    variant: &str,
    cfg: &EmConfig,
    gen: &mut SequenceGen,
    ledger: &mut CommLedger,
    log: &mut RunLog,
) -> Result<TrainedRouters> {
    train_routers_hooked(engine, variant, cfg, gen, ledger, log, |_, _| Ok(()))
}

/// [`train_routers`] with a per-round observation hook: `on_round(round,
/// routers)` runs after round `round`'s M-step (0-based), with the
/// routers in their post-round state. The async trainer publishes router
/// snapshots from here; the no-op hook reproduces [`train_routers`]
/// bit-exactly. A hook error aborts training.
pub fn train_routers_hooked(
    engine: &Engine,
    variant: &str,
    cfg: &EmConfig,
    gen: &mut SequenceGen,
    ledger: &mut CommLedger,
    log: &mut RunLog,
    mut on_round: impl FnMut(usize, &[TrainState]) -> Result<()>,
) -> Result<TrainedRouters> {
    let meta = engine.variant(variant)?.clone();
    let mut rng = Rng::new(cfg.seed);

    // independent init per router
    let mut routers: Vec<TrainState> = (0..cfg.n_routers)
        .map(|e| TrainState::init(engine, variant, cfg.seed ^ (0xA5A5 + e as u64)))
        .collect::<Result<_>>()?;

    let mut purity_per_round = Vec::with_capacity(cfg.rounds);
    let mut mean_score_per_round = Vec::with_capacity(cfg.rounds);
    let threads = crate::runtime::parallel::resolve_threads(cfg.threads);

    for round in 0..cfg.rounds {
        // ---- E-step: draw a fresh chunk and partition it ----
        let chunk: Vec<Sequence> = gen.batch(cfg.chunk_size);
        let assignment: Assignment = if round == 0 {
            // random balanced split (Alg. 1 line 3)
            let mut ids: Vec<usize> = (0..chunk.len()).collect();
            rng.shuffle(&mut ids);
            let cap = chunk.len().div_ceil(cfg.n_routers);
            let mut expert_of = vec![0usize; chunk.len()];
            let mut counts = vec![0usize; cfg.n_routers];
            for (i, &s) in ids.iter().enumerate() {
                let e = i / cap;
                expert_of[s] = e;
                counts[e] += 1;
            }
            Assignment { expert_of, counts }
        } else {
            let nll = score_matrix_threaded(engine, &routers, &meta, &chunk, cfg.prefix_len, threads)?;
            // all-gather: each node contributes one score per sequence
            ledger.record_score_allgather(cfg.n_routers, chunk.len() as u64, round as u64);
            let a = balanced_assign(&nll, None);
            mean_score_per_round.push(a.total_nll(&nll) / chunk.len() as f64);
            a
        };
        let purity = routing_purity(&assignment.expert_of, &chunk, cfg.n_routers);
        purity_per_round.push(purity);
        log.scalar("em/purity", round as f64, purity);

        // ---- M-step: each router trains on its segment, independently
        // ("no need to talk") — one task per router on the worker pool ----
        let chunk_ref = &chunk;
        let meta_ref = &meta;
        let steps = cfg.steps_per_round;
        let tasks: Vec<_> = routers
            .iter_mut()
            .enumerate()
            .map(|(e, router)| {
                let segment = assignment.segment(e);
                move || -> Result<Option<f32>> {
                    if segment.is_empty() {
                        return Ok(None);
                    }
                    let mut cursor = 0usize;
                    let mut last_loss = 0.0f32;
                    for _ in 0..steps {
                        // batch by reference into the chunk — no token clones
                        let mut batch: Vec<&[u32]> = Vec::with_capacity(meta_ref.train_batch);
                        for _ in 0..meta_ref.train_batch {
                            let s = segment[cursor % segment.len()];
                            batch.push(chunk_ref[s].tokens.as_slice());
                            cursor += 1;
                        }
                        last_loss = router.train_step(engine, &batch, meta_ref)?;
                    }
                    Ok(Some(last_loss))
                }
            })
            .collect();
        for (e, last_loss) in run_fallible(tasks, threads)?.into_iter().enumerate() {
            if let Some(loss) = last_loss {
                log.scalar(
                    &format!("em/router{e}_loss"),
                    (round * cfg.steps_per_round) as f64,
                    loss as f64,
                );
            }
        }
        on_round(round, &routers)?;
    }

    Ok(TrainedRouters {
        routers,
        meta,
        purity_per_round,
        mean_score_per_round,
    })
}
