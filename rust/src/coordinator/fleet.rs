//! Fleet-sharded elastic training: expert seats partitioned across
//! multiple independent [`SnapshotStore`] fault domains.
//!
//! One *shard* is a group of expert seats plus a router leader that runs
//! the shard's own EM loop and publishes to the shard's own store. Nodes
//! inside a shard communicate exactly as in the single-fleet elastic
//! runtime (snapshot broadcasts, checkpoint adoptions, merges — all
//! intra-shard). Shards talk to each other **only at EM-round
//! boundaries**, through a [`ShardExchange`] that swaps each shard's own
//! router block; every cross-shard byte is audited on the merged
//! [`CommLedger`] as [`CommKind::CrossShardPublish`] or
//! [`CommKind::ShardAdopt`], so inter-shard traffic between boundaries
//! is structurally zero.
//!
//! The shard-level failure model (partition / leader loss / shard kill)
//! is documented with the node-level model in the
//! [`trainer`](super::trainer) module docs; every fault is keyed on EM
//! rounds or node-local steps — never wall-clock — so a fleet run under
//! a seeded [`FaultPlan`] replays bit-identically after
//! [`FaultPlan::reset`].
//!
//! Each shard stays authoritative for its own router block: foreign
//! blocks only feed each shard's *held view* of the global router set
//! (refreshed at boundaries, caught up through the delayed-Nesterov
//! outer update after a partition heals). The final global router set is
//! therefore assembled from the per-shard blocks and is independent of
//! partition schedules.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use super::chaos::{DropSpec, FaultPlan, KillSpec, StallSpec, TransientSpec};
use super::comm::{CommKind, CommLedger};
use super::em::{train_routers_hooked, EmConfig};
use super::inference::Mixture;
use super::pipeline::{PipelineConfig, PipelineResult};
use super::trainer::{
    ckpt_path, engine_transfer_scalars, run_elastic_nodes, ElasticHandle, ElasticPlan,
    ElasticPolicy, ElasticReport, ElasticStats, EngineBackend, LeaveEvent, NodeEnd, NodeRunConfig,
    SeatIdentity, SnapshotStore, TrainBackend, TrainerConfig,
};
use crate::data::SequenceGen;
use crate::metrics::RunLog;
use crate::model::checkpoint::load_node_checkpoint;
use crate::runtime::{Engine, TrainState, VariantMeta};
use crate::tokenizer::Bpe;
use crate::util::json::Json;

// -------------------------------------------------------------------------
// shard plan
// -------------------------------------------------------------------------

/// Which global expert seat belongs to which shard. Membership is fixed
/// for a run; member order is the promotion order on leader loss.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    members: Vec<Vec<usize>>,
    total: usize,
}

impl ShardPlan {
    /// Spread `n_seats` contiguous seats near-evenly over `n_shards`
    /// (earlier shards take the remainder).
    pub fn partition(n_seats: usize, n_shards: usize) -> Result<Self> {
        ensure!(n_shards > 0, "a fleet needs at least one shard");
        ensure!(
            n_seats >= n_shards,
            "cannot spread {n_seats} expert seat(s) across {n_shards} shards"
        );
        let base = n_seats / n_shards;
        let extra = n_seats % n_shards;
        let mut members = Vec::with_capacity(n_shards);
        let mut next = 0;
        for s in 0..n_shards {
            let k = base + usize::from(s < extra);
            members.push((next..next + k).collect());
            next += k;
        }
        Ok(ShardPlan {
            members,
            total: n_seats,
        })
    }

    /// An explicit membership: every seat in `0..total` assigned to
    /// exactly one shard, no shard empty.
    pub fn from_members(members: Vec<Vec<usize>>) -> Result<Self> {
        ensure!(!members.is_empty(), "a fleet needs at least one shard");
        let total: usize = members.iter().map(Vec::len).sum();
        let mut seen = vec![false; total];
        for (s, m) in members.iter().enumerate() {
            ensure!(!m.is_empty(), "shard {s} has no member seats");
            for &g in m {
                ensure!(g < total, "seat {g} out of range for {total} seats");
                ensure!(!seen[g], "seat {g} assigned to two shards");
                seen[g] = true;
            }
        }
        Ok(ShardPlan { members, total })
    }

    pub fn n_shards(&self) -> usize {
        self.members.len()
    }

    pub fn total_seats(&self) -> usize {
        self.total
    }

    /// Global seats of `shard`, in promotion order.
    pub fn members(&self, shard: usize) -> &[usize] {
        &self.members[shard]
    }

    /// The shard a global seat belongs to.
    pub fn shard_of(&self, seat: usize) -> usize {
        self.members
            .iter()
            .position(|m| m.contains(&seat))
            .unwrap_or(0)
    }
}

/// Wire size of one router block: the full f32 parameter set, matching
/// [`SnapshotStore::publish`]'s broadcast accounting — which is what
/// makes the intra/inter byte audit reconcile in closed form.
pub fn router_block_bytes(block: &[TrainState]) -> u64 {
    block.iter().map(|r| r.params.len() as u64 * 4).sum()
}

// -------------------------------------------------------------------------
// cross-shard exchange
// -------------------------------------------------------------------------

struct ExchangeInner {
    /// Barrier generation per shard: `2*round` = arrived at `round`,
    /// `2*round + 1` = departed (done reading). Rounds are 1-based so
    /// any arrival beats the initial 0.
    phase: Vec<u64>,
    /// Dead shards are excluded from every wait (no deadlock on loss).
    live: Vec<bool>,
    /// Latest block each shard submitted, tagged with its round.
    blocks: Vec<Option<(u64, Vec<TrainState>)>>,
}

/// The only inter-shard channel: a two-phase generation barrier where
/// each shard deposits its own router block at an EM-round boundary and
/// reads the blocks of the shards it can see. Every transfer is recorded
/// on the exchange's own ledger (merged into the fleet ledger at the
/// end), so cross-shard bytes are exactly the events recorded here.
pub struct ShardExchange {
    inner: Mutex<ExchangeInner>,
    cv: Condvar,
    ledger: Mutex<CommLedger>,
}

impl ShardExchange {
    pub fn new(n_shards: usize) -> Self {
        ShardExchange {
            inner: Mutex::new(ExchangeInner {
                phase: vec![0; n_shards],
                live: vec![true; n_shards],
                blocks: (0..n_shards).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
            ledger: Mutex::new(CommLedger::default()),
        }
    }

    fn lock(&self) -> MutexGuard<'_, ExchangeInner> {
        self.inner.lock().expect("shard exchange poisoned")
    }

    /// Deposit `block` (None = partitioned, deposit nothing) and read the
    /// round-`round` blocks of the shards in `wants`. Blocks from shards
    /// that never reached this round (dead, or retired early) are
    /// silently absent — the caller keeps its stale held view. The
    /// depart phase guarantees nobody overwrites a block before every
    /// live shard has read it.
    fn exchange(
        &self,
        shard: usize,
        round: u64,
        block: Option<Vec<TrainState>>,
        wants: &[usize],
    ) -> Vec<(usize, Vec<TrainState>)> {
        let arrive = 2 * round;
        let depart = arrive + 1;
        let mut g = self.lock();
        if let Some(b) = block {
            g.blocks[shard] = Some((round, b));
        }
        g.phase[shard] = arrive;
        self.cv.notify_all();
        while g.live.iter().zip(&g.phase).any(|(&l, &p)| l && p < arrive) {
            g = self.cv.wait(g).expect("shard exchange poisoned");
        }
        let incoming: Vec<(usize, Vec<TrainState>)> = wants
            .iter()
            .filter(|&&t| t != shard)
            .filter_map(|&t| match &g.blocks[t] {
                Some((r, b)) if *r == round => Some((t, b.clone())),
                _ => None,
            })
            .collect();
        g.phase[shard] = depart;
        self.cv.notify_all();
        while g.live.iter().zip(&g.phase).any(|(&l, &p)| l && p < depart) {
            g = self.cv.wait(g).expect("shard exchange poisoned");
        }
        drop(g);
        incoming
    }

    /// Mark a shard dead: waiters stop waiting on it, its last block
    /// stays available for salvage.
    fn retire(&self, shard: usize) {
        self.lock().live[shard] = false;
        self.cv.notify_all();
    }

    /// The last block `shard` ever deposited (salvage for failed shards).
    fn last_block(&self, shard: usize) -> Option<Vec<TrainState>> {
        self.lock().blocks[shard].as_ref().map(|(_, b)| b.clone())
    }

    fn record_cross_shard_publish(&self, node: usize, bytes: u64, round: u64, staleness: u64) {
        self.ledger
            .lock()
            .expect("exchange ledger poisoned")
            .record_cross_shard_publish(node, bytes, round, staleness);
    }

    fn record_shard_adopt(&self, node: usize, bytes: u64, round: u64) {
        self.ledger
            .lock()
            .expect("exchange ledger poisoned")
            .record_shard_adopt(node, bytes, round);
    }

    fn take_ledger(&self) -> CommLedger {
        std::mem::take(&mut *self.ledger.lock().expect("exchange ledger poisoned"))
    }
}

/// Guarantees a shard retires from the exchange however its thread exits
/// (completion, error, panic) — the liveness half of the no-deadlock
/// argument.
struct RetireOnDrop<'a> {
    exchange: &'a ShardExchange,
    shard: usize,
}

impl Drop for RetireOnDrop<'_> {
    fn drop(&mut self) {
        self.exchange.retire(self.shard);
    }
}

// -------------------------------------------------------------------------
// per-shard round-boundary driver
// -------------------------------------------------------------------------

struct ShardCtxInner {
    /// Index into the member list of the current router leader.
    leader_pos: usize,
    promotions: u64,
    rounds_missed: u64,
    /// Held view of each foreign shard's router block `(round, block)` —
    /// what this shard routes foreign seats against between refreshes.
    held: Vec<Option<(u64, Vec<TrainState>)>>,
    /// Delayed-Nesterov outer velocity per foreign shard, per router
    /// (catch-up state for partition heals).
    outer_v: Vec<Vec<Vec<f32>>>,
}

/// Everything one shard's router driver needs at an EM-round boundary:
/// apply shard-level faults, exchange blocks, refresh held views, and
/// publish the assembled global router set to the shard's own store.
pub struct ShardCtx<'f> {
    shard: usize,
    plan: &'f ShardPlan,
    /// The *fleet-level* plan — shard faults are consumed here so a
    /// replay after [`FaultPlan::reset`] re-fires them identically.
    faults: &'f FaultPlan,
    exchange: &'f ShardExchange,
    policy: ElasticPolicy,
    inner: Mutex<ShardCtxInner>,
}

impl<'f> ShardCtx<'f> {
    fn new(
        shard: usize,
        plan: &'f ShardPlan,
        faults: &'f FaultPlan,
        exchange: &'f ShardExchange,
        policy: ElasticPolicy,
    ) -> Self {
        let n = plan.n_shards();
        ShardCtx {
            shard,
            plan,
            faults,
            exchange,
            policy,
            inner: Mutex::new(ShardCtxInner {
                leader_pos: 0,
                promotions: 0,
                rounds_missed: 0,
                held: (0..n).map(|_| None).collect(),
                outer_v: vec![Vec::new(); n],
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, ShardCtxInner> {
        self.inner.lock().expect("shard ctx poisoned")
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Global seat of the current router leader.
    pub fn leader_seat(&self) -> usize {
        self.plan.members(self.shard)[self.lock().leader_pos]
    }

    fn counters(&self) -> (u64, u64) {
        let g = self.lock();
        (g.promotions, g.rounds_missed)
    }

    /// The EM-round boundary: the only place cross-shard communication
    /// (and its audit) ever happens. `round` is 1-based; `own` is this
    /// shard's freshly trained router block (one state per member seat,
    /// in member order). Publishes the assembled global router set to
    /// the shard's own store, honoring any publish gate for `round`.
    pub fn round_boundary(
        &self,
        handle: &ElasticHandle<'_, '_>,
        round: u64,
        own: &[TrainState],
    ) -> Result<()> {
        let members = self.plan.members(self.shard);
        ensure!(round >= 1, "EM rounds are 1-based at the shard exchange");
        ensure!(
            own.len() == members.len(),
            "shard {} publishes {} routers for {} member seats",
            self.shard,
            own.len(),
            members.len()
        );

        // Leader loss: promote the next member (deterministic order); it
        // adopts the dead leader's router block — one audited transfer
        // across the fault-domain boundary. The publish below is then
        // re-derived by the promoted member: accounting, never math.
        if self.faults.take_leader_loss(self.shard, round) {
            let mut g = self.lock();
            g.leader_pos = (g.leader_pos + 1) % members.len();
            g.promotions += 1;
            let promoted = members[g.leader_pos];
            drop(g);
            self.exchange
                .record_shard_adopt(promoted, router_block_bytes(own), round);
        }
        let leader = members[self.lock().leader_pos];

        // Partition: a cut shard neither deposits nor reads this round
        // (symmetric, like a real network cut); participants likewise
        // skip reading from cut shards. Both sides compute the cut from
        // the same fleet plan, so the exclusion agrees everywhere.
        let cut = self.faults.partition_blocks(self.shard, round);
        let wants: Vec<usize> = if cut {
            Vec::new()
        } else {
            (0..self.plan.n_shards())
                .filter(|&t| t != self.shard && !self.faults.partition_blocks(t, round))
                .collect()
        };
        if cut {
            self.lock().rounds_missed += 1;
        }
        let incoming = self
            .exchange
            .exchange(self.shard, round, (!cut).then(|| own.to_vec()), &wants);

        // Fold received blocks into held views. A fresh edge (staleness
        // 0) replaces the view outright; a healed edge catches up via
        // the delayed-Nesterov outer update, with the rounds missed
        // audited as the event's staleness.
        {
            let mut g = self.lock();
            let inner = &mut *g;
            for (from, block) in incoming {
                let staleness = match &inner.held[from] {
                    Some((held_round, _)) => round.saturating_sub(held_round + 1),
                    None => 0,
                };
                self.exchange.record_cross_shard_publish(
                    leader,
                    router_block_bytes(&block),
                    round,
                    staleness,
                );
                let view = if staleness > 0 {
                    let (_, held) = inner.held[from].take().expect("stale view must be held");
                    nesterov_catch_up(&self.policy, &held, &block, &mut inner.outer_v[from])
                } else {
                    block
                };
                inner.held[from] = Some((round, view));
            }
        }

        // Assemble the global router set this shard's nodes route
        // against: own block authoritative, foreign seats from held
        // views. A seat never received (cut since round 1, or a dead
        // sender) gets a routing-only placeholder — replaced at the
        // first heal, and never part of the authoritative final set.
        let total = self.plan.total_seats();
        let mut global: Vec<Option<TrainState>> = vec![None; total];
        for (i, &seat) in members.iter().enumerate() {
            global[seat] = Some(own[i].clone());
        }
        {
            let g = self.lock();
            for t in 0..self.plan.n_shards() {
                if t == self.shard {
                    continue;
                }
                if let Some((_, view)) = &g.held[t] {
                    for (i, &seat) in self.plan.members(t).iter().enumerate() {
                        if let Some(r) = view.get(i) {
                            global[seat] = Some(r.clone());
                        }
                    }
                }
            }
        }
        let global: Vec<TrainState> = global
            .into_iter()
            .map(|r| r.unwrap_or_else(|| own[0].clone()))
            .collect();

        // Delayed publish: hold until the shard has trained `min` total
        // steps — deterministic in steps, not wall-clock (the same gate
        // semantics as the single-fleet elastic path, keyed on rounds).
        if let Some(min) = self.faults.publish_gate(round) {
            while (handle.total_steps_done() as u64) < min
                && handle.live_nodes() > 0
                && !handle.failed()
            {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        handle.store().publish(global, round as usize);
        Ok(())
    }
}

/// The delayed-Nesterov outer update (the rejoin-merge rule) applied to
/// a stale held view of a foreign router block: `d = latest − held;
/// v = μ·v + d; view = held + γ·(d + μ·v)`. Shape mismatches (a foreign
/// shard re-initialized a router) fall back to taking `latest` directly.
fn nesterov_catch_up(
    policy: &ElasticPolicy,
    held: &[TrainState],
    latest: &[TrainState],
    outer_v: &mut Vec<Vec<f32>>,
) -> Vec<TrainState> {
    if held.len() != latest.len() {
        return latest.to_vec();
    }
    outer_v.resize(latest.len(), Vec::new());
    let gamma = policy.outer_lr as f32;
    let mu = policy.outer_momentum as f32;
    held.iter()
        .zip(latest)
        .zip(outer_v.iter_mut())
        .map(|((h, l), vel)| {
            if h.params.len() != l.params.len() {
                return l.clone();
            }
            if vel.len() != l.params.len() {
                *vel = vec![0.0; l.params.len()];
            }
            let mut params = Vec::with_capacity(l.params.len());
            for i in 0..l.params.len() {
                let d = l.params[i] - h.params[i];
                vel[i] = mu * vel[i] + d;
                params.push(h.params[i] + gamma * (d + mu * vel[i]));
            }
            TrainState::from_params(&l.variant, params, l.m.clone(), l.v.clone(), l.step)
        })
        .collect()
}

// -------------------------------------------------------------------------
// fleet orchestration
// -------------------------------------------------------------------------

/// Global seat ids for shard `s`'s local seats `0..k+extra`: members
/// first, then this shard's spare seats parked past every real seat.
fn global_ids(plan: &ShardPlan, shard: usize, extra: usize) -> Vec<usize> {
    plan.members(shard)
        .iter()
        .copied()
        .chain((0..extra).map(|i| plan.total_seats() + shard * extra + i))
        .collect()
}

/// Project the fleet-level [`ElasticPlan`] onto one shard: node faults
/// filtered by membership and remapped to local indices, a whole-shard
/// kill expanded to one tagged kill per member, publish gates copied
/// (they are round-keyed, not node-keyed), and the local→global routing
/// identity attached.
fn shard_local_plan(plan: &ShardPlan, shard: usize, fleet: &ElasticPlan) -> ElasticPlan {
    let members = plan.members(shard);
    let local_of = |g: usize| members.iter().position(|&m| m == g);
    let f = &fleet.faults;
    let mut kills: Vec<KillSpec> = f
        .kills
        .iter()
        .filter_map(|k| {
            local_of(k.node).map(|node| KillSpec {
                node,
                at_step: k.at_step,
            })
        })
        .collect();
    let transients: Vec<TransientSpec> = f
        .transients
        .iter()
        .filter_map(|t| local_of(t.node).map(|node| TransientSpec { node, ..*t }))
        .collect();
    let stalls: Vec<StallSpec> = f
        .stalls
        .iter()
        .filter_map(|s| local_of(s.node).map(|node| StallSpec { node, ..*s }))
        .collect();
    let drops: Vec<DropSpec> = f
        .drops
        .iter()
        .filter_map(|d| local_of(d.node).map(|node| DropSpec { node, ..*d }))
        .collect();
    let mut shard_kill_indices = Vec::new();
    if let Some(at_step) = f.shard_kill_step(shard) {
        for node in 0..members.len() {
            shard_kill_indices.push(kills.len());
            kills.push(KillSpec { node, at_step });
        }
    }
    let faults = FaultPlan::from_specs(
        f.seed,
        kills,
        transients,
        stalls,
        drops,
        f.publish_gates.clone(),
    );
    let leaves: Vec<LeaveEvent> = fleet
        .leaves
        .iter()
        .filter_map(|ev| local_of(ev.node).map(|node| LeaveEvent { node, ..*ev }))
        .collect();
    let extra = fleet.policy.max_extra_nodes;
    ElasticPlan {
        faults,
        leaves,
        policy: fleet.policy,
        shard_kill_indices,
        seat_identity: Some(SeatIdentity {
            global: global_ids(plan, shard, extra),
            space: plan.total_seats(),
        }),
    }
}

/// Per-shard rollup of a fleet run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardStats {
    pub shard: usize,
    /// The shard's own elastic counters (kills, adoptions, ...).
    pub stats: ElasticStats,
    /// Leader promotions after leader-loss faults.
    pub promotions: u64,
    /// EM rounds this shard spent cut off the cross-shard exchange.
    pub rounds_missed: u64,
    /// Seat recoveries that crossed the shard's fault-domain boundary
    /// (whole-shard kills re-adopted from member checkpoints).
    pub shard_kills: u64,
}

/// What a whole fleet run reports: fleet-summed stats, per-shard rows,
/// the merged ledger (stores + elastic recoveries + cross-shard
/// exchange, all in global seat ids), and every seat's end.
pub struct FleetReport {
    pub stats: ElasticStats,
    pub shards: Vec<ShardStats>,
    pub ledger: CommLedger,
    /// One entry per seat that ever ran, sorted by global seat id.
    pub ends: Vec<NodeEnd>,
}

/// Elastic/fleet accounting as surfaced in the end-of-run report
/// (`shards` is empty for single-fleet elastic runs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ElasticSummary {
    pub stats: ElasticStats,
    pub shards: Vec<ShardStats>,
}

fn add_stats(a: &ElasticStats, b: &ElasticStats) -> ElasticStats {
    ElasticStats {
        kills: a.kills + b.kills,
        adoptions: a.adoptions + b.adoptions,
        leaves: a.leaves + b.leaves,
        joins: a.joins + b.joins,
        merges: a.merges + b.merges,
        steps_lost: a.steps_lost + b.steps_lost,
        transient_retries: a.transient_retries + b.transient_retries,
        recovery_micros: a.recovery_micros + b.recovery_micros,
    }
}

struct ShardRun {
    report: ElasticReport,
    store_ledger: CommLedger,
    block: Vec<TrainState>,
}

struct ShardSlot {
    shard: usize,
    promotions: u64,
    rounds_missed: u64,
    outcome: Result<ShardRun>,
}

/// Run an elastic fleet partitioned into shard fault domains: one
/// [`run_elastic_nodes`] per shard (own [`SnapshotStore`], own
/// checkpoint namespace `<dir>/shard{s}/`), cross-shard router exchange
/// at EM-round boundaries only, and shard-level faults from the fleet
/// plan. `driver(shard, ctx, handle)` runs the shard's router loop and
/// returns the shard's final router block (member order); it must call
/// [`ShardCtx::round_boundary`] once per EM round.
///
/// Returns `Ok` whenever at least one shard survives: failed shards are
/// reported in their [`ShardStats`] row and their seats degrade, with
/// their last exchanged block salvaged into the final global router set.
pub fn run_sharded_nodes<'env, B, G, D>(
    backend: &B,
    plan: &ShardPlan,
    seeds: &[u64],
    stream_factory: G,
    cfg: &NodeRunConfig,
    fleet: &ElasticPlan,
    driver: D,
) -> Result<(FleetReport, Vec<TrainState>)>
where
    B: TrainBackend,
    G: Fn(usize, u64) -> SequenceGen<'env> + Sync,
    D: Fn(usize, &ShardCtx<'_>, &ElasticHandle<'_, 'env>) -> Result<Vec<TrainState>> + Sync,
{
    ensure!(
        seeds.len() == plan.total_seats(),
        "{} seeds for {} expert seats",
        seeds.len(),
        plan.total_seats()
    );
    ensure!(
        fleet.seat_identity.is_none(),
        "fleet plans derive seat identities per shard; leave seat_identity unset"
    );
    ensure!(
        fleet.shard_kill_indices.is_empty(),
        "fleet plans derive shard-kill tags per shard; leave shard_kill_indices unset"
    );
    // Re-arm the fleet plan's one-shot shard faults so a replay of the
    // same plan re-fires them identically (node faults live on the
    // derived local plans, which run_elastic_nodes resets itself).
    fleet.faults.reset();
    let n_shards = plan.n_shards();
    let exchange = ShardExchange::new(n_shards);

    let slots: Vec<ShardSlot> = std::thread::scope(|scope| {
        let exchange = &exchange;
        let stream_factory = &stream_factory;
        let driver = &driver;
        let handles: Vec<_> = (0..n_shards)
            .map(|s| {
                scope.spawn(move || {
                    let _retire = RetireOnDrop { exchange, shard: s };
                    let members = plan.members(s);
                    let local = shard_local_plan(plan, s, fleet);
                    let identity = local
                        .seat_identity
                        .clone()
                        .expect("local shard plans always carry an identity");
                    let mut shard_cfg = cfg.clone();
                    if let Some(root) = &cfg.checkpoint_dir {
                        let sub = root.join(format!("shard{s}"));
                        if let Err(e) = std::fs::create_dir_all(&sub) {
                            return ShardSlot {
                                shard: s,
                                promotions: 0,
                                rounds_missed: 0,
                                outcome: Err(anyhow!(e).context(format!(
                                    "creating checkpoint directory for shard {s}"
                                ))),
                            };
                        }
                        shard_cfg.checkpoint_dir = Some(sub);
                        // pre-shard flat checkpoints only map cleanly
                        // when the fleet is one shard (global == local)
                        shard_cfg.legacy_flat_dir = (n_shards == 1).then(|| root.clone());
                    }
                    let store = SnapshotStore::new_sharded(members.len(), s);
                    let shard_seeds: Vec<u64> = members.iter().map(|&g| seeds[g]).collect();
                    let ident = identity.global.clone();
                    let factory = move |l: usize, salt: u64| {
                        stream_factory(ident.get(l).copied().unwrap_or(l), salt)
                    };
                    let ctx = ShardCtx::new(s, plan, &fleet.faults, exchange, fleet.policy);
                    let run = run_elastic_nodes(
                        backend,
                        &store,
                        &shard_seeds,
                        factory,
                        &shard_cfg,
                        &local,
                        |handle| driver(s, &ctx, handle),
                    );
                    let (promotions, rounds_missed) = ctx.counters();
                    ShardSlot {
                        shard: s,
                        promotions,
                        rounds_missed,
                        outcome: run.map(|(report, block)| ShardRun {
                            report,
                            store_ledger: store.take_ledger(),
                            block,
                        }),
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(s, h)| {
                h.join().unwrap_or_else(|_| ShardSlot {
                    shard: s,
                    promotions: 0,
                    rounds_missed: 0,
                    outcome: Err(anyhow!("shard {s} thread panicked")),
                })
            })
            .collect()
    });

    let total = plan.total_seats();
    let extra = fleet.policy.max_extra_nodes;
    let mut merged = CommLedger::default();
    let mut shard_rows = Vec::with_capacity(n_shards);
    let mut agg = ElasticStats::default();
    let mut ends: Vec<NodeEnd> = Vec::new();
    let mut blocks: Vec<Option<Vec<TrainState>>> = (0..n_shards).map(|_| None).collect();
    let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();

    for slot in slots {
        let s = slot.shard;
        match slot.outcome {
            Ok(run) => {
                let identity = global_ids(plan, s, extra);
                let shard_kills = run
                    .report
                    .ledger
                    .events
                    .iter()
                    .filter(|e| e.kind == CommKind::ShardAdopt)
                    .count() as u64;
                for mut ev in run.store_ledger.events {
                    if ev.kind == CommKind::SnapshotBroadcast && ev.bytes_received == 0 {
                        // the publisher pseudo-node: remap to a per-shard
                        // leader id past every real seat and spare
                        ev.node = total + n_shards * extra + s;
                    } else {
                        ev.node = identity.get(ev.node).copied().unwrap_or(ev.node);
                    }
                    merged.record(ev);
                }
                for mut ev in run.report.ledger.events {
                    ev.node = identity.get(ev.node).copied().unwrap_or(ev.node);
                    merged.record(ev);
                }
                agg = add_stats(&agg, &run.report.stats);
                shard_rows.push(ShardStats {
                    shard: s,
                    stats: run.report.stats,
                    promotions: slot.promotions,
                    rounds_missed: slot.rounds_missed,
                    shard_kills,
                });
                for mut end in run.report.ends {
                    remap_end(&mut end, &identity);
                    ends.push(end);
                }
                blocks[s] = Some(run.block);
            }
            Err(e) => {
                eprintln!("[fleet] shard {s} failed: {e:#}");
                shard_rows.push(ShardStats {
                    shard: s,
                    stats: ElasticStats::default(),
                    promotions: slot.promotions,
                    rounds_missed: slot.rounds_missed,
                    shard_kills: 0,
                });
                failures.push((s, e));
            }
        }
    }
    if failures.len() == n_shards {
        let (s, e) = failures.swap_remove(0);
        return Err(e.context(format!("every fleet shard failed (first: shard {s})")));
    }
    for (s, _) in &failures {
        // a dead shard's last exchanged block is still authoritative for
        // its seats (it crossed the boundary before the failure)
        blocks[*s] = exchange.last_block(*s);
    }
    let exchange_ledger = exchange.take_ledger();
    merged.events.extend(exchange_ledger.events);

    let fallback = blocks
        .iter()
        .flatten()
        .flat_map(|b| b.first())
        .next()
        .cloned()
        .context("no shard produced any router block")?;
    let mut global: Vec<Option<TrainState>> = (0..total).map(|_| None).collect();
    for (s, block) in blocks.iter().enumerate() {
        if let Some(block) = block {
            for (i, &seat) in plan.members(s).iter().enumerate() {
                if let Some(r) = block.get(i) {
                    global[seat] = Some(r.clone());
                }
            }
        }
    }
    let routers: Vec<TrainState> = global
        .into_iter()
        .map(|r| r.unwrap_or_else(|| fallback.clone()))
        .collect();

    shard_rows.sort_by_key(|r| r.shard);
    ends.sort_by_key(NodeEnd::node);
    Ok((
        FleetReport {
            stats: agg,
            shards: shard_rows,
            ledger: merged,
            ends,
        },
        routers,
    ))
}

fn remap_end(end: &mut NodeEnd, identity: &[usize]) {
    match end {
        NodeEnd::Completed(o) | NodeEnd::Left(o) => {
            o.node = identity.get(o.node).copied().unwrap_or(o.node);
        }
        NodeEnd::Failed(f) => {
            f.node = identity.get(f.node).copied().unwrap_or(f.node);
        }
    }
}

// -------------------------------------------------------------------------
// end-of-run report
// -------------------------------------------------------------------------

/// Human-readable elastic/fleet rollup for the `smalltalk train` report.
pub fn render_elastic_summary(s: &ElasticSummary) -> String {
    let st = &s.stats;
    let mut out = format!(
        "elastic: kills {}, adoptions {}, leaves {}, joins {}, merges {}, steps_lost {}, transient_retries {}, recovery {} us",
        st.kills,
        st.adoptions,
        st.leaves,
        st.joins,
        st.merges,
        st.steps_lost,
        st.transient_retries,
        st.recovery_micros
    );
    for row in &s.shards {
        out.push_str(&format!(
            "\n  shard {}: kills {}, adoptions {}, steps_lost {}, promotions {}, rounds_missed {}, shard_kills {}, recovery {} us",
            row.shard,
            row.stats.kills,
            row.stats.adoptions,
            row.stats.steps_lost,
            row.promotions,
            row.rounds_missed,
            row.shard_kills,
            row.stats.recovery_micros
        ));
    }
    out
}

fn stats_json(st: &ElasticStats) -> Json {
    Json::obj(vec![
        ("kills", Json::num(st.kills as f64)),
        ("adoptions", Json::num(st.adoptions as f64)),
        ("leaves", Json::num(st.leaves as f64)),
        ("joins", Json::num(st.joins as f64)),
        ("merges", Json::num(st.merges as f64)),
        ("steps_lost", Json::num(st.steps_lost as f64)),
        ("transient_retries", Json::num(st.transient_retries as f64)),
        ("recovery_micros", Json::num(st.recovery_micros as f64)),
    ])
}

/// The same rollup as JSON (for `smalltalk train --json`).
pub fn elastic_summary_json(s: &ElasticSummary) -> Json {
    Json::obj(vec![
        ("stats", stats_json(&s.stats)),
        (
            "shards",
            Json::Arr(
                s.shards
                    .iter()
                    .map(|row| {
                        Json::obj(vec![
                            ("shard", Json::num(row.shard as f64)),
                            ("promotions", Json::num(row.promotions as f64)),
                            ("rounds_missed", Json::num(row.rounds_missed as f64)),
                            ("shard_kills", Json::num(row.shard_kills as f64)),
                            ("stats", stats_json(&row.stats)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// -------------------------------------------------------------------------
// production entry point
// -------------------------------------------------------------------------

/// Async mixture training across `t.shards` fault domains: each shard
/// runs its own router EM (over its member seats only, salted per
/// shard) and its own elastic expert nodes, publishing the assembled
/// global router set to its own store every EM round (`snapshot_every`
/// does not apply — round boundaries are the cross-shard sync points).
/// Called by [`run_trainer`](super::trainer::run_trainer) when
/// `t.shards > 1`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_trainer_async_sharded(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend<'_>,
    router_meta: VariantMeta,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    ensure!(
        p.em_rounds > 0,
        "async training needs at least one EM round to publish a router snapshot"
    );
    ensure!(
        t.join_after == 0,
        "--join-after is not supported with --shards (hot-spare adoption is shard-local)"
    );
    let shard_plan = ShardPlan::partition(p.n_experts, t.shards)?;
    let faults = match &t.chaos_spec {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading chaos spec {}", path.display()))?;
            FaultPlan::from_json_str(&text)
                .with_context(|| format!("parsing chaos spec {}", path.display()))?
        }
        None => FaultPlan::none(),
    };
    let mut leaves = Vec::new();
    if t.leave_after > 0 {
        ensure!(p.n_experts > 0, "cannot schedule a leave with zero experts");
        leaves.push(LeaveEvent {
            node: p.n_experts - 1,
            at_step: t.leave_after,
            adopt: false,
            rejoin: None,
        });
    }
    let fleet = ElasticPlan {
        faults,
        leaves,
        ..ElasticPlan::default()
    };

    let seeds: Vec<u64> = (0..p.n_experts).map(|e| p.seed ^ (0xE0 + e as u64)).collect();
    let factory = |e: usize, salt: u64| {
        SequenceGen::new(
            bpe,
            expert_meta.seq_len,
            p.seed ^ (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };
    // shard 0 reproduces the single-fleet seeds exactly; other shards
    // train their EM on salted, disjoint streams
    let shard_salt = |s: usize| (s as u64).wrapping_mul(0x9E37_79B9_97F4_A7C5);
    let shard_logs: Mutex<Vec<Option<RunLog>>> = Mutex::new((0..t.shards).map(|_| None).collect());

    let (report, routers) = run_sharded_nodes(
        backend,
        &shard_plan,
        &seeds,
        factory,
        run_cfg,
        &fleet,
        |s, ctx: &ShardCtx<'_>, handle: &ElasticHandle<'_, '_>| {
            let em_cfg = EmConfig {
                n_routers: shard_plan.members(s).len(),
                rounds: em.rounds,
                chunk_size: em.chunk_size,
                steps_per_round: em.steps_per_round,
                prefix_len: em.prefix_len,
                seed: em.seed ^ shard_salt(s),
                threads: em.threads,
            };
            // the leader-local score exchange costs nothing on the
            // cluster; only the boundary traffic is audited
            let mut local_ledger = CommLedger::default();
            let mut log = RunLog::new();
            let mut router_gen =
                SequenceGen::new(bpe, router_meta.seq_len, p.seed ^ 0x52_0000 ^ shard_salt(s));
            let trained = train_routers_hooked(
                engine,
                &p.router_variant,
                &em_cfg,
                &mut router_gen,
                &mut local_ledger,
                &mut log,
                |round, routers| ctx.round_boundary(handle, round as u64 + 1, routers),
            )?;
            shard_logs.lock().expect("shard logs poisoned")[s] = Some(log);
            Ok(trained.routers)
        },
    )?;

    let mut log = RunLog::new();
    for (s, shard_log) in shard_logs
        .into_inner()
        .expect("shard logs poisoned")
        .into_iter()
        .enumerate()
    {
        if let Some(shard_log) = shard_log {
            log.merge_prefixed(&format!("shard{s}"), &shard_log);
        }
    }
    let FleetReport {
        stats,
        shards,
        ledger,
        ends,
    } = report;
    log.scalar("elastic/kills", 0.0, stats.kills as f64);
    log.scalar("elastic/adoptions", 0.0, stats.adoptions as f64);
    log.scalar("elastic/leaves", 0.0, stats.leaves as f64);
    log.scalar("elastic/joins", 0.0, stats.joins as f64);
    log.scalar("elastic/merges", 0.0, stats.merges as f64);
    log.scalar("elastic/steps_lost", 0.0, stats.steps_lost as f64);
    log.scalar(
        "elastic/transient_retries",
        0.0,
        stats.transient_retries as f64,
    );
    log.scalar("elastic/recovery_micros", 0.0, stats.recovery_micros as f64);
    for row in &shards {
        let s = row.shard;
        log.scalar(
            &format!("fleet/shard{s}_promotions"),
            0.0,
            row.promotions as f64,
        );
        log.scalar(
            &format!("fleet/shard{s}_rounds_missed"),
            0.0,
            row.rounds_missed as f64,
        );
        log.scalar(
            &format!("fleet/shard{s}_shard_kills"),
            0.0,
            row.shard_kills as f64,
        );
        log.scalar(&format!("fleet/shard{s}_kills"), 0.0, row.stats.kills as f64);
        log.scalar(
            &format!("fleet/shard{s}_steps_lost"),
            0.0,
            row.stats.steps_lost as f64,
        );
    }

    let mut slots: Vec<Option<NodeEnd>> = (0..p.n_experts).map(|_| None).collect();
    for end in ends {
        let seat = end.node();
        if seat < slots.len() {
            slots[seat] = Some(end);
        }
    }
    let mut experts = Vec::with_capacity(p.n_experts);
    let mut segment_purity = Vec::with_capacity(p.n_experts);
    let mut segment_sizes = Vec::with_capacity(p.n_experts);
    for (e, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(NodeEnd::Completed(o)) | Some(NodeEnd::Left(o)) => {
                log.merge_prefixed(&format!("expert{e}"), &o.log);
                log.scalar(&format!("async/node{e}_drawn"), 0.0, o.drawn as f64);
                log.scalar(&format!("async/node{e}_kept"), 0.0, o.kept as f64);
                log.scalar(&format!("async/node{e}_steps"), 0.0, o.steps_done as f64);
                segment_purity.push(o.purity());
                segment_sizes.push(o.trained_sequences() as usize);
                experts.push(o.state);
            }
            other => {
                // degraded seat: salvage from the failure, else its
                // shard-namespaced checkpoint, else a cold init
                if let Some(NodeEnd::Failed(f)) = &other {
                    eprintln!("[fleet] node {e} degraded: {:#}", f.error);
                }
                log.scalar(&format!("elastic/node{e}_degraded"), 0.0, 1.0);
                segment_purity.push(0.0);
                segment_sizes.push(0);
                let salvage = match other {
                    Some(NodeEnd::Failed(f)) => f.salvage,
                    _ => None,
                };
                let state = match salvage {
                    Some(s) => s,
                    None => {
                        let shard = shard_plan.shard_of(e);
                        let local = shard_plan
                            .members(shard)
                            .iter()
                            .position(|&g| g == e)
                            .unwrap_or(0);
                        let from_ckpt = run_cfg
                            .checkpoint_dir
                            .as_ref()
                            .map(|d| ckpt_path(&d.join(format!("shard{shard}")), local))
                            .filter(|path| path.exists());
                        match from_ckpt {
                            Some(path) => {
                                load_node_checkpoint(&path)
                                    .with_context(|| {
                                        format!("recovering degraded node {e} from its checkpoint")
                                    })?
                                    .state
                            }
                            None => backend.init_expert(e, p.seed ^ (0xE0 + e as u64))?,
                        }
                    }
                };
                experts.push(state);
            }
        }
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers,
            router_meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
        elastic: Some(ElasticSummary { stats, shards }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(x: f32) -> TrainState {
        TrainState::from_params("router_micro", vec![x, x + 1.0], vec![0.0; 2], vec![0.0; 2], 1)
    }

    #[test]
    fn partition_is_near_even_and_covering() {
        let plan = ShardPlan::partition(10, 3).unwrap();
        assert_eq!(plan.n_shards(), 3);
        assert_eq!(plan.total_seats(), 10);
        assert_eq!(plan.members(0), &[0, 1, 2, 3]);
        assert_eq!(plan.members(1), &[4, 5, 6]);
        assert_eq!(plan.members(2), &[7, 8, 9]);
        assert_eq!(plan.shard_of(0), 0);
        assert_eq!(plan.shard_of(6), 1);
        assert_eq!(plan.shard_of(9), 2);
        assert!(ShardPlan::partition(2, 3).is_err());
        assert!(ShardPlan::partition(4, 0).is_err());
    }

    #[test]
    fn from_members_rejects_overlap_gap_and_empty() {
        assert!(ShardPlan::from_members(vec![vec![0, 1], vec![2]]).is_ok());
        assert!(ShardPlan::from_members(vec![vec![0, 1], vec![1]]).is_err());
        assert!(ShardPlan::from_members(vec![vec![0, 3], vec![1]]).is_err());
        assert!(ShardPlan::from_members(vec![vec![0], vec![]]).is_err());
        assert!(ShardPlan::from_members(vec![]).is_err());
    }

    #[test]
    fn exchange_swaps_blocks_and_skips_dead_shards() {
        let ex = ShardExchange::new(3);
        ex.retire(2); // never shows up
        let b0 = vec![state(1.0)];
        let b1 = vec![state(5.0)];
        let (got0, got1) = std::thread::scope(|scope| {
            let ex = &ex;
            let h0 = scope.spawn(move || ex.exchange(0, 1, Some(b0), &[1, 2]));
            let h1 = scope.spawn(move || ex.exchange(1, 1, Some(b1), &[0, 2]));
            (h0.join().unwrap(), h1.join().unwrap())
        });
        assert_eq!(got0.len(), 1);
        assert_eq!(got0[0].0, 1);
        assert_eq!(got0[0].1[0].params, vec![5.0, 6.0]);
        assert_eq!(got1.len(), 1);
        assert_eq!(got1[0].0, 0);
        assert_eq!(got1[0].1[0].params, vec![1.0, 2.0]);
        assert_eq!(ex.last_block(0).unwrap()[0].params, vec![1.0, 2.0]);
        assert!(ex.last_block(2).is_none());
    }

    #[test]
    fn exchange_rounds_never_read_stale_deposits() {
        let ex = ShardExchange::new(2);
        std::thread::scope(|scope| {
            let ex = &ex;
            for s in 0..2usize {
                scope.spawn(move || {
                    for round in 1..=4u64 {
                        let mine = vec![state(s as f32 * 100.0 + round as f32)];
                        let got = ex.exchange(s, round, Some(mine), &[1 - s]);
                        assert_eq!(got.len(), 1, "shard {s} round {round}");
                        let expect = (1 - s) as f32 * 100.0 + round as f32;
                        assert_eq!(got[0].1[0].params[0], expect, "shard {s} round {round}");
                    }
                });
            }
        });
    }

    #[test]
    fn partitioned_exchange_neither_sends_nor_receives() {
        let ex = ShardExchange::new(2);
        let b1 = vec![state(5.0)];
        let (cut, open) = std::thread::scope(|scope| {
            let ex = &ex;
            let h0 = scope.spawn(move || ex.exchange(0, 1, None, &[]));
            let h1 = scope.spawn(move || ex.exchange(1, 1, Some(b1), &[]));
            (h0.join().unwrap(), h1.join().unwrap())
        });
        assert!(cut.is_empty());
        assert!(open.is_empty());
        assert!(ex.last_block(0).is_none(), "cut shard deposited nothing");
    }

    #[test]
    fn nesterov_catch_up_matches_the_merge_rule() {
        let policy = ElasticPolicy::default(); // gamma 0.5, mu 0.9
        let held = vec![state(0.0)];
        let latest = vec![state(1.0)];
        let mut vel = Vec::new();
        let view = nesterov_catch_up(&policy, &held, &latest, &mut vel);
        // d = 1, v = 0.9*0 + 1 = 1, view = 0 + 0.5*(1 + 0.9) = 0.95
        assert!((view[0].params[0] - 0.95).abs() < 1e-6, "{}", view[0].params[0]);
        assert_eq!(vel[0][0], 1.0);
        // a second heal from the same gap accelerates via the velocity
        let view2 = nesterov_catch_up(&policy, &view, &vec![state(2.0)], &mut vel);
        assert!(view2[0].params[0] > view[0].params[0]);
    }

    #[test]
    fn nesterov_catch_up_falls_back_on_shape_mismatch() {
        let policy = ElasticPolicy::default();
        let held = vec![TrainState::from_params("r", vec![0.0], vec![0.0], vec![0.0], 0)];
        let latest = vec![state(3.0)];
        let mut vel = Vec::new();
        let view = nesterov_catch_up(&policy, &held, &latest, &mut vel);
        assert_eq!(view[0].params, latest[0].params);
    }

    #[test]
    fn local_plan_filters_remaps_and_tags_shard_kills() {
        let plan = ShardPlan::partition(4, 2).unwrap();
        let mut faults = FaultPlan::none();
        faults.kills = vec![
            KillSpec { node: 0, at_step: 3 },
            KillSpec { node: 2, at_step: 5 },
        ];
        faults.shard_kills = vec![super::super::chaos::ShardKillSpec { shard: 1, at_step: 7 }];
        let fleet = ElasticPlan {
            faults,
            ..ElasticPlan::default()
        };
        let local0 = shard_local_plan(&plan, 0, &fleet);
        assert_eq!(local0.faults.kills, vec![KillSpec { node: 0, at_step: 3 }]);
        assert!(local0.shard_kill_indices.is_empty());
        let id0 = local0.seat_identity.unwrap();
        assert_eq!(id0.global, vec![0, 1]);
        assert_eq!(id0.space, 4);
        let local1 = shard_local_plan(&plan, 1, &fleet);
        // node-level kill on global seat 2 remaps to local 0; the shard
        // kill expands to one tagged kill per member after it
        assert_eq!(
            local1.faults.kills,
            vec![
                KillSpec { node: 0, at_step: 5 },
                KillSpec { node: 0, at_step: 7 },
                KillSpec { node: 1, at_step: 7 },
            ]
        );
        assert_eq!(local1.shard_kill_indices, vec![1, 2]);
        assert_eq!(local1.seat_identity.unwrap().global, vec![2, 3]);
    }

    #[test]
    fn summary_render_and_json_pin_the_report_shape() {
        let summary = ElasticSummary {
            stats: ElasticStats {
                kills: 3,
                steps_lost: 7,
                ..ElasticStats::default()
            },
            shards: vec![ShardStats {
                shard: 1,
                stats: ElasticStats {
                    kills: 2,
                    ..ElasticStats::default()
                },
                promotions: 1,
                rounds_missed: 2,
                shard_kills: 2,
            }],
        };
        let text = render_elastic_summary(&summary);
        assert!(text.starts_with("elastic: kills 3,"), "{text}");
        assert!(text.contains("steps_lost 7"), "{text}");
        assert!(text.contains("shard 1: kills 2"), "{text}");
        assert!(text.contains("promotions 1"), "{text}");
        assert!(text.contains("rounds_missed 2"), "{text}");
        assert!(text.contains("shard_kills 2"), "{text}");

        let j = elastic_summary_json(&summary);
        assert_eq!(j.get("stats").unwrap().get("kills").unwrap().as_i64(), Some(3));
        let Some(Json::Arr(rows)) = j.get("shards") else {
            panic!("shards must be an array");
        };
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("shard").unwrap().as_i64(), Some(1));
        assert_eq!(rows[0].get("promotions").unwrap().as_i64(), Some(1));
        assert_eq!(
            rows[0].get("stats").unwrap().get("kills").unwrap().as_i64(),
            Some(2)
        );
        // round-trips through the repo's own JSON printer/parser
        let reparsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            reparsed.get("shards").unwrap().get("x").is_none(),
            true
        );
    }
}
