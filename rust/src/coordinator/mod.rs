//! The paper's contribution: the SmallTalk LM coordinator.
//!
//! * [`assignment`] — balanced / argmin assignment (Fig. 1, Eq. 4)
//! * [`scoring`] — batched prefix-NLL score matrices
//! * [`em`] — router EM training (Algorithm 1 lines 1–10)
//! * [`sharding`] — corpus segmentation by trained routers (lines 12–13)
//! * [`expert`] — independent expert training (lines 14–16)
//! * [`inference`] — argmin routing + batched serving loop
//! * [`server`] — continuous-batching serve: cross-wave request queue
//!   with admission scheduling
//! * [`replica`] — the serving fleet behind [`server`]'s dispatch queue:
//!   expert→replica placement with hot-expert replication, least-loaded
//!   dispatch, and histogram-driven online rebalance
//! * [`net`] — the TCP/JSONL wire front-end over [`server`]: streaming
//!   request/response lines, load shedding, per-client fairness
//! * [`comm`] — communication ledger and §A.4 closed forms
//! * [`pipeline`] — end-to-end orchestration (routers → shard → experts)
//! * [`trainer`] — event-driven trainer nodes: staged (bit-exact classic
//!   pipeline), async (checkpointed, stale-router-snapshot), and elastic
//!   (failure-tolerant, join/leave membership) modes
//! * [`chaos`] — seeded deterministic fault plans for the elastic
//!   trainer's chaos harness
//! * [`fleet`] — fleet-sharded elastic training: expert seats across
//!   multiple snapshot-store fault domains with round-boundary-only
//!   cross-shard exchange and shard-level chaos

pub mod assignment;
pub mod chaos;
pub mod comm;
pub mod em;
pub mod expert;
pub mod fleet;
pub mod inference;
pub mod net;
pub mod pipeline;
pub mod replica;
pub mod scoring;
pub mod server;
pub mod sharding;
pub mod trainer;

pub use assignment::{argmin_assign, balanced_assign, sequential_assign, Assignment};
pub use comm::{CommKind, CommLedger};
pub use em::{train_routers, train_routers_hooked, EmConfig, TrainedRouters};
pub use expert::{train_expert, ExpertConfig};
pub use inference::{
    amortized_micros, dense_perplexity, eval_nll_groups, group_by_expert, plan_wave,
    response_triples, serve, serve_replicated, serve_threaded, EvalLaunch, EvalUnit, Mixture,
    Request, Response, WavePlan,
};
pub use pipeline::{run_pipeline, run_pipeline_reference, PipelineConfig, PipelineResult};
pub use chaos::{
    is_transient, DropSpec, FaultPlan, KillSpec, LeaderLossSpec, PlanShape, PublishGate,
    ShardKillSpec, ShardPartitionSpec, StallSpec, TransientFault, TransientSpec,
};
pub use fleet::{
    elastic_summary_json, render_elastic_summary, router_block_bytes, run_sharded_nodes,
    ElasticSummary, FleetReport, ShardCtx, ShardExchange, ShardPlan, ShardStats,
};
pub use trainer::{
    run_async_nodes, run_elastic_nodes, run_staged_nodes, run_trainer, ElasticHandle, ElasticPlan,
    ElasticPolicy, ElasticReport, ElasticStats, EngineBackend, LeaveEvent, NodeEnd, NodeFailure,
    NodeOutcome, NodeProgress, NodeRunConfig, Rejoin, RouterSnapshot, SeatIdentity, SnapshotStore,
    TrainBackend, TrainMode, TrainerConfig, TrainerHandle,
};
pub use net::{serve_net, FairMux, NetConfig, NetHandle, NetReport};
pub use replica::{
    DispatchPick, PlacementMap, PlacementMove, ReplicaLane, ReplicaReport, ReplicaSet,
};
pub use server::{
    run_server, run_server_streaming, MixtureBackend, SchedStats, ServeBackend, ServerClient,
    ServerConfig, SubmitOutcome,
};
pub use scoring::{
    score_matrix, score_matrix_rows, score_matrix_rows_fanout, score_matrix_rows_fused,
    score_matrix_rows_threaded, score_matrix_threaded,
};
pub use sharding::{shard_corpus, Shards};
