//! Corpus sharding by trained routers (Algorithm 1, lines 12–13).
//!
//! Draws the expert-training corpus, scores every sequence's prefix under
//! every router, and produces E balanced segments. The score exchange is
//! the mixture's only pre-expert-training collective and is recorded in
//! the comm ledger (chunked the way §A.4 describes: scores for ~T tokens
//! of data per exchange).
//!
//! This leader-side sharding is the *staged* (barrier) path. The async
//! trainer ([`super::trainer`]) replaces it with node-local routing
//! against broadcast router snapshots — each node keeps what routes to
//! itself from its own stream, and no corpus-wide score all-gather ever
//! happens.

use anyhow::Result;

use super::assignment::balanced_assign;
use super::comm::CommLedger;
use super::scoring::score_matrix_threaded;
use crate::data::{Sequence, SequenceGen};
use crate::runtime::{Engine, TrainState, VariantMeta};

/// The sharded corpus: one segment per expert plus provenance.
pub struct Shards {
    pub segments: Vec<Vec<Sequence>>,
    /// `nll[seq][router]` for diagnostics (Fig. 5 uses segment scores).
    pub expert_of: Vec<usize>,
}

/// Shard `n_sequences` fresh sequences into `routers.len()` balanced
/// segments using prefix scoring with prefix length `m`. Router scoring
/// fans across `threads` workers (`<= 1` scores sequentially).
pub fn shard_corpus(
    engine: &Engine,
    routers: &[TrainState],
    meta: &VariantMeta,
    gen: &mut SequenceGen,
    n_sequences: usize,
    m: usize,
    ledger: &mut CommLedger,
    threads: usize,
) -> Result<Shards> {
    let seqs: Vec<Sequence> = gen.batch(n_sequences);
    let nll = score_matrix_threaded(engine, routers, meta, &seqs, m, threads)?;
    ledger.record_score_allgather(routers.len(), n_sequences as u64, u64::MAX);
    let assignment = balanced_assign(&nll, None);

    let mut segments: Vec<Vec<Sequence>> = (0..routers.len()).map(|_| Vec::new()).collect();
    for (i, seq) in seqs.into_iter().enumerate() {
        segments[assignment.expert_of[i]].push(seq);
    }
    Ok(Shards {
        segments,
        expert_of: assignment.expert_of,
    })
}

impl Shards {
    /// Fraction of each segment drawn from its plurality domain — the
    /// specialization diagnostic reported alongside Fig. 5.
    pub fn segment_purity(&self) -> Vec<f64> {
        self.segments
            .iter()
            .map(|seg| {
                if seg.is_empty() {
                    return 0.0;
                }
                let mut counts = std::collections::HashMap::new();
                for s in seg {
                    *counts.entry(s.domain).or_insert(0usize) += 1;
                }
                let max = counts.values().copied().max().unwrap_or(0);
                max as f64 / seg.len() as f64
            })
            .collect()
    }
}
