//! End-to-end orchestration: Algorithm 1 as one call.
//!
//!   1. train E routers with EM on fresh chunks,
//!   2. shard the expert-training corpus with the trained routers,
//!   3. train E experts independently on their segments,
//!
//! returning the [`Mixture`], the communication ledger, and the full
//! metric log. This is what `smalltalk e2e`, the examples, and the Fig. 2
//! benches drive.
//!
//! Since the async-trainer refactor, [`run_pipeline`] is a thin staged-
//! mode wrapper over [`super::trainer::run_trainer`]: the expert stage
//! runs as trainer nodes on the shared worker pool (gaining checkpoint/
//! resume for free) while producing bit-identical outputs to the classic
//! loop. The classic loop is preserved verbatim as
//! [`run_pipeline_reference`] — the equality oracle
//! `rust/tests/async_train.rs` asserts against.

use anyhow::Result;

use super::comm::CommLedger;
use super::em::{train_routers, EmConfig};
use super::expert::{train_expert, ExpertConfig};
use super::fleet::ElasticSummary;
use super::inference::Mixture;
use super::sharding::shard_corpus;
use super::trainer::{run_trainer, TrainerConfig};
use crate::data::SequenceGen;
use crate::metrics::RunLog;
use crate::runtime::parallel::{resolve_threads, run_fallible};
use crate::runtime::Engine;
use crate::tokenizer::Bpe;

/// Configuration of a full mixture training run.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub router_variant: String,
    pub expert_variant: String,
    pub n_experts: usize,
    /// EM rounds for router training.
    pub em_rounds: usize,
    /// Fresh sequences per EM round.
    pub em_chunk: usize,
    /// Router SGD steps per EM round.
    pub em_steps_per_round: usize,
    /// Sequences in the expert-training corpus (sharded across experts).
    pub shard_sequences: usize,
    /// SGD steps per expert.
    pub expert_steps: usize,
    /// Routing prefix length M (training-time).
    pub prefix_len: usize,
    pub seed: u64,
    /// Worker threads for expert/router group fan-out (0 = auto: the
    /// machine's available parallelism).
    pub threads: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            router_variant: "router_micro".into(),
            expert_variant: "expert_sm".into(),
            n_experts: 4,
            em_rounds: 3,
            em_chunk: 192,
            em_steps_per_round: 16,
            shard_sequences: 512,
            expert_steps: 60,
            prefix_len: 32,
            seed: 1234,
            threads: 0,
        }
    }
}

/// Everything a run produces.
pub struct PipelineResult {
    pub mixture: Mixture,
    pub ledger: CommLedger,
    pub log: RunLog,
    /// Plurality-domain fraction per expert segment (specialization).
    /// In async mode this is computed from what each node actually
    /// trained on rather than from a leader-sharded corpus.
    pub segment_purity: Vec<f64>,
    /// Segment sizes after sharding (async: sequences trained per node).
    pub segment_sizes: Vec<usize>,
    /// Elastic/fleet recovery accounting — `None` for staged and plain
    /// async runs, `Some` whenever the elastic machinery ran (per-shard
    /// rows only in fleet mode).
    pub elastic: Option<ElasticSummary>,
}

/// Run Algorithm 1 end to end (staged orchestration, bit-identical to
/// [`run_pipeline_reference`]).
pub fn run_pipeline(engine: &Engine, bpe: &Bpe, cfg: &PipelineConfig) -> Result<PipelineResult> {
    run_trainer(engine, bpe, cfg, &TrainerConfig::staged())
}

/// The classic barrier pipeline, preserved verbatim as the bit-exact
/// reference for the staged orchestrator (see `rust/tests/async_train.rs`).
/// New callers should use [`run_pipeline`].
pub fn run_pipeline_reference(
    engine: &Engine,
    bpe: &Bpe,
    cfg: &PipelineConfig,
) -> Result<PipelineResult> {
    let mut ledger = CommLedger::default();
    let mut log = RunLog::new();
    let router_meta = engine.variant(&cfg.router_variant)?.clone();
    let expert_meta = engine.variant(&cfg.expert_variant)?.clone();
    anyhow::ensure!(
        router_meta.seq_len == expert_meta.seq_len,
        "router/expert seq_len mismatch"
    );

    // Stage 1: routers (Alg. 1 lines 1-10).
    let em = EmConfig {
        n_routers: cfg.n_experts,
        rounds: cfg.em_rounds,
        chunk_size: cfg.em_chunk,
        steps_per_round: cfg.em_steps_per_round,
        prefix_len: cfg.prefix_len,
        seed: cfg.seed,
        threads: cfg.threads,
    };
    let mut router_gen = SequenceGen::new(bpe, router_meta.seq_len, cfg.seed ^ 0x52_0000);
    let trained = train_routers(
        engine,
        &cfg.router_variant,
        &em,
        &mut router_gen,
        &mut ledger,
        &mut log,
    )?;

    // Stage 2: shard the expert corpus (lines 12-13). The paper's experts
    // train single-epoch on fresh data; make the corpus at least cover
    // every expert's step budget so no sequence repeats.
    let needed = cfg.n_experts * cfg.expert_steps * expert_meta.train_batch;
    let n_shard = cfg.shard_sequences.max(needed);
    let threads = resolve_threads(cfg.threads);
    let mut shard_gen = SequenceGen::new(bpe, expert_meta.seq_len, cfg.seed ^ 0x5AD);
    let shards = shard_corpus(
        engine,
        &trained.routers,
        &trained.meta,
        &mut shard_gen,
        n_shard,
        cfg.prefix_len,
        &mut ledger,
        threads,
    )?;
    let segment_purity = shards.segment_purity();
    let segment_sizes: Vec<usize> = shards.segments.iter().map(Vec::len).collect();

    // Stage 3: independent experts (lines 14-16). Each expert is its own
    // node in the paper's topology — no communication — so the E training
    // runs fan across the worker pool; per-expert trajectories depend
    // only on their own seed and segment, so any worker count produces
    // identical experts.
    let tasks: Vec<_> = shards
        .segments
        .iter()
        .enumerate()
        .map(|(e, segment)| {
            let ecfg = ExpertConfig {
                steps: cfg.expert_steps,
                seed: cfg.seed ^ (0xE0 + e as u64),
                log_every: 10,
            };
            let variant = &cfg.expert_variant;
            move || -> Result<(crate::runtime::TrainState, RunLog)> {
                let mut elog = RunLog::new();
                let state = train_expert(engine, variant, &ecfg, segment, &mut elog)?;
                Ok((state, elog))
            }
        })
        .collect();
    let mut experts = Vec::with_capacity(cfg.n_experts);
    for (e, (state, elog)) in run_fallible(tasks, threads)?.into_iter().enumerate() {
        log.merge_prefixed(&format!("expert{e}"), &elog);
        experts.push(state);
    }

    // Transfer accounting: engine-lifetime totals at pipeline completion,
    // so run records show how much host↔device traffic the device-resident
    // buffer cache saved (uploads_avoided are copies the literal-per-call
    // path would have performed).
    let stats = engine.stats();
    log.scalar("engine/h2d_bytes", 0.0, stats.h2d_bytes as f64);
    log.scalar("engine/d2h_bytes", 0.0, stats.d2h_bytes as f64);
    log.scalar("engine/h2d_bytes_avoided", 0.0, stats.h2d_bytes_avoided as f64);
    log.scalar("engine/uploads_avoided", 0.0, stats.uploads_avoided as f64);
    log.scalar("engine/param_uploads", 0.0, stats.param_uploads as f64);

    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
        elastic: None,
    })
}
