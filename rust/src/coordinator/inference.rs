//! Inference-time routing and serving (§2.2: "During inference, no
//! balancing is performed, and the expert is selected solely based on
//! equation 4").
//!
//! [`Mixture`] bundles E tiny routers + E experts. A request's prefix is
//! scored by every router; the argmin router's expert alone evaluates the
//! sequence. [`serve`] implements the batched request loop: requests are
//! routed, grouped per expert, and executed in expert-batch-sized chunks
//! — the dispatch pattern a vLLM-style front-end would use. The loop is
//! allocation-light: requests are batched by index over borrowed token
//! rows (no `Sequence`/`Vec<u32>` clones), and router/expert parameters
//! stay device-resident across waves via the engine's buffer cache.

use std::time::Instant;

use anyhow::Result;

use super::assignment::argmin_assign;
use super::scoring::{batch_spans, score_matrix, score_matrix_rows};
use crate::data::Sequence;
use crate::runtime::{Engine, TrainState, VariantMeta};

/// A trained mixture: E routers (tiny LMs) + E experts.
pub struct Mixture {
    pub routers: Vec<TrainState>,
    pub router_meta: VariantMeta,
    pub experts: Vec<TrainState>,
    pub expert_meta: VariantMeta,
}

impl Mixture {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Route a batch of sequences: returns the chosen expert per sequence.
    pub fn route(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<Vec<usize>> {
        let nll = score_matrix(engine, &self.routers, &self.router_meta, seqs, m)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// [`Mixture::route`] over borrowed token rows (full sequences; the
    /// first `m` tokens of each row are scored).
    pub fn route_rows(&self, engine: &Engine, rows: &[&[u32]], m: usize) -> Result<Vec<usize>> {
        let prefixes: Vec<&[u32]> = rows.iter().map(|r| &r[..m.min(r.len())]).collect();
        let nll = score_matrix_rows(engine, &self.routers, &self.router_meta, &prefixes, m)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// Per-sequence full NLL under the routed expert, grouped per expert
    /// for batching. Returns (nll, expert) per input sequence.
    pub fn eval_routed(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
    ) -> Result<Vec<(f32, usize)>> {
        let routes = self.route(engine, seqs, m)?;
        let mut out = vec![(0.0f32, 0usize); seqs.len()];
        for e in 0..self.n_experts() {
            let idx: Vec<usize> = (0..seqs.len()).filter(|&i| routes[i] == e).collect();
            if idx.is_empty() {
                continue;
            }
            // batch by index over borrowed rows — no token clones
            let rows: Vec<&[u32]> = idx.iter().map(|&i| seqs[i].tokens.as_slice()).collect();
            let nll = eval_nll_all(engine, &self.experts[e], &self.expert_meta, &rows)?;
            for (k, &i) in idx.iter().enumerate() {
                out[i] = (nll[k], e);
            }
        }
        Ok(out)
    }

    /// Mixture perplexity on a held-out set (routing with prefix `m`).
    pub fn perplexity(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<f64> {
        let per_seq = self.eval_routed(engine, seqs, m)?;
        let total: f64 = per_seq.iter().map(|&(n, _)| n as f64).sum();
        let tokens = seqs.len() * (self.expert_meta.seq_len);
        Ok((total / tokens as f64).exp())
    }
}

/// Evaluate full-sequence NLL for an arbitrary number of rows, padding the
/// tail to the compiled eval batch shape (by reference — padding rows are
/// discarded). Rows may be owned vectors or borrowed slices.
pub fn eval_nll_all<R: AsRef<[u32]>>(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    rows: &[R],
) -> Result<Vec<f32>> {
    let bs = meta.eval_batch;
    let mut out = Vec::with_capacity(rows.len());
    for (start, real) in batch_spans(rows.len(), bs) {
        let mut batch: Vec<&[u32]> = rows[start..start + real]
            .iter()
            .map(AsRef::as_ref)
            .collect();
        let pad = batch[real - 1];
        while batch.len() < bs {
            batch.push(pad);
        }
        let nll = state.eval_nll(engine, &batch, meta)?;
        out.extend_from_slice(&nll[..real]);
    }
    Ok(out)
}

/// Dense-baseline perplexity on the same sequences (comparator).
pub fn dense_perplexity(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    seqs: &[Sequence],
) -> Result<f64> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.tokens.as_slice()).collect();
    let nll = eval_nll_all(engine, state, meta, &rows)?;
    let total: f64 = nll.iter().map(|&n| n as f64).sum();
    Ok((total / (seqs.len() * meta.seq_len) as f64).exp())
}

// ----------------------------------------------------------------------
// Serving loop
// ----------------------------------------------------------------------

/// One inference request: a token sequence to score (seq_len + 1 tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// The server's answer.
///
/// Timing semantics (unified): both latency fields are **mean microseconds
/// per request** over the batch that processed this request. Routing is a
/// single batched score-matrix over the whole wave, so `route_micros` is
/// wave-total / wave-size and identical for every response in a wave;
/// execution is batched per expert group, so `exec_micros` is group-total /
/// group-size and identical within a group. Neither is an isolated
/// single-request latency — that is the batched-serving cost model.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub expert: usize,
    pub nll: f32,
    /// Mean routing microseconds per request (amortized over the wave).
    pub route_micros: u128,
    /// Mean expert-execution microseconds per request (amortized over the
    /// request's expert group).
    pub exec_micros: u128,
}

impl Response {
    /// Amortized end-to-end latency attributed to this request.
    pub fn total_micros(&self) -> u128 {
        self.route_micros + self.exec_micros
    }
}

/// Batched serving: route all queued requests, group by expert, execute.
/// Returns responses in input order plus amortized per-request timings
/// (see [`Response`] for the exact semantics).
pub fn serve(engine: &Engine, mixture: &Mixture, requests: &[Request], m: usize) -> Result<Vec<Response>> {
    // borrow token rows straight out of the requests — no Sequence clones
    let rows: Vec<&[u32]> = requests.iter().map(|r| r.tokens.as_slice()).collect();
    let t0 = Instant::now();
    let routes = mixture.route_rows(engine, &rows, m)?;
    let route_us = t0.elapsed().as_micros() / requests.len().max(1) as u128;

    let mut responses: Vec<Response> = requests
        .iter()
        .zip(&routes)
        .map(|(r, &e)| Response {
            id: r.id,
            expert: e,
            nll: 0.0,
            route_micros: route_us,
            exec_micros: 0,
        })
        .collect();

    for e in 0..mixture.n_experts() {
        let idx: Vec<usize> = (0..requests.len()).filter(|&i| routes[i] == e).collect();
        if idx.is_empty() {
            continue;
        }
        let group: Vec<&[u32]> = idx.iter().map(|&i| rows[i]).collect();
        let t1 = Instant::now();
        let nll = eval_nll_all(engine, &mixture.experts[e], &mixture.expert_meta, &group)?;
        let exec_us = t1.elapsed().as_micros() / idx.len() as u128;
        for (k, &i) in idx.iter().enumerate() {
            responses[i].nll = nll[k];
            responses[i].exec_micros = exec_us;
        }
    }
    Ok(responses)
}
