//! Inference-time routing and serving (§2.2: "During inference, no
//! balancing is performed, and the expert is selected solely based on
//! equation 4").
//!
//! [`Mixture`] bundles E tiny routers + E experts. A request's prefix is
//! scored by every router; the argmin router's expert alone evaluates the
//! sequence. [`serve`] implements the batched request loop: requests are
//! routed, grouped per expert, and executed in expert-batch-sized chunks
//! — the dispatch pattern a vLLM-style front-end would use.

use std::time::Instant;

use anyhow::Result;

use super::assignment::argmin_assign;
use super::scoring::score_matrix;
use crate::data::Sequence;
use crate::runtime::{Engine, TrainState, VariantMeta};

/// A trained mixture: E routers (tiny LMs) + E experts.
pub struct Mixture {
    pub routers: Vec<TrainState>,
    pub router_meta: VariantMeta,
    pub experts: Vec<TrainState>,
    pub expert_meta: VariantMeta,
}

impl Mixture {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Route a batch of sequences: returns the chosen expert per sequence.
    pub fn route(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<Vec<usize>> {
        let nll = score_matrix(engine, &self.routers, &self.router_meta, seqs, m)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// Per-sequence full NLL under the routed expert, grouped per expert
    /// for batching. Returns (nll, expert) per input sequence.
    pub fn eval_routed(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
    ) -> Result<Vec<(f32, usize)>> {
        let routes = self.route(engine, seqs, m)?;
        let mut out = vec![(0.0f32, 0usize); seqs.len()];
        for e in 0..self.n_experts() {
            let idx: Vec<usize> = (0..seqs.len()).filter(|&i| routes[i] == e).collect();
            if idx.is_empty() {
                continue;
            }
            let nll = eval_nll_all(
                engine,
                &self.experts[e],
                &self.expert_meta,
                &idx.iter().map(|&i| seqs[i].tokens.clone()).collect::<Vec<_>>(),
            )?;
            for (k, &i) in idx.iter().enumerate() {
                out[i] = (nll[k], e);
            }
        }
        Ok(out)
    }

    /// Mixture perplexity on a held-out set (routing with prefix `m`).
    pub fn perplexity(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<f64> {
        let per_seq = self.eval_routed(engine, seqs, m)?;
        let total: f64 = per_seq.iter().map(|&(n, _)| n as f64).sum();
        let tokens = seqs.len() * (self.expert_meta.seq_len);
        Ok((total / tokens as f64).exp())
    }
}

/// Evaluate full-sequence NLL for an arbitrary number of rows, padding the
/// tail to the compiled eval batch shape.
pub fn eval_nll_all(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    rows: &[Vec<u32>],
) -> Result<Vec<f32>> {
    let bs = meta.eval_batch;
    let mut out = Vec::with_capacity(rows.len());
    let mut i = 0;
    while i < rows.len() {
        let real = (rows.len() - i).min(bs);
        let mut batch: Vec<Vec<u32>> = rows[i..i + real].to_vec();
        while batch.len() < bs {
            batch.push(batch[real - 1].clone());
        }
        let nll = state.eval_nll(engine, &batch, meta)?;
        out.extend_from_slice(&nll[..real]);
        i += real;
    }
    Ok(out)
}

/// Dense-baseline perplexity on the same sequences (comparator).
pub fn dense_perplexity(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    seqs: &[Sequence],
) -> Result<f64> {
    let rows: Vec<Vec<u32>> = seqs.iter().map(|s| s.tokens.clone()).collect();
    let nll = eval_nll_all(engine, state, meta, &rows)?;
    let total: f64 = nll.iter().map(|&n| n as f64).sum();
    Ok((total / (seqs.len() * meta.seq_len) as f64).exp())
}

// ----------------------------------------------------------------------
// Serving loop
// ----------------------------------------------------------------------

/// One inference request: a token sequence to score (seq_len + 1 tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub expert: usize,
    pub nll: f32,
    pub route_micros: u128,
    pub exec_micros: u128,
}

/// Batched serving: route all queued requests, group by expert, execute.
/// Returns responses in input order plus aggregate wall time.
pub fn serve(engine: &Engine, mixture: &Mixture, requests: &[Request], m: usize) -> Result<Vec<Response>> {
    let seqs: Vec<Sequence> = requests
        .iter()
        .map(|r| Sequence {
            tokens: r.tokens.clone(),
            domain: usize::MAX,
        })
        .collect();
    let t0 = Instant::now();
    let routes = mixture.route(engine, &seqs, m)?;
    let route_us = t0.elapsed().as_micros() / requests.len().max(1) as u128;

    let mut responses: Vec<Response> = requests
        .iter()
        .zip(&routes)
        .map(|(r, &e)| Response {
            id: r.id,
            expert: e,
            nll: 0.0,
            route_micros: route_us,
            exec_micros: 0,
        })
        .collect();

    for e in 0..mixture.n_experts() {
        let idx: Vec<usize> = (0..requests.len()).filter(|&i| routes[i] == e).collect();
        if idx.is_empty() {
            continue;
        }
        let t1 = Instant::now();
        let nll = eval_nll_all(
            engine,
            &mixture.experts[e],
            &mixture.expert_meta,
            &idx.iter()
                .map(|&i| requests[i].tokens.clone())
                .collect::<Vec<_>>(),
        )?;
        let exec_us = t1.elapsed().as_micros() / idx.len() as u128;
        for (k, &i) in idx.iter().enumerate() {
            responses[i].nll = nll[k];
            responses[i].exec_micros = exec_us;
        }
    }
    Ok(responses)
}
