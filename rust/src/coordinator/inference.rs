//! Inference-time routing and serving (§2.2: "During inference, no
//! balancing is performed, and the expert is selected solely based on
//! equation 4").
//!
//! [`Mixture`] bundles E tiny routers + E experts. A request's prefix is
//! scored by every router; the argmin router's expert alone evaluates the
//! sequence. [`serve`] implements the batched request loop: requests are
//! routed, grouped per expert, and executed in expert-batch-sized chunks
//! — the dispatch pattern a vLLM-style front-end would use. The loop is
//! allocation-light: requests are batched by index over borrowed token
//! rows (no `Sequence`/`Vec<u32>` clones), and router/expert parameters
//! stay device-resident across waves via the engine's buffer cache.
//!
//! Expert groups never talk to each other (the paper's core property), so
//! [`serve_threaded`] / [`Mixture::eval_routed_threaded`] execute them
//! concurrently on a scoped worker pool; each group writes a disjoint set
//! of response slots, so the parallel output is bit-identical to the
//! sequential one at any worker count.

use std::time::Instant;

use anyhow::Result;

use super::assignment::argmin_assign;
use super::scoring::{
    batch_spans, pad_batch, score_matrix_rows_threaded, score_matrix_threaded,
};
use crate::data::Sequence;
use crate::runtime::parallel::{default_threads, run_fallible};
use crate::runtime::{Engine, TrainState, VariantMeta};

/// A trained mixture: E routers (tiny LMs) + E experts.
pub struct Mixture {
    pub routers: Vec<TrainState>,
    pub router_meta: VariantMeta,
    pub experts: Vec<TrainState>,
    pub expert_meta: VariantMeta,
}

impl Mixture {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Route a batch of sequences: returns the chosen expert per sequence.
    /// Router scoring fans across [`default_threads`] workers.
    pub fn route(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<Vec<usize>> {
        self.route_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::route`] with an explicit worker count for the router
    /// fan-out (`threads <= 1` scores sequentially).
    pub fn route_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        let nll = score_matrix_threaded(engine, &self.routers, &self.router_meta, seqs, m, threads)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// [`Mixture::route`] over borrowed token rows (full sequences; the
    /// first `m` tokens of each row are scored; rows shorter than `m` are
    /// scored as padded prefixes — see
    /// [`score_matrix_rows`](super::scoring::score_matrix_rows)).
    pub fn route_rows(&self, engine: &Engine, rows: &[&[u32]], m: usize) -> Result<Vec<usize>> {
        self.route_rows_threaded(engine, rows, m, default_threads())
    }

    /// [`Mixture::route_rows`] with an explicit worker count for the
    /// router fan-out.
    pub fn route_rows_threaded(
        &self,
        engine: &Engine,
        rows: &[&[u32]],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        // truncate to the prefix by slicing (not via the scorer's padded
        // copies): full-length rows would otherwise each pay an owned
        // m-token copy in pad_prefix_row; a slice is free
        let prefixes: Vec<&[u32]> = rows.iter().map(|r| &r[..m.min(r.len())]).collect();
        let nll = score_matrix_rows_threaded(
            engine,
            &self.routers,
            &self.router_meta,
            &prefixes,
            m,
            threads,
        )?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// Per-sequence full NLL under the routed expert, grouped per expert
    /// for batching. Returns (nll, expert) per input sequence. Expert
    /// groups run on [`default_threads`] workers.
    pub fn eval_routed(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
    ) -> Result<Vec<(f32, usize)>> {
        self.eval_routed_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::eval_routed`] with an explicit worker count covering
    /// the whole wave (router scoring *and* the expert-group fan-out —
    /// `threads = 1` is fully sequential). Bit-identical at any count.
    pub fn eval_routed_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<(f32, usize)>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let routes = self.route_threaded(engine, seqs, m, threads)?;
        let groups: Vec<Vec<usize>> = group_by_expert(&routes, self.n_experts());
        // batch by index over borrowed rows — no token clones; every
        // non-empty group is one independent task
        let tasks: Vec<_> = groups
            .iter()
            .enumerate()
            .filter(|(_, idx)| !idx.is_empty())
            .map(|(e, idx)| {
                let expert = &self.experts[e];
                let meta = &self.expert_meta;
                move || {
                    let rows: Vec<&[u32]> =
                        idx.iter().map(|&i| seqs[i].tokens.as_slice()).collect();
                    let nll = eval_nll_all(engine, expert, meta, &rows)?;
                    Ok((e, nll))
                }
            })
            .collect();
        let mut out = vec![(0.0f32, 0usize); seqs.len()];
        for (e, nll) in run_fallible(tasks, threads)? {
            for (k, &i) in groups[e].iter().enumerate() {
                out[i] = (nll[k], e);
            }
        }
        Ok(out)
    }

    /// Mixture perplexity on a held-out set (routing with prefix `m`).
    /// Routing and expert groups fan across [`default_threads`] workers.
    pub fn perplexity(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<f64> {
        self.perplexity_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::perplexity`] with an explicit worker count for the
    /// whole wave (`threads <= 1` is fully sequential).
    pub fn perplexity_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<f64> {
        let per_seq = self.eval_routed_threaded(engine, seqs, m, threads)?;
        let total: f64 = per_seq.iter().map(|&(n, _)| n as f64).sum();
        let tokens = seqs.len() * (self.expert_meta.seq_len);
        Ok((total / tokens as f64).exp())
    }
}

/// Evaluate full-sequence NLL for an arbitrary number of rows, padding the
/// tail to the compiled eval batch shape (by reference — padding rows are
/// discarded). Rows may be owned vectors or borrowed slices.
pub fn eval_nll_all<R: AsRef<[u32]>>(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    rows: &[R],
) -> Result<Vec<f32>> {
    let bs = meta.eval_batch;
    let mut out = Vec::with_capacity(rows.len());
    for (start, real) in batch_spans(rows.len(), bs) {
        let batch = pad_batch(
            rows[start..start + real].iter().map(AsRef::as_ref).collect(),
            bs,
        );
        let nll = state.eval_nll(engine, &batch, meta)?;
        out.extend_from_slice(&nll[..real]);
    }
    Ok(out)
}

/// Group sequence indices by their routed expert: `groups[e]` holds the
/// input indices assigned to expert `e`, in input order.
fn group_by_expert(routes: &[usize], n_experts: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_experts];
    for (i, &e) in routes.iter().enumerate() {
        groups[e].push(i);
    }
    groups
}

/// Dense-baseline perplexity on the same sequences (comparator).
pub fn dense_perplexity(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    seqs: &[Sequence],
) -> Result<f64> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.tokens.as_slice()).collect();
    let nll = eval_nll_all(engine, state, meta, &rows)?;
    let total: f64 = nll.iter().map(|&n| n as f64).sum();
    Ok((total / (seqs.len() * meta.seq_len) as f64).exp())
}

// ----------------------------------------------------------------------
// Serving loop
// ----------------------------------------------------------------------

/// One inference request: a token sequence to score (seq_len + 1 tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// The server's answer.
///
/// Timing semantics (unified): both latency fields are **mean microseconds
/// per request** over the batch that processed this request. Routing is a
/// single batched score-matrix over the whole wave, so `route_micros` is
/// wave-total / wave-size and identical for every response in a wave;
/// execution is batched per expert group, so `exec_micros` is group-total /
/// group-size and identical within a group. Neither is an isolated
/// single-request latency — that is the batched-serving cost model.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub expert: usize,
    pub nll: f32,
    /// Mean routing microseconds per request (amortized over the wave).
    pub route_micros: u128,
    /// Mean expert-execution microseconds per request (amortized over the
    /// request's expert group).
    pub exec_micros: u128,
}

impl Response {
    /// Amortized end-to-end latency attributed to this request.
    pub fn total_micros(&self) -> u128 {
        self.route_micros + self.exec_micros
    }
}

/// Batched serving: route all queued requests, group by expert, execute.
/// Returns responses in input order plus amortized per-request timings
/// (see [`Response`] for the exact semantics). Expert groups execute on
/// [`default_threads`] workers; see [`serve_threaded`].
pub fn serve(engine: &Engine, mixture: &Mixture, requests: &[Request], m: usize) -> Result<Vec<Response>> {
    serve_threaded(engine, mixture, requests, m, default_threads())
}

/// [`serve`] with an explicit worker count covering the whole wave:
/// router scoring and the expert-group fan-out both run on `threads`
/// workers, so `threads = 1` is the fully sequential reference path.
///
/// Groups are independent (no expert ever sees another's requests), so
/// they run concurrently; each writes a disjoint slice of the response
/// vector, keeping the output — ids, experts, NLLs, input order —
/// bit-identical to the sequential `threads = 1` path. Only the timing
/// fields vary run-to-run (they are wall-clock measurements).
pub fn serve_threaded(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
    threads: usize,
) -> Result<Vec<Response>> {
    if requests.is_empty() {
        // nothing to route: never build a zero-row batch
        return Ok(Vec::new());
    }
    // borrow token rows straight out of the requests — no Sequence clones
    let rows: Vec<&[u32]> = requests.iter().map(|r| r.tokens.as_slice()).collect();
    let t0 = Instant::now();
    let routes = mixture.route_rows_threaded(engine, &rows, m, threads)?;
    let route_us = t0.elapsed().as_micros() / requests.len() as u128;

    let mut responses: Vec<Response> = requests
        .iter()
        .zip(&routes)
        .map(|(r, &e)| Response {
            id: r.id,
            expert: e,
            nll: 0.0,
            route_micros: route_us,
            exec_micros: 0,
        })
        .collect();

    let groups = group_by_expert(&routes, mixture.n_experts());
    let tasks: Vec<_> = groups
        .iter()
        .enumerate()
        .filter(|(_, idx)| !idx.is_empty())
        .map(|(e, idx)| {
            let expert = &mixture.experts[e];
            let meta = &mixture.expert_meta;
            let rows = &rows;
            move || {
                let group: Vec<&[u32]> = idx.iter().map(|&i| rows[i]).collect();
                let t1 = Instant::now();
                let nll = eval_nll_all(engine, expert, meta, &group)?;
                let exec_us = t1.elapsed().as_micros() / idx.len() as u128;
                Ok((e, nll, exec_us))
            }
        })
        .collect();
    for (e, nll, exec_us) in run_fallible(tasks, threads)? {
        for (k, &i) in groups[e].iter().enumerate() {
            responses[i].nll = nll[k];
            responses[i].exec_micros = exec_us;
        }
    }
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_micros_sums_route_and_exec() {
        let r = Response {
            id: 9,
            expert: 2,
            nll: 1.5,
            route_micros: 120,
            exec_micros: 880,
        };
        assert_eq!(r.total_micros(), 1000);
        let zero = Response {
            id: 0,
            expert: 0,
            nll: 0.0,
            route_micros: 0,
            exec_micros: 0,
        };
        assert_eq!(zero.total_micros(), 0);
    }

    #[test]
    fn group_by_expert_partitions_in_input_order() {
        let groups = group_by_expert(&[1, 0, 1, 2, 0], 4);
        assert_eq!(groups, vec![vec![1, 4], vec![0, 2], vec![3], vec![]]);
        // every index appears exactly once
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }
}
