//! Inference-time routing and serving (§2.2: "During inference, no
//! balancing is performed, and the expert is selected solely based on
//! equation 4").
//!
//! [`Mixture`] bundles E tiny routers + E experts. A request's prefix is
//! scored by every router; the argmin router's expert alone evaluates the
//! sequence. [`serve`] implements the batched request loop: requests are
//! routed, grouped per expert, and executed in expert-batch-sized chunks
//! — the dispatch pattern a vLLM-style front-end would use. The loop is
//! allocation-light: the sequential reference path batches by index over
//! borrowed token rows (no `Sequence`/`Vec<u32>` clones), and
//! router/expert parameters stay device-resident across waves via the
//! engine's buffer cache. The `threads > 1` path hands the scheduler one
//! owned copy of the wave (the queue outlives the caller's borrow); that
//! single memcpy is noise next to the batched model execution it feeds.
//!
//! Expert groups never talk to each other (the paper's core property), so
//! [`serve_threaded`] / [`Mixture::eval_routed_threaded`] execute them
//! concurrently on a scoped worker pool; each group writes a disjoint set
//! of response slots, so the parallel output is bit-identical to the
//! sequential one at any worker count.
//!
//! Closed waves are now the degenerate case of the continuous-batching
//! scheduler in [`super::server`]: [`serve_threaded`] with `threads > 1`
//! is a thin wrapper that submits the whole request slice as one atomic
//! wave ([`crate::coordinator::server::ServerConfig::closed_wave`]),
//! while `threads = 1` keeps the direct sequential loop as the bit-exact
//! reference path the determinism suites compare against.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::assignment::argmin_assign;
use super::scoring::{
    batch_spans, pad_batch, score_matrix_rows_threaded, score_matrix_threaded,
};
use crate::data::Sequence;
use crate::runtime::parallel::{default_threads, run_fallible};
use crate::runtime::{Engine, TrainState, VariantMeta};

/// A trained mixture: E routers (tiny LMs) + E experts.
pub struct Mixture {
    pub routers: Vec<TrainState>,
    pub router_meta: VariantMeta,
    pub experts: Vec<TrainState>,
    pub expert_meta: VariantMeta,
}

impl Mixture {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Route a batch of sequences: returns the chosen expert per sequence.
    /// Router scoring fans across [`default_threads`] workers.
    pub fn route(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<Vec<usize>> {
        self.route_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::route`] with an explicit worker count for the router
    /// fan-out (`threads <= 1` scores sequentially).
    pub fn route_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        let nll = score_matrix_threaded(engine, &self.routers, &self.router_meta, seqs, m, threads)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// [`Mixture::route`] over borrowed token rows (full sequences; the
    /// first `m` tokens of each row are scored; rows shorter than `m` are
    /// scored as padded prefixes — see
    /// [`score_matrix_rows`](super::scoring::score_matrix_rows)).
    pub fn route_rows(&self, engine: &Engine, rows: &[&[u32]], m: usize) -> Result<Vec<usize>> {
        self.route_rows_threaded(engine, rows, m, default_threads())
    }

    /// [`Mixture::route_rows`] with an explicit worker count for the
    /// router fan-out.
    pub fn route_rows_threaded(
        &self,
        engine: &Engine,
        rows: &[&[u32]],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        // truncate to the prefix by slicing (not via the scorer's padded
        // copies): full-length rows would otherwise each pay an owned
        // m-token copy in pad_prefix_row; a slice is free
        let prefixes: Vec<&[u32]> = rows.iter().map(|r| &r[..m.min(r.len())]).collect();
        let nll = score_matrix_rows_threaded(
            engine,
            &self.routers,
            &self.router_meta,
            &prefixes,
            m,
            threads,
        )?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// Per-sequence full NLL under the routed expert, grouped per expert
    /// for batching. Returns (nll, expert) per input sequence. Expert
    /// groups run on [`default_threads`] workers.
    pub fn eval_routed(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
    ) -> Result<Vec<(f32, usize)>> {
        self.eval_routed_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::eval_routed`] with an explicit worker count covering
    /// the whole wave (router scoring *and* the expert-group fan-out —
    /// `threads = 1` is fully sequential). Bit-identical at any count.
    pub fn eval_routed_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<(f32, usize)>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let routes = self.route_threaded(engine, seqs, m, threads)?;
        let groups: Vec<Vec<usize>> = group_by_expert(&routes, self.n_experts())?;
        // batch by index over borrowed rows — no token clones; every
        // non-empty group is one independent task
        let tasks: Vec<_> = groups
            .iter()
            .enumerate()
            .filter(|(_, idx)| !idx.is_empty())
            .map(|(e, idx)| {
                let expert = &self.experts[e];
                let meta = &self.expert_meta;
                move || {
                    let rows: Vec<&[u32]> =
                        idx.iter().map(|&i| seqs[i].tokens.as_slice()).collect();
                    let nll = eval_nll_all(engine, expert, meta, &rows)?;
                    Ok((e, nll))
                }
            })
            .collect();
        let mut out = vec![(0.0f32, 0usize); seqs.len()];
        for (e, nll) in run_fallible(tasks, threads)? {
            for (k, &i) in groups[e].iter().enumerate() {
                out[i] = (nll[k], e);
            }
        }
        Ok(out)
    }

    /// Mixture perplexity on a held-out set (routing with prefix `m`).
    /// Routing and expert groups fan across [`default_threads`] workers.
    pub fn perplexity(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<f64> {
        self.perplexity_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::perplexity`] with an explicit worker count for the
    /// whole wave (`threads <= 1` is fully sequential).
    pub fn perplexity_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<f64> {
        let per_seq = self.eval_routed_threaded(engine, seqs, m, threads)?;
        let total: f64 = per_seq.iter().map(|&(n, _)| n as f64).sum();
        let tokens = seqs.len() * (self.expert_meta.seq_len);
        Ok((total / tokens as f64).exp())
    }
}

/// Evaluate full-sequence NLL for an arbitrary number of rows, padding the
/// tail to the compiled eval batch shape (by reference — padding rows are
/// discarded). Rows may be owned vectors or borrowed slices.
pub fn eval_nll_all<R: AsRef<[u32]>>(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    rows: &[R],
) -> Result<Vec<f32>> {
    let bs = meta.eval_batch;
    let mut out = Vec::with_capacity(rows.len());
    for (start, real) in batch_spans(rows.len(), bs) {
        let batch = pad_batch(
            rows[start..start + real].iter().map(AsRef::as_ref).collect(),
            bs,
        );
        let nll = state.eval_nll(engine, &batch, meta)?;
        out.extend_from_slice(&nll[..real]);
    }
    Ok(out)
}

/// Group sequence indices by their routed expert: `groups[e]` holds the
/// input indices assigned to expert `e`, in input order.
///
/// A route index `>= n_experts` (a corrupt checkpoint, a buggy backend)
/// is a structured error, not a slice-index panic.
pub fn group_by_expert(routes: &[usize], n_experts: usize) -> Result<Vec<Vec<usize>>> {
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_experts];
    for (i, &e) in routes.iter().enumerate() {
        if e >= n_experts {
            bail!("route index {e} out of range for {n_experts} experts (sequence position {i})");
        }
        groups[e].push(i);
    }
    Ok(groups)
}

/// Mean microseconds per request, rounded half-up from the total's
/// nanosecond count — the shared amortization rule for every batched
/// timing field (the old `total_micros / n` integer division silently
/// dropped up to a microsecond per request). Returns 0 for an empty
/// batch.
pub fn amortized_micros(total: Duration, n: usize) -> u128 {
    if n == 0 {
        return 0;
    }
    let denom = n as u128 * 1000;
    (total.as_nanos() + denom / 2) / denom
}

/// Dense-baseline perplexity on the same sequences (comparator).
pub fn dense_perplexity(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    seqs: &[Sequence],
) -> Result<f64> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.tokens.as_slice()).collect();
    let nll = eval_nll_all(engine, state, meta, &rows)?;
    let total: f64 = nll.iter().map(|&n| n as f64).sum();
    Ok((total / (seqs.len() * meta.seq_len) as f64).exp())
}

// ----------------------------------------------------------------------
// Serving loop
// ----------------------------------------------------------------------

/// One inference request: a token sequence to score (seq_len + 1 tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// The server's answer.
///
/// Timing semantics (unified): `route_micros` and `exec_micros` are
/// **mean microseconds per request** over the batch that processed this
/// request, rounded half-up ([`amortized_micros`]). Routing is a batched
/// score-matrix per **admission wave** (the whole wave in closed-wave
/// serving), so `route_micros` is wave-total / wave-size and identical
/// for every response admitted together; execution is batched per
/// **dispatched expert batch** (the whole expert group in closed-wave
/// serving), so `exec_micros` is batch-total / batch-size and identical
/// within a batch. Neither is an isolated single-request latency — that
/// is the batched-serving cost model.
///
/// `queue_micros` is different: it is this request's **true** queueing
/// delay — the arrival-queue wait (submission → admission) plus the
/// pending/linger and dispatch-queue wait (routing done → batch execution
/// start). The routing span between those two windows is deliberately
/// excluded: `route_micros` accounts for it, so [`Response::total_micros`]
/// sums three disjoint components. The sequential closed-wave reference
/// path has no queue and reports 0.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub expert: usize,
    pub nll: f32,
    /// True per-request queueing delay (arrival-queue + pending +
    /// dispatch-queue wait, routing excluded; 0 on the sequential
    /// closed-wave path).
    pub queue_micros: u128,
    /// Mean routing microseconds per request (amortized over the
    /// admission wave).
    pub route_micros: u128,
    /// Mean expert-execution microseconds per request (amortized over the
    /// request's dispatched batch).
    pub exec_micros: u128,
}

impl Response {
    /// End-to-end latency attributed to this request: queueing delay plus
    /// the amortized routing and execution shares.
    pub fn total_micros(&self) -> u128 {
        self.queue_micros + self.route_micros + self.exec_micros
    }
}

/// The determinism key of a response set: sorted `(id, expert, NLL bits)`
/// triples. Two serving paths answered the same requests identically iff
/// their keys are equal — the comparison every determinism guard (the
/// serve benches, `rust/tests/server.rs`, `smalltalk serve`) performs.
pub fn response_triples(responses: &[Response]) -> Vec<(u64, usize, u32)> {
    let mut t: Vec<(u64, usize, u32)> = responses
        .iter()
        .map(|r| (r.id, r.expert, r.nll.to_bits()))
        .collect();
    t.sort_unstable();
    t
}

/// Batched serving: route all queued requests, group by expert, execute.
/// Returns responses in input order plus amortized per-request timings
/// (see [`Response`] for the exact semantics). Expert groups execute on
/// [`default_threads`] workers; see [`serve_threaded`].
pub fn serve(engine: &Engine, mixture: &Mixture, requests: &[Request], m: usize) -> Result<Vec<Response>> {
    serve_threaded(engine, mixture, requests, m, default_threads())
}

/// [`serve`] with an explicit worker count covering the whole wave:
/// router scoring and the expert-group fan-out both run on `threads`
/// workers, so `threads = 1` is the fully sequential reference path.
///
/// `threads = 1` runs the classic closed-wave loop inline — no threads
/// spawned, groups executed in expert order: the bit-exact reference.
/// `threads > 1` submits the slice as one atomic wave to the
/// continuous-batching scheduler in [`super::server`] under its
/// closed-wave configuration (one admission wave, each expert group
/// dispatched whole at drain), so both paths score and batch identically.
/// The wrapper clones the request slice once to hand the queue an owned
/// wave — the only allocation difference from the sequential path.
/// Either way the output — ids, experts, NLLs, input order — is
/// bit-identical across worker counts; only the timing fields vary
/// run-to-run (they are wall-clock measurements).
pub fn serve_threaded(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
    threads: usize,
) -> Result<Vec<Response>> {
    if requests.is_empty() {
        // nothing to route: never build a zero-row batch
        return Ok(Vec::new());
    }
    if threads <= 1 {
        return serve_closed_wave(engine, mixture, requests, m);
    }
    let backend = super::server::MixtureBackend {
        engine,
        mixture,
        prefix_len: m,
    };
    let cfg = super::server::ServerConfig::closed_wave(threads);
    let (responses, _stats, ()) = super::server::run_server(&backend, &cfg, |client| {
        client.submit_wave(requests.to_vec());
    })?;
    Ok(responses)
}

/// The sequential closed-wave loop: route everything in one score-matrix
/// wave, execute each expert group in expert order on the caller's
/// thread. This is the reference implementation every scheduled path is
/// measured against.
fn serve_closed_wave(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
) -> Result<Vec<Response>> {
    // borrow token rows straight out of the requests — no Sequence clones
    let rows: Vec<&[u32]> = requests.iter().map(|r| r.tokens.as_slice()).collect();
    let t0 = Instant::now();
    let routes = mixture.route_rows_threaded(engine, &rows, m, 1)?;
    let route_us = amortized_micros(t0.elapsed(), requests.len());

    let mut responses: Vec<Response> = requests
        .iter()
        .zip(&routes)
        .map(|(r, &e)| Response {
            id: r.id,
            expert: e,
            nll: 0.0,
            queue_micros: 0,
            route_micros: route_us,
            exec_micros: 0,
        })
        .collect();

    let groups = group_by_expert(&routes, mixture.n_experts())?;
    for (e, idx) in groups.iter().enumerate().filter(|(_, idx)| !idx.is_empty()) {
        let group: Vec<&[u32]> = idx.iter().map(|&i| rows[i]).collect();
        let t1 = Instant::now();
        let nll = eval_nll_all(engine, &mixture.experts[e], &mixture.expert_meta, &group)?;
        let exec_us = amortized_micros(t1.elapsed(), idx.len());
        for (k, &i) in idx.iter().enumerate() {
            responses[i].nll = nll[k];
            responses[i].exec_micros = exec_us;
        }
    }
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_micros_sums_queue_route_and_exec() {
        let r = Response {
            id: 9,
            expert: 2,
            nll: 1.5,
            queue_micros: 40,
            route_micros: 120,
            exec_micros: 840,
        };
        assert_eq!(r.total_micros(), 1000);
        let zero = Response {
            id: 0,
            expert: 0,
            nll: 0.0,
            queue_micros: 0,
            route_micros: 0,
            exec_micros: 0,
        };
        assert_eq!(zero.total_micros(), 0);
    }

    #[test]
    fn group_by_expert_partitions_in_input_order() {
        let groups = group_by_expert(&[1, 0, 1, 2, 0], 4).unwrap();
        assert_eq!(groups, vec![vec![1, 4], vec![0, 2], vec![3], vec![]]);
        // every index appears exactly once
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn group_by_expert_rejects_out_of_range_routes() {
        // boundary: n_experts itself is already out of range
        let err = group_by_expert(&[0, 3, 1], 3).unwrap_err().to_string();
        assert!(err.contains("route index 3"), "{err}");
        assert!(err.contains("3 experts"), "{err}");
        assert!(err.contains("position 1"), "{err}");
        assert!(group_by_expert(&[9], 0).is_err());
        // in-range max is fine
        assert!(group_by_expert(&[2], 3).is_ok());
    }

    #[test]
    fn amortized_micros_rounds_half_up() {
        // exact division: unchanged
        assert_eq!(amortized_micros(Duration::from_micros(100), 4), 25);
        // 1.5 µs/request rounds up (integer division would truncate to 1)
        assert_eq!(amortized_micros(Duration::from_nanos(3000), 2), 2);
        // just below the half-way point rounds down
        assert_eq!(amortized_micros(Duration::from_nanos(2999), 2), 1);
        // sub-microsecond totals no longer vanish: 0.6 µs/request -> 1
        assert_eq!(amortized_micros(Duration::from_nanos(600), 1), 1);
        assert_eq!(amortized_micros(Duration::from_nanos(499), 1), 0);
        // 10 µs over 3 requests = 3.33 -> 3
        assert_eq!(amortized_micros(Duration::from_micros(10), 3), 3);
        // empty batch is defined, not a division by zero
        assert_eq!(amortized_micros(Duration::from_micros(10), 0), 0);
    }
}
