//! Inference-time routing and serving (§2.2: "During inference, no
//! balancing is performed, and the expert is selected solely based on
//! equation 4").
//!
//! [`Mixture`] bundles E tiny routers + E experts. A request's prefix is
//! scored by every router; the argmin router's expert alone evaluates the
//! sequence. [`serve`] implements the batched request loop: requests are
//! routed, grouped per expert, and executed in expert-batch-sized chunks
//! — the dispatch pattern a vLLM-style front-end would use. The loop is
//! allocation-free on the hot path: every group-evaluation path batches
//! by index over borrowed `&[u32]` token rows end to end (no
//! `Sequence`/`Vec<u32>` clones; tail padding repeats the last row by
//! reference), and router/expert parameters stay device-resident across
//! waves via the engine's buffer cache. The `threads > 1` path hands the
//! scheduler one owned copy of the wave (the queue outlives the caller's
//! borrow); that single memcpy is noise next to the batched model
//! execution it feeds.
//!
//! Launch discipline: when the manifest carries fused `eval_nll_all_{b}`
//! entries ([`VariantMeta::fused_eval_buckets`], from `aot.py --fused`),
//! the wave's per-expert batches are evaluated through the bucket-ladder
//! planner ([`plan_wave`]) and [`eval_nll_groups`]: each batch pads up to
//! the smallest compiled bucket that fits, equal-bucket batches stack
//! across experts into one `eval_nll_all_{b}` execution (the stacked
//! `[E, P]` parameter tensor reuses the engine's versioned stack cache),
//! and dead rows/columns are discarded on readback — so an E-expert wave
//! drops from E expert launches + E readbacks to one or two bucketed
//! launches. Manifests without the entries (or a single-unit slab, where
//! stacking would multiply FLOPs for nothing) keep the per-expert
//! `eval_nll` fan-out, bit-identical.
//!
//! Expert groups never talk to each other (the paper's core property), so
//! [`serve_threaded`] / [`Mixture::eval_routed_threaded`] execute them
//! concurrently on a scoped worker pool; each group writes a disjoint set
//! of response slots, so the parallel output is bit-identical to the
//! sequential one at any worker count.
//!
//! Closed waves are now the degenerate case of the continuous-batching
//! scheduler in [`super::server`]: [`serve_threaded`] with `threads > 1`
//! is a thin wrapper that submits the whole request slice as one atomic
//! wave ([`crate::coordinator::server::ServerConfig::closed_wave`]),
//! while `threads = 1` keeps the direct sequential loop as the bit-exact
//! reference path the determinism suites compare against.

use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::assignment::argmin_assign;
use super::scoring::{
    batch_spans, pad_batch, score_matrix_rows_threaded, score_matrix_threaded, SPAN_WINDOW,
};
use crate::data::Sequence;
use crate::runtime::engine::{to_f32_vec, tokens_literal, Arg};
use crate::runtime::parallel::{default_threads, run_fallible};
use crate::runtime::{stacked_params_buffer, DeviceBuffer, Engine, TrainState, VariantMeta};

/// A trained mixture: E routers (tiny LMs) + E experts.
pub struct Mixture {
    pub routers: Vec<TrainState>,
    pub router_meta: VariantMeta,
    pub experts: Vec<TrainState>,
    pub expert_meta: VariantMeta,
}

impl Mixture {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Route a batch of sequences: returns the chosen expert per sequence.
    /// Router scoring fans across [`default_threads`] workers.
    pub fn route(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<Vec<usize>> {
        self.route_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::route`] with an explicit worker count for the router
    /// fan-out (`threads <= 1` scores sequentially).
    pub fn route_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        let nll = score_matrix_threaded(engine, &self.routers, &self.router_meta, seqs, m, threads)?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// [`Mixture::route`] over borrowed token rows (full sequences; the
    /// first `m` tokens of each row are scored; rows shorter than `m` are
    /// scored as padded prefixes — see
    /// [`score_matrix_rows`](super::scoring::score_matrix_rows)).
    pub fn route_rows(&self, engine: &Engine, rows: &[&[u32]], m: usize) -> Result<Vec<usize>> {
        self.route_rows_threaded(engine, rows, m, default_threads())
    }

    /// [`Mixture::route_rows`] with an explicit worker count for the
    /// router fan-out.
    pub fn route_rows_threaded(
        &self,
        engine: &Engine,
        rows: &[&[u32]],
        m: usize,
        threads: usize,
    ) -> Result<Vec<usize>> {
        // truncate to the prefix by slicing (not via the scorer's padded
        // copies): full-length rows would otherwise each pay an owned
        // m-token copy in pad_prefix_row; a slice is free
        let prefixes: Vec<&[u32]> = rows.iter().map(|r| &r[..m.min(r.len())]).collect();
        let nll = score_matrix_rows_threaded(
            engine,
            &self.routers,
            &self.router_meta,
            &prefixes,
            m,
            threads,
        )?;
        Ok(argmin_assign(&nll).expert_of)
    }

    /// Per-sequence full NLL under the routed expert, grouped per expert
    /// for batching. Returns (nll, expert) per input sequence. Expert
    /// groups run on [`default_threads`] workers.
    pub fn eval_routed(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
    ) -> Result<Vec<(f32, usize)>> {
        self.eval_routed_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::eval_routed`] with an explicit worker count covering
    /// the whole wave (router scoring *and* the expert-group fan-out —
    /// `threads = 1` is fully sequential). Bit-identical at any count.
    pub fn eval_routed_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<Vec<(f32, usize)>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let routes = self.route_threaded(engine, seqs, m, threads)?;
        let groups = group_by_expert(&routes, self.n_experts())?;
        // batch by index over borrowed rows — no token clones; the whole
        // wave is one planned launch set so equal-bucket groups fuse
        let group_rows: Vec<Vec<&[u32]>> = groups
            .iter()
            .map(|idx| idx.iter().map(|&i| seqs[i].tokens.as_slice()).collect())
            .collect();
        let experts: Vec<&TrainState> = self.experts.iter().collect();
        let nlls = eval_nll_groups(engine, &experts, &self.expert_meta, &group_rows, threads)?;
        let mut out = vec![(0.0f32, 0usize); seqs.len()];
        for (e, (idx, nll)) in groups.iter().zip(&nlls).enumerate() {
            for (k, &i) in idx.iter().enumerate() {
                out[i] = (nll[k], e);
            }
        }
        Ok(out)
    }

    /// [`Mixture::eval_routed_threaded`] through the continuous-batching
    /// scheduler with an explicit [`ServerConfig`](super::server::ServerConfig)
    /// — with `cfg.replicas > 1` the expert executions spread across the
    /// replica fleet (see [`super::replica`]). Returns the same
    /// `(nll, expert)` per input sequence as the closed-wave path —
    /// bit-identical for any replica count — plus the scheduler stats
    /// carrying the fleet report.
    pub fn eval_routed_replicated(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        cfg: &super::server::ServerConfig,
    ) -> Result<(Vec<(f32, usize)>, super::server::SchedStats)> {
        if seqs.is_empty() {
            return Ok((Vec::new(), super::server::SchedStats::default()));
        }
        let requests: Vec<Request> = seqs
            .iter()
            .enumerate()
            .map(|(i, s)| Request {
                id: i as u64,
                tokens: s.tokens.clone(),
            })
            .collect();
        let backend = super::server::MixtureBackend {
            engine,
            mixture: self,
            prefix_len: m,
        };
        let (responses, stats, ()) = super::server::run_server(&backend, cfg, |client| {
            client.submit_wave(requests);
        })?;
        // run_server returns responses in submission order == seqs order
        Ok((
            responses.iter().map(|r| (r.nll, r.expert)).collect(),
            stats,
        ))
    }

    /// Mixture perplexity on a held-out set (routing with prefix `m`).
    /// Routing and expert groups fan across [`default_threads`] workers.
    pub fn perplexity(&self, engine: &Engine, seqs: &[Sequence], m: usize) -> Result<f64> {
        self.perplexity_threaded(engine, seqs, m, default_threads())
    }

    /// [`Mixture::perplexity`] with an explicit worker count for the
    /// whole wave (`threads <= 1` is fully sequential).
    pub fn perplexity_threaded(
        &self,
        engine: &Engine,
        seqs: &[Sequence],
        m: usize,
        threads: usize,
    ) -> Result<f64> {
        let per_seq = self.eval_routed_threaded(engine, seqs, m, threads)?;
        let total: f64 = per_seq.iter().map(|&(n, _)| n as f64).sum();
        let tokens = seqs.len() * (self.expert_meta.seq_len);
        Ok((total / tokens as f64).exp())
    }
}

/// Evaluate full-sequence NLL for an arbitrary number of rows, padding the
/// tail to a compiled batch shape (by reference — padding rows are
/// discarded). Rows may be owned vectors or borrowed slices.
///
/// This is the single-model view of [`eval_nll_groups`]: with fused
/// `eval_nll_all_{b}` entries in the manifest the row batches fuse into
/// bucketed stacked launches (the same expert repeated across the stack);
/// otherwise each batch runs one per-expert `eval_nll` execution at the
/// compiled `eval_batch` — bit-identical either way.
pub fn eval_nll_all<R: AsRef<[u32]>>(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    rows: &[R],
) -> Result<Vec<f32>> {
    let rows: Vec<&[u32]> = rows.iter().map(AsRef::as_ref).collect();
    let mut out = eval_nll_groups(engine, &[state], meta, std::slice::from_ref(&rows), 1)?;
    Ok(out.pop().unwrap_or_default())
}

// ----------------------------------------------------------------------
// Bucket-ladder wave planning (pure — unit-tested without artifacts)
// ----------------------------------------------------------------------

/// One expert-batch unit of a wave: rows `start..start + real` of group
/// `group`, padded up to `bucket` rows inside its launch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EvalUnit {
    pub group: usize,
    pub start: usize,
    pub real: usize,
    /// The compiled batch shape this unit evaluates under: the smallest
    /// ladder bucket that fits `real` (fused), or the plain `eval_batch`
    /// (single fan-out).
    pub bucket: usize,
}

/// One kernel launch of a planned wave.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EvalLaunch {
    /// ≥ 2 equal-bucket units stacked into one `eval_nll_all_{bucket}`
    /// execution (a short stack pads by repeating its last unit; the dead
    /// columns are discarded on readback).
    Fused { bucket: usize, units: Vec<EvalUnit> },
    /// A lone unit: runs the per-expert `eval_nll` at the compiled
    /// `eval_batch`. A one-unit stack would compute `width - 1` dead
    /// columns of FLOPs to save zero launches, so it never fuses.
    Single(EvalUnit),
}

impl EvalLaunch {
    /// Rows this launch computes only to discard: bucket padding past each
    /// unit's real rows plus whole dead columns padding a short stack to
    /// `width`. The single path reports 0 — its `eval_batch` tail padding
    /// is the pre-existing fan-out cost, not fused-launch waste.
    pub fn pad_rows(&self, width: usize) -> u64 {
        match self {
            EvalLaunch::Single(_) => 0,
            EvalLaunch::Fused { bucket, units } => {
                let unit_pad: u64 = units.iter().map(|u| (bucket - u.real) as u64).sum();
                unit_pad + (width.saturating_sub(units.len()) * bucket) as u64
            }
        }
    }
}

/// A planned wave: the launch list plus its accounting, satisfying
/// `launches.len() == fanout_launches - execs_avoided` exactly.
#[derive(Clone, Debug, Default)]
pub struct WavePlan {
    pub launches: Vec<EvalLaunch>,
    /// Launches the per-expert fan-out would have performed (one per
    /// batch-span unit).
    pub fanout_launches: usize,
    /// Launches fusion removed: `k - 1` per fused launch of `k` units.
    pub execs_avoided: usize,
    /// Total discarded rows across fused launches
    /// ([`EvalLaunch::pad_rows`] summed).
    pub pad_rows: u64,
}

/// Plan a wave's expert-side launches. `group_sizes[g]` is group `g`'s row
/// count, `bs` the compiled `eval_batch`, `buckets` the ascending fused
/// ladder ([`VariantMeta::fused_eval_buckets`] — empty on pre-fused
/// manifests), `width` the compiled stack width (`fused_experts`).
///
/// Each group tiles into `eval_batch` spans; each span becomes a unit
/// whose bucket is the smallest ladder shape that fits its real rows.
/// Equal-bucket units (across groups — this is where cross-expert fusion
/// happens) chunk into `width`-wide stacks, smallest buckets first; a
/// chunk of one unit degrades to the fan-out path. With an empty ladder
/// or `width < 2` every unit is a [`EvalLaunch::Single`] — the exact
/// pre-fused behaviour.
pub fn plan_wave(group_sizes: &[usize], bs: usize, buckets: &[usize], width: usize) -> WavePlan {
    let bs = bs.max(1);
    let mut units: Vec<EvalUnit> = Vec::new();
    let mut singles: Vec<EvalUnit> = Vec::new();
    for (group, &n) in group_sizes.iter().enumerate() {
        for (start, real) in batch_spans(n, bs) {
            match buckets.iter().find(|&&b| b >= real) {
                Some(&bucket) if width >= 2 => units.push(EvalUnit { group, start, real, bucket }),
                _ => singles.push(EvalUnit { group, start, real, bucket: bs }),
            }
        }
    }
    let fanout_launches = units.len() + singles.len();
    // stable: equal-bucket units keep (group, start) order, so the plan —
    // and therefore the launch set and its accounting — is deterministic
    units.sort_by_key(|u| u.bucket);

    let mut plan = WavePlan {
        fanout_launches,
        ..WavePlan::default()
    };
    let mut i = 0;
    while i < units.len() {
        let bucket = units[i].bucket;
        let class_end = i + units[i..].iter().take_while(|u| u.bucket == bucket).count();
        while i < class_end {
            let chunk = &units[i..class_end.min(i + width)];
            i += chunk.len();
            if chunk.len() == 1 {
                let mut unit = chunk[0].clone();
                unit.bucket = bs;
                singles.push(unit);
            } else {
                plan.execs_avoided += chunk.len() - 1;
                plan.launches.push(EvalLaunch::Fused {
                    bucket,
                    units: chunk.to_vec(),
                });
            }
        }
    }
    plan.pad_rows = plan.launches.iter().map(|l| l.pad_rows(width)).sum();
    plan.launches.extend(singles.into_iter().map(EvalLaunch::Single));
    plan
}

/// Device-side inputs of one fused launch, prepped on the caller thread
/// so worker tasks only execute and read back.
struct FusedPrep {
    entry: String,
    stack: DeviceBuffer,
    tokens: DeviceBuffer,
    pad_rows: u64,
}

/// Evaluate a whole wave's per-expert row groups — `groups[e]` under
/// `experts[e]` — returning one NLL vector per group. This is the
/// expert-side hot path behind [`Mixture::eval_routed_threaded`],
/// closed-wave serving, and (via [`eval_nll_all`]) the scheduler's
/// dispatched batches, dense eval, and downstream scoring.
///
/// Launches follow [`plan_wave`] over the manifest's fused bucket ladder:
/// equal-bucket batches from *different experts* stack into one
/// `eval_nll_all_{b}` execution over the cached stacked `[E, P]`
/// parameter tensor, lone units and pre-fused manifests fan out through
/// the per-expert `eval_nll`. Both paths are bit-identical (asserted by
/// `rust/tests/fused_eval.rs`) at any `threads` count: every launch
/// writes a disjoint region of the output. Launches are windowed like
/// scoring spans so device residency stays bounded on large waves.
pub fn eval_nll_groups(
    engine: &Engine,
    experts: &[&TrainState],
    meta: &VariantMeta,
    groups: &[Vec<&[u32]>],
    threads: usize,
) -> Result<Vec<Vec<f32>>> {
    ensure!(
        experts.len() == groups.len(),
        "{} expert groups for {} experts",
        groups.len(),
        experts.len()
    );
    let sizes: Vec<usize> = groups.iter().map(|g| g.len()).collect();
    let plan = plan_wave(
        &sizes,
        meta.eval_batch,
        &meta.fused_eval_buckets(),
        meta.fused_experts,
    );
    let mut out: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0.0f32; n]).collect();
    let bs = meta.eval_batch.max(1);
    let width = meta.fused_experts;
    let cols = meta.seq_len + 1;

    for window in plan.launches.chunks(SPAN_WINDOW) {
        // prep fused launches' device inputs up front (stacked params are
        // served from the engine's versioned stack cache; the token slab
        // uploads once and is dropped with the window)
        let preps: Vec<Option<FusedPrep>> = window
            .iter()
            .map(|launch| -> Result<Option<FusedPrep>> {
                let (bucket, units) = match launch {
                    EvalLaunch::Fused { bucket, units } => (*bucket, units),
                    EvalLaunch::Single(_) => return Ok(None),
                };
                let entry = meta.fused_eval_entry(bucket).with_context(|| {
                    format!(
                        "no fused eval_nll_all_{bucket} entry compiled for {} — \
                         re-run `make artifacts` (aot.py --fused)",
                        meta.name
                    )
                })?;
                let mut members: Vec<&TrainState> =
                    units.iter().map(|u| experts[u.group]).collect();
                let last = *members.last().expect("fused launches hold >= 2 units");
                members.resize(width, last);
                let stack = stacked_params_buffer(engine, &members)?;
                // [width, bucket, S+1] token slab: each unit's rows padded
                // to the bucket by repeating its last row by reference;
                // dead columns repeat the last unit's padded rows
                let mut rows: Vec<&[u32]> = Vec::with_capacity(width * bucket);
                for u in units {
                    let group = &groups[u.group][u.start..u.start + u.real];
                    rows.extend(pad_batch(group.to_vec(), bucket));
                }
                let tail = rows.len() - bucket;
                for _ in units.len()..width {
                    rows.extend_from_within(tail..tail + bucket);
                }
                let lit = tokens_literal(&rows, cols)?
                    .reshape(&[width as i64, bucket as i64, cols as i64])
                    .map_err(anyhow::Error::msg)?;
                Ok(Some(FusedPrep {
                    entry,
                    stack,
                    tokens: engine.upload(&lit)?,
                    pad_rows: launch.pad_rows(width),
                }))
            })
            .collect::<Result<_>>()?;

        let tasks: Vec<_> = window
            .iter()
            .zip(&preps)
            .map(|(launch, prep)| {
                move || -> Result<Vec<f32>> {
                    match launch {
                        EvalLaunch::Fused { units, .. } => {
                            let p = prep.as_ref().context("fused launch lost its prep")?;
                            let slab = engine.run_buffers_fused_eval(
                                &meta.name,
                                &p.entry,
                                &[Arg::Dev(&p.stack), Arg::Dev(&p.tokens)],
                                units.len(),
                                p.pad_rows,
                            )?;
                            to_f32_vec(slab.first().context("eval_nll_all empty")?)
                        }
                        EvalLaunch::Single(u) => {
                            let group = &groups[u.group][u.start..u.start + u.real];
                            let batch = pad_batch(group.to_vec(), bs);
                            experts[u.group].eval_nll(engine, &batch, meta)
                        }
                    }
                }
            })
            .collect();

        for (launch, nll) in window.iter().zip(run_fallible(tasks, threads)?) {
            match launch {
                EvalLaunch::Fused { bucket, units } => {
                    // row-major [width, bucket] slab: unit j's rows start
                    // at j * bucket; everything past real is padding
                    ensure!(
                        nll.len() == width * bucket,
                        "fused eval returned {} scores for a [{width}, {bucket}] slab",
                        nll.len()
                    );
                    for (j, u) in units.iter().enumerate() {
                        out[u.group][u.start..u.start + u.real]
                            .copy_from_slice(&nll[j * bucket..j * bucket + u.real]);
                    }
                }
                EvalLaunch::Single(u) => {
                    out[u.group][u.start..u.start + u.real].copy_from_slice(&nll[..u.real]);
                }
            }
        }
    }
    Ok(out)
}

/// Group sequence indices by their routed expert: `groups[e]` holds the
/// input indices assigned to expert `e`, in input order.
///
/// A route index `>= n_experts` (a corrupt checkpoint, a buggy backend)
/// is a structured error, not a slice-index panic.
pub fn group_by_expert(routes: &[usize], n_experts: usize) -> Result<Vec<Vec<usize>>> {
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_experts];
    for (i, &e) in routes.iter().enumerate() {
        if e >= n_experts {
            bail!("route index {e} out of range for {n_experts} experts (sequence position {i})");
        }
        groups[e].push(i);
    }
    Ok(groups)
}

/// Mean microseconds per request, rounded half-up from the total's
/// nanosecond count — the shared amortization rule for every batched
/// timing field (the old `total_micros / n` integer division silently
/// dropped up to a microsecond per request). Returns 0 for an empty
/// batch.
pub fn amortized_micros(total: Duration, n: usize) -> u128 {
    if n == 0 {
        return 0;
    }
    let denom = n as u128 * 1000;
    (total.as_nanos() + denom / 2) / denom
}

/// Dense-baseline perplexity on the same sequences (comparator).
pub fn dense_perplexity(
    engine: &Engine,
    state: &TrainState,
    meta: &VariantMeta,
    seqs: &[Sequence],
) -> Result<f64> {
    let rows: Vec<&[u32]> = seqs.iter().map(|s| s.tokens.as_slice()).collect();
    let nll = eval_nll_all(engine, state, meta, &rows)?;
    let total: f64 = nll.iter().map(|&n| n as f64).sum();
    Ok((total / (seqs.len() * meta.seq_len) as f64).exp())
}

// ----------------------------------------------------------------------
// Serving loop
// ----------------------------------------------------------------------

/// One inference request: a token sequence to score (seq_len + 1 tokens).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// The server's answer.
///
/// Timing semantics (unified): `route_micros` and `exec_micros` are
/// **mean microseconds per request** over the batch that processed this
/// request, rounded half-up ([`amortized_micros`]). Routing is a batched
/// score-matrix per **admission wave** (the whole wave in closed-wave
/// serving), so `route_micros` is wave-total / wave-size and identical
/// for every response admitted together. Execution is batched per
/// **dispatched expert batch** in the scheduler; closed-wave serving
/// times the whole expert phase as one span and amortizes it wave-wide
/// (fused bucket launches interleave expert groups, so per-group
/// execution is not separable), making `exec_micros` identical for every
/// response of a closed wave. Neither is an isolated single-request
/// latency — that is the batched-serving cost model.
///
/// `queue_micros` is different: it is this request's **true** queueing
/// delay — the arrival-queue wait (submission → admission) plus the
/// pending/linger and dispatch-queue wait (routing done → batch execution
/// start). The routing span between those two windows is deliberately
/// excluded: `route_micros` accounts for it, so [`Response::total_micros`]
/// sums three disjoint components. The sequential closed-wave reference
/// path has no queue and reports 0.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub expert: usize,
    pub nll: f32,
    /// True per-request queueing delay (arrival-queue + pending +
    /// dispatch-queue wait, routing excluded; 0 on the sequential
    /// closed-wave path).
    pub queue_micros: u128,
    /// Mean routing microseconds per request (amortized over the
    /// admission wave).
    pub route_micros: u128,
    /// Mean expert-execution microseconds per request (amortized over the
    /// request's dispatched batch).
    pub exec_micros: u128,
}

impl Response {
    /// End-to-end latency attributed to this request: queueing delay plus
    /// the amortized routing and execution shares.
    pub fn total_micros(&self) -> u128 {
        self.queue_micros + self.route_micros + self.exec_micros
    }
}

/// The determinism key of a response set: sorted `(id, expert, NLL bits)`
/// triples. Two serving paths answered the same requests identically iff
/// their keys are equal — the comparison every determinism guard (the
/// serve benches, `rust/tests/server.rs`, `smalltalk serve`) performs.
pub fn response_triples(responses: &[Response]) -> Vec<(u64, usize, u32)> {
    let mut t: Vec<(u64, usize, u32)> = responses
        .iter()
        .map(|r| (r.id, r.expert, r.nll.to_bits()))
        .collect();
    t.sort_unstable();
    t
}

/// Batched serving: route all queued requests, group by expert, execute.
/// Returns responses in input order plus amortized per-request timings
/// (see [`Response`] for the exact semantics). Expert groups execute on
/// [`default_threads`] workers; see [`serve_threaded`].
pub fn serve(engine: &Engine, mixture: &Mixture, requests: &[Request], m: usize) -> Result<Vec<Response>> {
    serve_threaded(engine, mixture, requests, m, default_threads())
}

/// [`serve`] with an explicit worker count covering the whole wave:
/// router scoring and the expert-group fan-out both run on `threads`
/// workers, so `threads = 1` is the fully sequential reference path.
///
/// `threads = 1` runs the classic closed-wave loop inline — no threads
/// spawned, groups executed in expert order: the bit-exact reference.
/// `threads > 1` submits the slice as one atomic wave to the
/// continuous-batching scheduler in [`super::server`] under its
/// closed-wave configuration (one admission wave, each expert group
/// dispatched whole at drain), so both paths score and batch identically.
/// The wrapper clones the request slice once to hand the queue an owned
/// wave — the only allocation difference from the sequential path.
/// Either way the output — ids, experts, NLLs, input order — is
/// bit-identical across worker counts; only the timing fields vary
/// run-to-run (they are wall-clock measurements).
pub fn serve_threaded(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
    threads: usize,
) -> Result<Vec<Response>> {
    if requests.is_empty() {
        // nothing to route: never build a zero-row batch
        return Ok(Vec::new());
    }
    if threads <= 1 {
        return serve_closed_wave(engine, mixture, requests, m);
    }
    let backend = super::server::MixtureBackend {
        engine,
        mixture,
        prefix_len: m,
    };
    let cfg = super::server::ServerConfig::closed_wave(threads);
    let (responses, _stats, ()) = super::server::run_server(&backend, &cfg, |client| {
        client.submit_wave(requests.to_vec());
    })?;
    Ok(responses)
}

/// [`serve_threaded`] through an explicit [`ServerConfig`] — the entry
/// point the replica fleet rides in on: a `cfg` with `replicas > 1`
/// dispatches each batch to the least-loaded live holder of its expert
/// (see [`super::replica`]) and reports the fleet accounting in
/// [`SchedStats::replica`]. Responses still come back in input order and
/// the `(id, expert, nll)` triples are bit-identical to `replicas = 1` —
/// replica choice cannot change an NLL.
pub fn serve_replicated(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
    cfg: &super::server::ServerConfig,
) -> Result<(Vec<Response>, super::server::SchedStats)> {
    if requests.is_empty() {
        return Ok((Vec::new(), super::server::SchedStats::default()));
    }
    let backend = super::server::MixtureBackend {
        engine,
        mixture,
        prefix_len: m,
    };
    let (responses, stats, ()) = super::server::run_server(&backend, cfg, |client| {
        client.submit_wave(requests.to_vec());
    })?;
    Ok((responses, stats))
}

/// The sequential closed-wave loop: route everything in one score-matrix
/// wave, execute each expert group in expert order on the caller's
/// thread. This is the reference implementation every scheduled path is
/// measured against.
fn serve_closed_wave(
    engine: &Engine,
    mixture: &Mixture,
    requests: &[Request],
    m: usize,
) -> Result<Vec<Response>> {
    // borrow token rows straight out of the requests — no Sequence clones
    let rows: Vec<&[u32]> = requests.iter().map(|r| r.tokens.as_slice()).collect();
    let t0 = Instant::now();
    let routes = mixture.route_rows_threaded(engine, &rows, m, 1)?;
    let route_us = amortized_micros(t0.elapsed(), requests.len());

    let mut responses: Vec<Response> = requests
        .iter()
        .zip(&routes)
        .map(|(r, &e)| Response {
            id: r.id,
            expert: e,
            nll: 0.0,
            queue_micros: 0,
            route_micros: route_us,
            exec_micros: 0,
        })
        .collect();

    let groups = group_by_expert(&routes, mixture.n_experts())?;
    let group_rows: Vec<Vec<&[u32]>> = groups
        .iter()
        .map(|idx| idx.iter().map(|&i| rows[i]).collect())
        .collect();
    let experts: Vec<&TrainState> = mixture.experts.iter().collect();
    // the expert phase is timed whole-wave: fused launches interleave
    // expert groups, so per-group execution is no longer separable
    let t1 = Instant::now();
    let nlls = eval_nll_groups(engine, &experts, &mixture.expert_meta, &group_rows, 1)?;
    let exec_us = amortized_micros(t1.elapsed(), requests.len());
    for (e, idx) in groups.iter().enumerate() {
        for (k, &i) in idx.iter().enumerate() {
            responses[i].nll = nlls[e][k];
            responses[i].exec_micros = exec_us;
        }
    }
    Ok(responses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_micros_sums_queue_route_and_exec() {
        let r = Response {
            id: 9,
            expert: 2,
            nll: 1.5,
            queue_micros: 40,
            route_micros: 120,
            exec_micros: 840,
        };
        assert_eq!(r.total_micros(), 1000);
        let zero = Response {
            id: 0,
            expert: 0,
            nll: 0.0,
            queue_micros: 0,
            route_micros: 0,
            exec_micros: 0,
        };
        assert_eq!(zero.total_micros(), 0);
    }

    #[test]
    fn group_by_expert_partitions_in_input_order() {
        let groups = group_by_expert(&[1, 0, 1, 2, 0], 4).unwrap();
        assert_eq!(groups, vec![vec![1, 4], vec![0, 2], vec![3], vec![]]);
        // every index appears exactly once
        let mut all: Vec<usize> = groups.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn group_by_expert_rejects_out_of_range_routes() {
        // boundary: n_experts itself is already out of range
        let err = group_by_expert(&[0, 3, 1], 3).unwrap_err().to_string();
        assert!(err.contains("route index 3"), "{err}");
        assert!(err.contains("3 experts"), "{err}");
        assert!(err.contains("position 1"), "{err}");
        assert!(group_by_expert(&[9], 0).is_err());
        // in-range max is fine
        assert!(group_by_expert(&[2], 3).is_ok());
    }

    /// Every (group, row) index is written by exactly one launch.
    fn assert_covers_exactly_once(plan: &WavePlan, sizes: &[usize]) {
        let mut seen: Vec<Vec<bool>> = sizes.iter().map(|&n| vec![false; n]).collect();
        let units = plan.launches.iter().flat_map(|l| match l {
            EvalLaunch::Fused { units, .. } => units.as_slice(),
            EvalLaunch::Single(u) => std::slice::from_ref(u),
        });
        for u in units {
            for i in u.start..u.start + u.real {
                assert!(!seen[u.group][i], "row ({}, {i}) covered twice", u.group);
                seen[u.group][i] = true;
            }
        }
        for (g, rows) in seen.iter().enumerate() {
            assert!(rows.iter().all(|&s| s), "group {g} not fully covered");
        }
    }

    const LADDER: &[usize] = &[1, 2, 4, 8, 16];

    #[test]
    fn plan_wave_empty_ladder_is_pure_fanout() {
        let plan = plan_wave(&[3, 20, 0], 16, &[], 4);
        assert_eq!(plan.launches.len(), 3); // spans: 1 + 2 + 0
        assert!(plan
            .launches
            .iter()
            .all(|l| matches!(l, EvalLaunch::Single(_))));
        assert_eq!(plan.fanout_launches, 3);
        assert_eq!(plan.execs_avoided, 0);
        assert_eq!(plan.pad_rows, 0);
        assert_covers_exactly_once(&plan, &[3, 20, 0]);
    }

    #[test]
    fn plan_wave_width_under_two_never_fuses() {
        let plan = plan_wave(&[4, 4], 16, LADDER, 1);
        assert!(plan
            .launches
            .iter()
            .all(|l| matches!(l, EvalLaunch::Single(_))));
        assert_eq!(plan.execs_avoided, 0);
    }

    #[test]
    fn plan_wave_straddle_wave_fuses_to_two_launches() {
        // the acceptance shape: groups {1, bs-1, bs, bs+1} at E = 4 —
        // bucket 1 holds two one-row units, bucket 16 holds three
        let sizes = [1, 15, 16, 17];
        let plan = plan_wave(&sizes, 16, LADDER, 4);
        assert_eq!(plan.fanout_launches, 5);
        assert_eq!(plan.launches.len(), 2);
        assert_eq!(plan.execs_avoided, 3);
        assert_eq!(
            plan.launches.len(),
            plan.fanout_launches - plan.execs_avoided
        );
        // bucket 1: two full one-row units, two dead columns; bucket 16:
        // one unit one row short, one dead column
        assert_eq!(plan.pad_rows, (0 + 2 * 1) + (1 + 1 * 16));
        assert_covers_exactly_once(&plan, &sizes);
    }

    #[test]
    fn plan_wave_skewed_all_to_one_expert() {
        // one expert takes the whole wave: 3 full buckets fuse (the same
        // group stacked against itself), the 5-row tail is a lone
        // bucket-8 unit and degrades to a single fan-out launch
        let sizes = [53, 0, 0, 0];
        let plan = plan_wave(&sizes, 16, LADDER, 4);
        assert_eq!(plan.fanout_launches, 4);
        assert_eq!(plan.launches.len(), 2);
        assert_eq!(plan.execs_avoided, 2);
        let fused: Vec<_> = plan
            .launches
            .iter()
            .filter_map(|l| match l {
                EvalLaunch::Fused { bucket, units } => Some((*bucket, units.len())),
                EvalLaunch::Single(_) => None,
            })
            .collect();
        assert_eq!(fused, vec![(16, 3)]);
        // one dead column of 16 rows pads the 3-unit stack to width 4
        assert_eq!(plan.pad_rows, 16);
        assert_covers_exactly_once(&plan, &sizes);
    }

    #[test]
    fn plan_wave_bucket_edges() {
        // group sizes straddling every bucket edge pick the smallest
        // bucket that fits (paired so every class fuses)
        let sizes = [1, 1, 3, 4, 5, 8, 9, 16];
        let plan = plan_wave(&sizes, 16, LADDER, 8);
        let mut buckets: Vec<(usize, usize)> = Vec::new();
        for l in &plan.launches {
            if let EvalLaunch::Fused { bucket, units } = l {
                for u in units {
                    buckets.push((u.group, *bucket));
                }
            }
        }
        buckets.sort_unstable();
        assert_eq!(
            buckets,
            vec![
                (0, 1),
                (1, 1),
                (2, 4),
                (3, 4),
                (4, 8),
                (5, 8),
                (6, 16),
                (7, 16)
            ]
        );
        assert_eq!(plan.launches.len(), 4);
        assert_eq!(plan.execs_avoided, 4);
        assert_covers_exactly_once(&plan, &sizes);
    }

    #[test]
    fn plan_wave_chunks_wide_classes_and_demotes_leftovers() {
        // five equal-bucket units at width 4: one full stack + a lone
        // leftover that must NOT burn 3 dead columns — it goes single
        let sizes = [2, 2, 2, 2, 2];
        let plan = plan_wave(&sizes, 16, LADDER, 4);
        assert_eq!(plan.launches.len(), 2);
        let (mut fused, mut single) = (0, 0);
        for l in &plan.launches {
            match l {
                EvalLaunch::Fused { bucket, units } => {
                    assert_eq!((*bucket, units.len()), (2, 4));
                    fused += 1;
                }
                EvalLaunch::Single(_) => single += 1,
            }
        }
        assert_eq!((fused, single), (1, 1));
        assert_eq!(plan.execs_avoided, 3);
        assert_eq!(plan.pad_rows, 0); // full stack, full buckets
        assert_covers_exactly_once(&plan, &sizes);
    }

    #[test]
    fn plan_wave_counters_reconcile_on_grids() {
        // launch count == fan-out count - execs avoided, for every mix
        for &width in &[2usize, 3, 4, 8] {
            for sizes in [
                vec![1, 15, 16, 17],
                vec![0, 0, 35, 1],
                vec![7; 9],
                vec![16, 16, 16, 16],
                vec![33],
                vec![],
            ] {
                let plan = plan_wave(&sizes, 16, LADDER, width);
                assert_eq!(
                    plan.launches.len(),
                    plan.fanout_launches - plan.execs_avoided,
                    "sizes {sizes:?} width {width}"
                );
                assert_covers_exactly_once(&plan, &sizes);
            }
        }
    }

    #[test]
    fn eval_launch_pad_rows_accounting() {
        let unit = |real, bucket| EvalUnit {
            group: 0,
            start: 0,
            real,
            bucket,
        };
        // 2 units at bucket 8 (3 + 0 pad) + 2 dead columns of 8
        let l = EvalLaunch::Fused {
            bucket: 8,
            units: vec![unit(5, 8), unit(8, 8)],
        };
        assert_eq!(l.pad_rows(4), 3 + 16);
        // singles never report fused waste
        assert_eq!(EvalLaunch::Single(unit(3, 16)).pad_rows(4), 0);
    }

    #[test]
    fn amortized_micros_rounds_half_up() {
        // exact division: unchanged
        assert_eq!(amortized_micros(Duration::from_micros(100), 4), 25);
        // 1.5 µs/request rounds up (integer division would truncate to 1)
        assert_eq!(amortized_micros(Duration::from_nanos(3000), 2), 2);
        // just below the half-way point rounds down
        assert_eq!(amortized_micros(Duration::from_nanos(2999), 2), 1);
        // sub-microsecond totals no longer vanish: 0.6 µs/request -> 1
        assert_eq!(amortized_micros(Duration::from_nanos(600), 1), 1);
        assert_eq!(amortized_micros(Duration::from_nanos(499), 1), 0);
        // 10 µs over 3 requests = 3.33 -> 3
        assert_eq!(amortized_micros(Duration::from_micros(10), 3), 3);
        // empty batch is defined, not a division by zero
        assert_eq!(amortized_micros(Duration::from_micros(10), 0), 0);
    }
}
