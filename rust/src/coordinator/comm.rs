//! Communication ledger — the §A.4 accounting, measured not assumed.
//!
//! Every "node" (router trainer, expert trainer, leader) records the
//! messages it would send/receive on a real cluster. The mixture's only
//! collective is the all-gather of per-sequence router scores before each
//! balanced assignment; expert training is fully independent. The ledger
//! also implements the paper's DDP comparator (gradient all-reduce every
//! step under a bandwidth-optimal collective: `2 * W * 4` bytes per node
//! per step).

use std::collections::BTreeMap;

/// Kind of communication event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum CommKind {
    /// All-gather of router scores for a data chunk (Alg. 1 line 8/13).
    ScoreAllGather,
    /// Broadcast of assignment results back to trainers.
    AssignmentBroadcast,
    /// Checkpoint/weight movement (once per training, not per step).
    WeightTransfer,
    /// Router-snapshot broadcast: the async trainer's only inter-node
    /// traffic. The router leader pushes the full (tiny) router parameter
    /// set to every expert node; nodes route locally against whatever
    /// snapshot they hold, so no per-chunk score exchange ever happens.
    SnapshotBroadcast,
    /// DDP gradient all-reduce (baseline comparator only).
    GradAllReduce,
    /// A replacement node pulls a departed node's checkpoint from shared
    /// storage and resumes its seat (elastic membership). One point-to-
    /// point transfer of the full checkpoint file per adoption.
    CheckpointAdopt,
    /// A rejoining node's offline parameter delta merges back into its
    /// seat through the delayed-Nesterov outer update (Async Local-SGD).
    /// `staleness` records the snapshot-version lag the offline worker
    /// trained under.
    ParamMerge,
    /// One shard's router block crossing the shard boundary at an
    /// EM-round boundary: the sending shard's leader ships its routers to
    /// another shard's leader. This is the *only* inter-shard traffic in
    /// a healthy fleet, and it happens exclusively at round boundaries —
    /// the fleet tests assert zero cross-shard bytes in between.
    /// `staleness` records how many rounds behind the receiver's held
    /// copy was (nonzero only when a partition heals).
    CrossShardPublish,
    /// A whole-shard recovery transfer: a promoted member (leader loss)
    /// or a re-adopted shard (shard kill) pulls a checkpoint across the
    /// shard's fault-domain boundary.
    ShardAdopt,
    /// A serving-fleet placement move: an engine replica gaining a copy
    /// of an expert pulls the full expert parameter set once (the serve
    /// analogue of [`CommKind::WeightTransfer`]). Replicas live inside
    /// one serving domain, so this is intra-shard traffic; `step` carries
    /// the rebalance epoch, which is what lets the fleet tests reconcile
    /// ledger bytes against the move count in closed form.
    ReplicaSync,
}

impl CommKind {
    /// `true` for event kinds that cross a shard (fault-domain) boundary.
    /// Everything else stays inside one shard's `SnapshotStore` domain,
    /// so [`CommLedger::intra_shard_bytes`] + [`CommLedger::inter_shard_bytes`]
    /// always partition [`CommLedger::total_bytes`] exactly.
    pub fn is_cross_shard(self) -> bool {
        matches!(self, CommKind::CrossShardPublish | CommKind::ShardAdopt)
    }
}

/// One recorded event.
#[derive(Clone, Debug)]
pub struct CommEvent {
    pub node: usize,
    pub kind: CommKind,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub step: u64,
    /// Snapshot-version lag for [`CommKind::ParamMerge`] events (how many
    /// router-snapshot versions behind the live store the merged worker
    /// was). Zero for every other kind.
    pub staleness: u64,
}

/// Ledger of all communication in a run.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    pub events: Vec<CommEvent>,
}

/// Aggregate view per node.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeTotals {
    pub events: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

impl CommLedger {
    pub fn record(&mut self, ev: CommEvent) {
        self.events.push(ev);
    }

    /// Record a bandwidth-optimal all-gather of `scores_per_node` f16
    /// scores across `nodes` participants: each node sends its own scores
    /// once and receives everyone else's.
    pub fn record_score_allgather(&mut self, nodes: usize, scores_per_node: u64, step: u64) {
        let own = scores_per_node * 2; // f16 = 2 bytes (paper §A.4)
        for node in 0..nodes {
            self.record(CommEvent {
                node,
                kind: CommKind::ScoreAllGather,
                bytes_sent: own,
                bytes_received: own * (nodes as u64 - 1),
                step,
                staleness: 0,
            });
        }
    }

    /// Record one router-snapshot broadcast: the publisher (node index
    /// `nodes` — the router leader sits outside the expert-node range)
    /// sends the full `snapshot_bytes` router parameter set to each of
    /// `nodes` expert nodes; each node receives one copy. `version` is
    /// the snapshot version, which doubles as the collective-round id
    /// for [`CommLedger::rounds`].
    pub fn record_snapshot_broadcast(&mut self, nodes: usize, snapshot_bytes: u64, version: u64) {
        self.record(CommEvent {
            node: nodes,
            kind: CommKind::SnapshotBroadcast,
            bytes_sent: snapshot_bytes * nodes as u64,
            bytes_received: 0,
            step: version,
            staleness: 0,
        });
        for node in 0..nodes {
            self.record(CommEvent {
                node,
                kind: CommKind::SnapshotBroadcast,
                bytes_sent: 0,
                bytes_received: snapshot_bytes,
                step: version,
                staleness: 0,
            });
        }
    }

    /// Record one checkpoint adoption: the replacement taking over seat
    /// `node` pulls the departed node's `ckpt_bytes` checkpoint from
    /// shared storage (one point-to-point transfer; the storage side is
    /// the sender so [`CommLedger::total_bytes`] counts it once).
    pub fn record_checkpoint_adopt(&mut self, node: usize, ckpt_bytes: u64, step: u64) {
        self.record(CommEvent {
            node,
            kind: CommKind::CheckpointAdopt,
            bytes_sent: ckpt_bytes,
            bytes_received: ckpt_bytes,
            step,
            staleness: 0,
        });
    }

    /// Record one delayed-Nesterov parameter merge into seat `node`: the
    /// rejoining worker ships its full `param_bytes` delta, the seat
    /// receives it, and `staleness` snapshot versions of lag are audited.
    pub fn record_param_merge(&mut self, node: usize, param_bytes: u64, step: u64, staleness: u64) {
        self.record(CommEvent {
            node,
            kind: CommKind::ParamMerge,
            bytes_sent: param_bytes,
            bytes_received: param_bytes,
            step,
            staleness,
        });
    }

    /// Record one cross-shard router-block publish landing on `node` (the
    /// receiving shard's leader seat): `bytes` of router parameters cross
    /// the shard boundary once (sender's leader → receiver's leader, so
    /// [`CommLedger::total_bytes`] counts the transfer once). `round` is
    /// the EM round the exchange happened at — cross-shard events carry
    /// the round id as their step, which is what lets the fleet tests
    /// assert "zero inter-shard bytes between round boundaries" exactly.
    /// `staleness` is the receiver's held-copy lag in rounds (nonzero
    /// only on partition heal, where the delayed-Nesterov catch-up runs).
    pub fn record_cross_shard_publish(&mut self, node: usize, bytes: u64, round: u64, staleness: u64) {
        self.record(CommEvent {
            node,
            kind: CommKind::CrossShardPublish,
            bytes_sent: bytes,
            bytes_received: bytes,
            step: round,
            staleness,
        });
    }

    /// Record one shard-recovery checkpoint transfer into seat `node`
    /// (leader promotion or whole-shard re-adoption): `ckpt_bytes` cross
    /// the fault-domain boundary once.
    pub fn record_shard_adopt(&mut self, node: usize, ckpt_bytes: u64, step: u64) {
        self.record(CommEvent {
            node,
            kind: CommKind::ShardAdopt,
            bytes_sent: ckpt_bytes,
            bytes_received: ckpt_bytes,
            step,
            staleness: 0,
        });
    }

    /// Record one serving-fleet placement move: replica `node` gains a
    /// copy of an expert and pulls its full `param_bytes` once (one
    /// point-to-point transfer, counted once in
    /// [`CommLedger::total_bytes`]). `epoch` is the rebalance epoch the
    /// move belongs to — every move of one rebalance shares it, so
    /// [`CommLedger::rounds`] counts rebalances that actually moved
    /// something and `kind_bytes(ReplicaSync)` is exactly
    /// `moves * param_bytes`.
    pub fn record_replica_sync(&mut self, node: usize, param_bytes: u64, epoch: u64) {
        self.record(CommEvent {
            node,
            kind: CommKind::ReplicaSync,
            bytes_sent: param_bytes,
            bytes_received: param_bytes,
            step: epoch,
            staleness: 0,
        });
    }

    /// Record one DDP gradient all-reduce step: `2 * W * 4` bytes per node
    /// (bandwidth-optimal ring, f32 gradients — §A.4 "Comparison with
    /// Distributed Training").
    pub fn record_ddp_allreduce(&mut self, nodes: usize, params: u64, step: u64) {
        let bytes = 2 * params * 4;
        for node in 0..nodes {
            self.record(CommEvent {
                node,
                kind: CommKind::GradAllReduce,
                bytes_sent: bytes / 2,
                bytes_received: bytes / 2,
                step,
                staleness: 0,
            });
        }
    }

    /// Total bytes sent for one event kind (exact-audit queries in the
    /// chaos tests: snapshot vs adoption vs merge traffic).
    pub fn kind_bytes(&self, kind: CommKind) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.bytes_sent)
            .sum()
    }

    /// Largest staleness audited across all [`CommKind::ParamMerge`]
    /// events (0 when no merge happened).
    pub fn max_merge_staleness(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == CommKind::ParamMerge)
            .map(|e| e.staleness)
            .max()
            .unwrap_or(0)
    }

    pub fn totals_per_node(&self) -> BTreeMap<usize, NodeTotals> {
        let mut out: BTreeMap<usize, NodeTotals> = BTreeMap::new();
        for ev in &self.events {
            let t = out.entry(ev.node).or_default();
            t.events += 1;
            t.bytes_sent += ev.bytes_sent;
            t.bytes_received += ev.bytes_received;
        }
        out
    }

    pub fn total_bytes(&self) -> u64 {
        self.events.iter().map(|e| e.bytes_sent).sum()
    }

    /// Bytes that stayed inside a single shard's fault domain (snapshot
    /// broadcasts, in-shard adoptions, merges, score exchanges, ...).
    pub fn intra_shard_bytes(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| !e.kind.is_cross_shard())
            .map(|e| e.bytes_sent)
            .sum()
    }

    /// Bytes that crossed a shard boundary ([`CommKind::is_cross_shard`]).
    /// With `intra_shard_bytes` this partitions [`CommLedger::total_bytes`].
    pub fn inter_shard_bytes(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind.is_cross_shard())
            .map(|e| e.bytes_sent)
            .sum()
    }

    /// Number of distinct collective rounds (unique (kind, step) pairs).
    pub fn rounds(&self, kind: CommKind) -> usize {
        let mut steps: Vec<u64> = self
            .events
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.step)
            .collect();
        steps.sort_unstable();
        steps.dedup();
        steps.len()
    }

    /// Max bytes (sent+received) seen by any single node — the interconnect
    /// requirement.
    pub fn peak_node_bytes(&self) -> u64 {
        self.totals_per_node()
            .values()
            .map(|t| t.bytes_sent + t.bytes_received)
            .max()
            .unwrap_or(0)
    }
}

// -------------------------------------------------------------------------
// Closed forms from the paper's §A.4, used to cross-check the ledger and to
// evaluate paper-scale configurations in the comm_overhead bench.
// -------------------------------------------------------------------------

/// Number of router communication rounds:
/// `N_comm = N_steps_router * S * B_r / T` (§A.4).
pub fn router_comm_rounds(steps: u64, seq_len: u64, batch: u64, tokens_between_comm: u64) -> u64 {
    (steps * seq_len * batch).div_ceil(tokens_between_comm)
}

/// Data per router over its whole training, bytes:
/// `2 * 2 * T * E / S` (§A.4, f16 scores, send+receive).
pub fn router_bytes_per_comm(tokens_between_comm: u64, experts: u64, seq_len: u64) -> u64 {
    2 * 2 * tokens_between_comm * experts / seq_len
}

/// DDP bytes per node per step for a model of `params` f32 parameters:
/// `2 * W * 4` (§A.4).
pub fn ddp_bytes_per_step(params: u64) -> u64 {
    2 * params * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers_router_rounds() {
        // Paper: 128k steps, B_r=32, S=1024, T=45M tokens -> ~94 rounds (<100)
        let n = router_comm_rounds(128_000, 1024, 32, 45_000_000);
        assert!((90..100).contains(&n), "{n}");
    }

    #[test]
    fn paper_numbers_router_bytes() {
        // Paper: T=45e6, E=32, S=1024 -> 5.625 MB per round
        let b = router_bytes_per_comm(45_000_000, 32, 1024);
        assert_eq!(b, 5_625_000);
    }

    #[test]
    fn paper_numbers_ddp() {
        // Paper: W=1.3e9 -> 10.4 GB per node per step
        let b = ddp_bytes_per_step(1_300_000_000);
        assert_eq!(b, 10_400_000_000);
    }

    #[test]
    fn allgather_symmetry() {
        let mut l = CommLedger::default();
        l.record_score_allgather(4, 1000, 0);
        let t = l.totals_per_node();
        assert_eq!(t.len(), 4);
        for v in t.values() {
            assert_eq!(v.bytes_sent, 2000);
            assert_eq!(v.bytes_received, 6000);
        }
        assert_eq!(l.rounds(CommKind::ScoreAllGather), 1);
    }

    #[test]
    fn snapshot_broadcast_totals_exact() {
        let mut l = CommLedger::default();
        // two publishes of a 64-byte snapshot to 3 expert nodes
        l.record_snapshot_broadcast(3, 64, 1);
        l.record_snapshot_broadcast(3, 64, 2);
        assert_eq!(l.events.len(), 2 * (3 + 1));
        assert_eq!(l.rounds(CommKind::SnapshotBroadcast), 2);
        let t = l.totals_per_node();
        // publisher (node 3) sends nodes x bytes per publish, receives 0
        assert_eq!(t[&3].bytes_sent, 2 * 3 * 64);
        assert_eq!(t[&3].bytes_received, 0);
        for node in 0..3 {
            assert_eq!(t[&node].bytes_sent, 0);
            assert_eq!(t[&node].bytes_received, 2 * 64);
        }
        assert_eq!(l.total_bytes(), 2 * 3 * 64);
        assert_eq!(l.peak_node_bytes(), 2 * 3 * 64);
    }

    #[test]
    fn adopt_and_merge_totals_exact() {
        let mut l = CommLedger::default();
        l.record_snapshot_broadcast(2, 64, 1);
        l.record_checkpoint_adopt(1, 500, 10);
        l.record_checkpoint_adopt(0, 500, 14);
        l.record_param_merge(1, 240, 20, 3);
        assert_eq!(l.kind_bytes(CommKind::SnapshotBroadcast), 2 * 64);
        assert_eq!(l.kind_bytes(CommKind::CheckpointAdopt), 2 * 500);
        assert_eq!(l.kind_bytes(CommKind::ParamMerge), 240);
        assert_eq!(l.total_bytes(), 2 * 64 + 2 * 500 + 240);
        assert_eq!(l.rounds(CommKind::CheckpointAdopt), 2);
        assert_eq!(l.rounds(CommKind::ParamMerge), 1);
        assert_eq!(l.max_merge_staleness(), 3);
        // non-merge events never carry staleness
        assert!(l
            .events
            .iter()
            .filter(|e| e.kind != CommKind::ParamMerge)
            .all(|e| e.staleness == 0));
    }

    #[test]
    fn intra_inter_shard_split_partitions_totals() {
        let mut l = CommLedger::default();
        l.record_snapshot_broadcast(2, 64, 1); // intra: 2 * 64
        l.record_checkpoint_adopt(1, 500, 10); // intra: 500
        l.record_param_merge(0, 240, 20, 1); // intra: 240
        l.record_cross_shard_publish(3, 96, 2, 0); // inter: 96
        l.record_cross_shard_publish(0, 96, 3, 2); // inter: 96, healed partition
        l.record_shard_adopt(4, 700, 12); // inter: 700
        assert_eq!(l.intra_shard_bytes(), 2 * 64 + 500 + 240);
        assert_eq!(l.inter_shard_bytes(), 96 + 96 + 700);
        assert_eq!(
            l.intra_shard_bytes() + l.inter_shard_bytes(),
            l.total_bytes()
        );
        assert_eq!(l.kind_bytes(CommKind::CrossShardPublish), 192);
        assert_eq!(l.kind_bytes(CommKind::ShardAdopt), 700);
        // cross-shard publishes carry the EM round as their step
        assert_eq!(l.rounds(CommKind::CrossShardPublish), 2);
        // staleness rides only on merges and healed cross-shard publishes
        assert!(l
            .events
            .iter()
            .filter(|e| e.kind != CommKind::ParamMerge
                && e.kind != CommKind::CrossShardPublish)
            .all(|e| e.staleness == 0));
    }

    #[test]
    fn cross_shard_kinds_are_flagged() {
        assert!(CommKind::CrossShardPublish.is_cross_shard());
        assert!(CommKind::ShardAdopt.is_cross_shard());
        for k in [
            CommKind::ScoreAllGather,
            CommKind::AssignmentBroadcast,
            CommKind::WeightTransfer,
            CommKind::SnapshotBroadcast,
            CommKind::GradAllReduce,
            CommKind::CheckpointAdopt,
            CommKind::ParamMerge,
            CommKind::ReplicaSync,
        ] {
            assert!(!k.is_cross_shard(), "{k:?} must be intra-shard");
        }
    }

    #[test]
    fn replica_sync_bytes_reconcile_against_moves() {
        let mut l = CommLedger::default();
        // epoch 1: two moves; epoch 2: one move; same 4 KiB expert
        l.record_replica_sync(1, 4096, 1);
        l.record_replica_sync(2, 4096, 1);
        l.record_replica_sync(0, 4096, 2);
        assert_eq!(l.kind_bytes(CommKind::ReplicaSync), 3 * 4096);
        assert_eq!(l.rounds(CommKind::ReplicaSync), 2, "one round per epoch");
        assert_eq!(l.inter_shard_bytes(), 0, "replica syncs stay in-domain");
        assert_eq!(l.intra_shard_bytes(), 3 * 4096);
    }

    #[test]
    fn merge_staleness_empty_is_zero() {
        let l = CommLedger::default();
        assert_eq!(l.max_merge_staleness(), 0);
        assert_eq!(l.kind_bytes(CommKind::ParamMerge), 0);
    }

    #[test]
    fn rounds_dedupe_by_step() {
        let mut l = CommLedger::default();
        l.record_score_allgather(2, 10, 0);
        l.record_score_allgather(2, 10, 0);
        l.record_score_allgather(2, 10, 1);
        assert_eq!(l.rounds(CommKind::ScoreAllGather), 2);
    }

    #[test]
    fn mixture_orders_of_magnitude_below_ddp() {
        // Scaled run: 4 routers, 100 rounds of 10k scores vs DDP of a 5M
        // param model for 400 steps on 4 nodes.
        let mut mix = CommLedger::default();
        for r in 0..100 {
            mix.record_score_allgather(4, 10_000, r);
        }
        let mut ddp = CommLedger::default();
        for s in 0..400 {
            ddp.record_ddp_allreduce(4, 5_000_000, s);
        }
        assert!(ddp.peak_node_bytes() > 100 * mix.peak_node_bytes());
    }
}
