//! Balanced assignment of sequences to experts (paper §2.2, Fig. 1).
//!
//! Input is a score matrix `nll[s][e]` — the negative log-likelihood of
//! sequence `s`'s prefix under router `e` (lower is better, Eq. 4).
//!
//! * **Inference** uses plain argmin (no capacity constraint).
//! * **Training** uses *balanced assignment*: each expert may receive at
//!   most `capacity` sequences. Sequences are processed in order of their
//!   best achievable score (`min_e nll`, i.e. the paper's sort by
//!   `-max_e log p(x|e)`), each taking its best-scoring expert that still
//!   has room. This avoids the Fig. 1a pathology where early arbitrary
//!   rows fill an expert that later, better-matched rows needed.

/// Assignment output: `expert[s]` for every sequence.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub expert_of: Vec<usize>,
    pub counts: Vec<usize>,
}

impl Assignment {
    /// Total NLL of the chosen assignments (the quantity EM minimizes).
    pub fn total_nll(&self, nll: &[Vec<f32>]) -> f64 {
        self.expert_of
            .iter()
            .enumerate()
            .map(|(s, &e)| nll[s][e] as f64)
            .sum()
    }

    /// Per-expert segment: indices of sequences assigned to `e`.
    pub fn segment(&self, e: usize) -> Vec<usize> {
        self.expert_of
            .iter()
            .enumerate()
            .filter_map(|(s, &x)| (x == e).then_some(s))
            .collect()
    }
}

fn n_experts(nll: &[Vec<f32>]) -> usize {
    nll.first().map(|r| r.len()).unwrap_or(0)
}

/// Unconstrained argmin assignment (inference-time routing, §2.2:
/// "During inference, no balancing is performed").
pub fn argmin_assign(nll: &[Vec<f32>]) -> Assignment {
    let e_count = n_experts(nll);
    let mut counts = vec![0usize; e_count];
    let expert_of = nll
        .iter()
        .map(|row| {
            let mut best = 0usize;
            for (e, &v) in row.iter().enumerate() {
                if v < row[best] {
                    best = e;
                }
            }
            counts[best] += 1;
            best
        })
        .collect();
    Assignment { expert_of, counts }
}

/// Balanced assignment with per-expert capacity (training-time, Fig. 1b).
///
/// `capacity` defaults to `ceil(n / E)` when `None`. Requires
/// `capacity * E >= n`.
pub fn balanced_assign(nll: &[Vec<f32>], capacity: Option<usize>) -> Assignment {
    let n = nll.len();
    let e_count = n_experts(nll);
    assert!(e_count > 0, "empty score matrix");
    let cap = capacity.unwrap_or(n.div_ceil(e_count));
    assert!(
        cap * e_count >= n,
        "capacity {cap} x {e_count} experts < {n} sequences"
    );

    // Sort sequence ids by their best score ascending (best-likelihood
    // first). Stable tie-break on index for determinism.
    let mut order: Vec<usize> = (0..n).collect();
    let best_score: Vec<f32> = nll
        .iter()
        .map(|row| row.iter().copied().fold(f32::INFINITY, f32::min))
        .collect();
    order.sort_by(|&a, &b| {
        best_score[a]
            .partial_cmp(&best_score[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    let mut counts = vec![0usize; e_count];
    let mut expert_of = vec![usize::MAX; n];
    // Per-sequence expert preference ranking is consulted lazily: walk the
    // row each time but skip full experts — E is small (<= 32).
    for &s in &order {
        let row = &nll[s];
        let mut best: Option<usize> = None;
        for e in 0..e_count {
            if counts[e] >= cap {
                continue;
            }
            match best {
                None => best = Some(e),
                Some(b) if row[e] < row[b] => best = Some(e),
                _ => {}
            }
        }
        let e = best.expect("capacity invariant guarantees a free expert");
        expert_of[s] = e;
        counts[e] += 1;
    }
    Assignment { expert_of, counts }
}

/// Sequential greedy baseline (Fig. 1a): assign rows in input order to
/// their best non-full expert. Kept as the ablation comparator.
pub fn sequential_assign(nll: &[Vec<f32>], capacity: Option<usize>) -> Assignment {
    let n = nll.len();
    let e_count = n_experts(nll);
    assert!(e_count > 0, "empty score matrix");
    let cap = capacity.unwrap_or(n.div_ceil(e_count));
    assert!(cap * e_count >= n);
    let mut counts = vec![0usize; e_count];
    let mut expert_of = vec![usize::MAX; n];
    for s in 0..n {
        let row = &nll[s];
        let mut best: Option<usize> = None;
        for e in 0..e_count {
            if counts[e] >= cap {
                continue;
            }
            match best {
                None => best = Some(e),
                Some(b) if row[e] < row[b] => best = Some(e),
                _ => {}
            }
        }
        let e = best.expect("capacity invariant");
        expert_of[s] = e;
        counts[e] += 1;
    }
    Assignment { expert_of, counts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// The Fig. 1 pathology: 3 sequences x 3 experts, capacity 1. Row 0
    /// arrives first and is nearly indifferent, but sequential assignment
    /// hands it expert 0 — which rows 1 and 2 *need* (their only good
    /// expert). Balanced assignment processes the strongly-matched rows
    /// first and recovers a much better total.
    #[test]
    fn figure1_example() {
        let nll = vec![
            vec![5.0, 5.1, 5.2], // indifferent
            vec![1.0, 9.0, 9.0], // only e0 works
            vec![1.1, 9.0, 9.0], // only e0 works
        ];
        let seq = sequential_assign(&nll, Some(1));
        let bal = balanced_assign(&nll, Some(1));
        // sequential: r0 grabs e0 => total 5.0 + 9.0 + 9.0 = 23.0
        assert!((seq.total_nll(&nll) - 23.0).abs() < 1e-6);
        // balanced: r1 (best 1.0) gets e0; r0 falls to a cheap alternative
        assert!((bal.total_nll(&nll) - 15.2).abs() < 1e-6);
        assert!(bal.total_nll(&nll) < seq.total_nll(&nll));
        assert_eq!(bal.counts, vec![1, 1, 1]);
        assert_eq!(bal.expert_of[1], 0);
    }

    #[test]
    fn argmin_matches_row_minimum() {
        let nll = vec![vec![3.0, 1.0], vec![0.5, 2.0], vec![2.0, 2.0]];
        let a = argmin_assign(&nll);
        assert_eq!(a.expert_of, vec![1, 0, 0]); // tie -> lowest index
        assert_eq!(a.counts, vec![2, 1]);
    }

    #[test]
    fn balanced_without_pressure_equals_argmin() {
        // plenty of capacity => same result as argmin
        let mut rng = Rng::new(3);
        let nll: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..4).map(|_| rng.f32() * 10.0).collect())
            .collect();
        let a = argmin_assign(&nll);
        let b = balanced_assign(&nll, Some(20));
        assert_eq!(a.expert_of, b.expert_of);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn infeasible_capacity_panics() {
        let nll = vec![vec![1.0], vec![1.0]];
        balanced_assign(&nll, Some(1));
    }

    #[test]
    fn segments_partition_sequences() {
        let mut rng = Rng::new(5);
        let nll: Vec<Vec<f32>> = (0..33)
            .map(|_| (0..4).map(|_| rng.f32()).collect())
            .collect();
        let a = balanced_assign(&nll, None);
        let mut all: Vec<usize> = (0..4).flat_map(|e| a.segment(e)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..33).collect::<Vec<_>>());
    }

    // ------------------ property tests ------------------

    fn random_matrix(rng: &mut Rng) -> Vec<Vec<f32>> {
        let n = 1 + rng.usize_below(60);
        let e = 1 + rng.usize_below(8);
        (0..n)
            .map(|_| (0..e).map(|_| rng.f32() * 20.0 - 5.0).collect())
            .collect()
    }

    #[test]
    fn prop_capacity_respected_and_total_assignment() {
        prop::check(
            "balanced-capacity",
            200,
            random_matrix,
            |nll| {
                let e = nll[0].len();
                let cap = nll.len().div_ceil(e);
                let a = balanced_assign(nll, None);
                if a.expert_of.len() != nll.len() {
                    return Err("not all sequences assigned".into());
                }
                if a.expert_of.iter().any(|&x| x >= e) {
                    return Err("invalid expert id".into());
                }
                if a.counts.iter().any(|&c| c > cap) {
                    return Err(format!("capacity violated: {:?} cap {cap}", a.counts));
                }
                let mut recount = vec![0usize; e];
                for &x in &a.expert_of {
                    recount[x] += 1;
                }
                if recount != a.counts {
                    return Err("counts mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Both assignments are greedy heuristics — neither dominates on every
    /// instance — but sorting by best score must win *in aggregate* (this
    /// is the paper's justification for Fig. 1b). Checked statistically
    /// over many random matrices under tight capacity.
    #[test]
    fn balanced_beats_sequential_on_average() {
        let mut rng = Rng::new(0xBA1A);
        let (mut bal_total, mut seq_total) = (0.0f64, 0.0f64);
        let mut bal_wins = 0usize;
        let cases = 300;
        for _ in 0..cases {
            let nll = random_matrix(&mut rng);
            let bal = balanced_assign(&nll, None).total_nll(&nll);
            let seq = sequential_assign(&nll, None).total_nll(&nll);
            bal_total += bal;
            seq_total += seq;
            if bal <= seq + 1e-9 {
                bal_wins += 1;
            }
        }
        assert!(
            bal_total < seq_total,
            "balanced {bal_total} >= sequential {seq_total} in aggregate"
        );
        assert!(bal_wins * 2 > cases, "balanced won only {bal_wins}/{cases}");
    }

    #[test]
    fn prop_argmin_is_lower_bound() {
        prop::check(
            "argmin-lower-bounds-balanced",
            200,
            random_matrix,
            |nll| {
                let free = argmin_assign(nll).total_nll(nll);
                let bal = balanced_assign(nll, None).total_nll(nll);
                if free <= bal + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("argmin {free} > balanced {bal}"))
                }
            },
        );
    }

    #[test]
    fn prop_deterministic() {
        prop::check(
            "assignment-deterministic",
            50,
            random_matrix,
            |nll| {
                if balanced_assign(nll, None) == balanced_assign(nll, None) {
                    Ok(())
                } else {
                    Err("nondeterministic".into())
                }
            },
        );
    }
}
