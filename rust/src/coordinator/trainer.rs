//! Event-driven trainer-node orchestration: the paper's "no need to
//! talk" property applied to *training*, not just serving.
//!
//! The classic [`run_pipeline`](super::pipeline::run_pipeline) is three
//! global barriers: EM finishes → a leader shards the whole expert corpus
//! → experts start in lockstep. This module replaces that with a set of
//! independent trainer nodes:
//!
//! * every expert node runs on a long-lived worker (a
//!   [`WorkQueue`]-backed pool — the same substrate the continuous-
//!   batching server uses), executing bounded **slices** of work and
//!   re-queueing itself, so a straggler node delays nobody;
//! * each node pulls fresh sequences from its **own** deterministic
//!   [`SequenceGen`] stream and routes them **locally** against a
//!   versioned router snapshot from the [`SnapshotStore`] — keeping the
//!   sequences whose argmin router is itself, discarding the rest. Nodes
//!   tolerate stale snapshots and pick refreshes up at their next
//!   routing call, without blocking; the broadcast of a snapshot is the
//!   mixture's *only* inter-node traffic
//!   ([`CommKind::SnapshotBroadcast`](super::comm::CommKind));
//! * nodes checkpoint periodically through
//!   [`model::checkpoint`](crate::model::checkpoint): a killed node
//!   resumes from its last checkpoint with a bit-identical continuation
//!   (same stream position via [`StreamPos`], same optimizer state, same
//!   not-yet-trained routed pool).
//!
//! **Staged mode** runs the *same* node machinery over pre-sharded
//! segments with the routers trained up front — reproducing the classic
//! pipeline's outputs bit-identically (it is the reference
//! `run_pipeline` now wraps). **Async mode** overlaps router EM with
//! expert training: the router leader (the orchestrator thread) trains
//! routers and publishes snapshots at EM-round boundaries
//! (`snapshot_every`), while expert nodes train continuously against
//! whatever snapshot they last saw.
//!
//! # Locking order (extends the table in `runtime/engine.rs`)
//!
//! * `SnapshotStore.inner` (Mutex + Condvar) — held only to swap/clone
//!   the `Arc` snapshot or to wait for the first publish; never held
//!   across routing, training, or any other lock.
//! * `SnapshotStore.ledger` (Mutex) — broadcast accounting; taken after
//!   `inner` is *released* during a publish, never nested.
//! * `WorkQueue` internals — queue mutation only (see
//!   `runtime/parallel.rs`); never held across a node slice.
//! * `outcomes` (Mutex) — completion slots, taken by a worker after a
//!   node finishes, never while holding anything else.
//! * `ErrSlot` — first-failure slot; flag checked lock-free, the slot
//!   lock never nested under anything else.
//!
//! Per-node state (stream, pool, cursor, counters, log) is owned by the
//! node object itself, which moves through the queue — exactly one
//! worker touches it at a time, so it needs no lock at all.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::comm::CommLedger;
use super::em::{train_routers, train_routers_hooked, EmConfig};
use super::expert::segment_batch;
use super::inference::Mixture;
use super::pipeline::{PipelineConfig, PipelineResult};
use super::scoring::score_matrix_rows_threaded;
use super::sharding::shard_corpus;
use crate::data::{Sequence, SequenceGen, DOMAINS};
use crate::metrics::RunLog;
use crate::model::checkpoint::{
    load_node_checkpoint, save_node_checkpoint, NodeCheckpoint, NodeCheckpointView,
    NODE_MODE_ASYNC, NODE_MODE_STAGED,
};
use crate::runtime::parallel::{resolve_threads, WorkQueue};
use crate::runtime::{Engine, TrainState, VariantMeta};
use crate::tokenizer::Bpe;

// -------------------------------------------------------------------------
// router snapshots
// -------------------------------------------------------------------------

/// One immutable, versioned copy of the router set — what an expert node
/// routes against. Nodes hold whatever version they last fetched; routing
/// under an older version than the store's latest is *expected* (that is
/// the "almost asynchronous" relaxation) and converges as nodes pick up
/// refreshes at their next routing call.
pub struct RouterSnapshot {
    /// Monotonic publish counter (1-based).
    pub version: u64,
    /// EM rounds completed when this snapshot was taken.
    pub em_round: usize,
    pub routers: Vec<TrainState>,
}

struct StoreInner {
    snap: Option<Arc<RouterSnapshot>>,
    closed: bool,
}

/// `Arc`-swapped registry of the latest router snapshot plus the comm
/// ledger of its broadcasts. Readers clone the `Arc` under a
/// momentarily-held lock (no blocking on publishers mid-routing);
/// [`SnapshotStore::wait_current`] blocks only before the *first*
/// publish. Closing the store (automatic when the orchestrator's router
/// driver returns) wakes any first-publish waiters; an already-published
/// snapshot keeps serving after close.
pub struct SnapshotStore {
    subscribers: usize,
    inner: Mutex<StoreInner>,
    cv: Condvar,
    ledger: Mutex<CommLedger>,
}

impl SnapshotStore {
    /// A store broadcasting to `subscribers` expert nodes.
    pub fn new(subscribers: usize) -> Self {
        SnapshotStore {
            subscribers,
            inner: Mutex::new(StoreInner {
                snap: None,
                closed: false,
            }),
            cv: Condvar::new(),
            ledger: Mutex::new(CommLedger::default()),
        }
    }

    pub fn subscribers(&self) -> usize {
        self.subscribers
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, StoreInner> {
        self.inner.lock().expect("snapshot store poisoned")
    }

    /// Publish a new snapshot, returning its version. Records one
    /// [`SnapshotBroadcast`](super::comm::CommKind::SnapshotBroadcast):
    /// the full router parameter set (f32) to every subscriber.
    pub fn publish(&self, routers: Vec<TrainState>, em_round: usize) -> u64 {
        let bytes: u64 = routers.iter().map(|r| r.params.len() as u64 * 4).sum();
        let mut g = self.lock();
        let version = g.snap.as_ref().map(|s| s.version).unwrap_or(0) + 1;
        g.snap = Some(Arc::new(RouterSnapshot {
            version,
            em_round,
            routers,
        }));
        drop(g);
        self.cv.notify_all();
        self.ledger
            .lock()
            .expect("snapshot ledger poisoned")
            .record_snapshot_broadcast(self.subscribers, bytes, version);
        version
    }

    /// The latest snapshot, if any was ever published. Never blocks.
    pub fn current(&self) -> Option<Arc<RouterSnapshot>> {
        self.lock().snap.clone()
    }

    /// Latest published version (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.lock().snap.as_ref().map(|s| s.version).unwrap_or(0)
    }

    /// The latest snapshot, blocking until the first publish. Errors if
    /// the store is closed while still empty (the router driver exited
    /// without ever publishing).
    pub fn wait_current(&self) -> Result<Arc<RouterSnapshot>> {
        let mut g = self.lock();
        loop {
            if let Some(s) = &g.snap {
                return Ok(s.clone());
            }
            if g.closed {
                bail!("snapshot store closed before any router snapshot was published");
            }
            g = self.cv.wait(g).expect("snapshot store poisoned");
        }
    }

    /// Close the store: wakes first-publish waiters. An existing snapshot
    /// keeps serving; only an empty closed store makes
    /// [`wait_current`](SnapshotStore::wait_current) fail.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Drain the broadcast ledger (the async run's full inter-node
    /// communication record).
    pub fn take_ledger(&self) -> CommLedger {
        std::mem::take(&mut *self.ledger.lock().expect("snapshot ledger poisoned"))
    }
}

struct CloseStoreOnDrop<'a>(&'a SnapshotStore);

impl Drop for CloseStoreOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

// -------------------------------------------------------------------------
// the model side, abstracted (testable without compiled artifacts)
// -------------------------------------------------------------------------

/// What a trainer node needs from the model side. The production
/// implementation is [`EngineBackend`]; tier-1 tests substitute
/// deterministic stubs so the orchestration (slicing, local routing,
/// checkpoint/resume, comm accounting) is testable without compiled
/// artifacts — the same pattern as the server's `ServeBackend`.
pub trait TrainBackend: Sync {
    /// Rows per training batch.
    fn train_batch_rows(&self) -> usize;
    /// Tokens consumed per training step (the `tokens` log series x-axis).
    fn tokens_per_step(&self) -> usize;
    /// Fresh expert state for `node` (deterministic per seed).
    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState>;
    /// One SGD step of `state` on `batch`; returns the batch loss.
    fn train_step(&self, node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32>;
    /// Local routing: the winning expert index per row under `snap`'s
    /// routers. Runs *inside* one node's worker — implementations should
    /// not fan out across threads of their own.
    fn route_local(&self, snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>>;
}

/// The real backend: engine-executed training steps and argmin
/// prefix-NLL routing (Eq. 4) under the snapshot's routers.
pub struct EngineBackend<'a> {
    pub engine: &'a Engine,
    pub router_meta: VariantMeta,
    pub expert_meta: VariantMeta,
    pub expert_variant: String,
    /// Routing prefix length M (training-time).
    pub prefix_len: usize,
}

impl TrainBackend for EngineBackend<'_> {
    fn train_batch_rows(&self) -> usize {
        self.expert_meta.train_batch
    }

    fn tokens_per_step(&self) -> usize {
        self.expert_meta.tokens_per_step()
    }

    fn init_expert(&self, _node: usize, seed: u64) -> Result<TrainState> {
        TrainState::init(self.engine, &self.expert_variant, seed)
    }

    fn train_step(&self, _node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        state.train_step(self.engine, batch, &self.expert_meta)
    }

    fn route_local(&self, snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        // one thread: the node *is* the unit of parallelism
        let nll = score_matrix_rows_threaded(
            self.engine,
            &snap.routers,
            &self.router_meta,
            rows,
            self.prefix_len,
            1,
        )?;
        Ok(nll
            .iter()
            .map(|row| {
                let mut best = 0usize;
                for (e, &v) in row.iter().enumerate() {
                    if v < row[best] {
                        best = e;
                    }
                }
                best
            })
            .collect())
    }
}

// -------------------------------------------------------------------------
// node run configuration / progress / outcomes
// -------------------------------------------------------------------------

/// Knobs shared by both orchestration modes (async-only fields are
/// ignored by staged runs).
#[derive(Clone, Debug)]
pub struct NodeRunConfig {
    /// SGD steps per node.
    pub steps_per_node: usize,
    /// Log the loss every `log_every` steps (and on the final step).
    pub log_every: usize,
    /// Checkpoint every `checkpoint_every` steps (0 = only the final
    /// checkpoint, which is always written when a directory is set).
    pub checkpoint_every: usize,
    /// Where node checkpoints live (`node{e}.ckpt`); `None` disables
    /// checkpointing entirely.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume each node from its checkpoint if one exists.
    pub resume: bool,
    /// Worker threads (0 = auto); nodes multiplex over fewer workers.
    pub threads: usize,
    /// Async: sequences drawn + locally routed per routing call
    /// (0 = the training batch size).
    pub route_chunk: usize,
    /// Async: max sequences a node may draw from its stream — the
    /// starvation valve for routers that assign a node (almost) nothing.
    /// 0 = auto: `2 × steps × batch × n_nodes` (twice the expected need
    /// at a uniform 1/E keep rate). Deterministic, so resume-exactness
    /// is unaffected.
    pub draw_budget: u64,
}

impl Default for NodeRunConfig {
    fn default() -> Self {
        NodeRunConfig {
            steps_per_node: 0,
            log_every: 10,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            threads: 0,
            route_chunk: 0,
            draw_budget: 0,
        }
    }
}

/// Lock-free per-node progress counters, readable by the router driver
/// through [`TrainerHandle`] while nodes run.
#[derive(Default)]
pub struct NodeProgress {
    steps: AtomicUsize,
    drawn: AtomicU64,
    kept: AtomicU64,
    snapshot_version: AtomicU64,
}

impl NodeProgress {
    pub fn steps(&self) -> usize {
        self.steps.load(Ordering::Relaxed)
    }
    pub fn drawn(&self) -> u64 {
        self.drawn.load(Ordering::Relaxed)
    }
    pub fn kept(&self) -> u64 {
        self.kept.load(Ordering::Relaxed)
    }
    pub fn snapshot_version(&self) -> u64 {
        self.snapshot_version.load(Ordering::Relaxed)
    }
}

/// What the orchestration driver (the router trainer) can observe while
/// expert nodes run: the snapshot store, live per-node progress, and
/// whether the run has already failed (so a polling driver can stop
/// waiting for progress that will never come).
pub struct TrainerHandle<'a> {
    store: Option<&'a SnapshotStore>,
    progress: &'a [NodeProgress],
    failed: &'a AtomicBool,
}

impl TrainerHandle<'_> {
    pub fn n_nodes(&self) -> usize {
        self.progress.len()
    }

    pub fn store(&self) -> Option<&SnapshotStore> {
        self.store
    }

    pub fn node(&self, node: usize) -> &NodeProgress {
        &self.progress[node]
    }

    /// Training steps completed across all nodes so far.
    pub fn total_steps_done(&self) -> usize {
        self.progress.iter().map(NodeProgress::steps).sum()
    }

    /// A node (or the driver itself, on a previous poll) already failed;
    /// the run will return that error once the pool drains.
    pub fn failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }
}

/// Everything one finished node produced.
pub struct NodeOutcome {
    pub node: usize,
    pub state: TrainState,
    pub log: RunLog,
    pub steps_done: usize,
    /// Sequences drawn from the node's stream (0 in staged mode).
    pub drawn: u64,
    /// Sequences the node routed to itself (0 in staged mode).
    pub kept: u64,
    /// Ground-truth domain histogram of the sequences actually trained
    /// on (async mode; empty-equivalent zeros in staged mode).
    pub domain_counts: Vec<u64>,
    /// Last snapshot version the node routed under.
    pub snapshot_version: u64,
    /// The node stopped early because its draw budget ran dry before the
    /// step budget was met.
    pub exhausted: bool,
}

impl NodeOutcome {
    /// Sequences this node trained on.
    pub fn trained_sequences(&self) -> u64 {
        self.domain_counts.iter().sum()
    }

    /// Plurality-domain fraction of the trained-on sequences (the async
    /// analogue of the staged segments' purity diagnostic).
    pub fn purity(&self) -> f64 {
        let total: u64 = self.domain_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let max = self.domain_counts.iter().copied().max().unwrap_or(0);
        max as f64 / total as f64
    }
}

// -------------------------------------------------------------------------
// the node itself
// -------------------------------------------------------------------------

/// Steps per scheduling slice: a node yields its worker after at most
/// this many training steps so siblings multiplex fairly over a smaller
/// worker pool. Pure scheduling granularity — results are identical at
/// any value.
const SLICE_STEPS: usize = 8;

enum Source<'env> {
    /// Staged mode: a pre-sharded segment, cycled by cursor (the classic
    /// pipeline's batch discipline — bit-identical to `train_expert`).
    Segment { seqs: Vec<Sequence>, cursor: u64 },
    /// Async mode: the node's own fresh-sequence stream plus the pool of
    /// sequences already routed to this node but not yet trained on.
    Stream {
        gen: SequenceGen<'env>,
        pool: VecDeque<Sequence>,
        route_chunk: usize,
        draw_budget: u64,
    },
}

struct Node<'env> {
    idx: usize,
    seed: u64,
    state: Option<TrainState>,
    source: Source<'env>,
    steps_done: usize,
    drawn: u64,
    kept: u64,
    domain_counts: Vec<u64>,
    snapshot_version: u64,
    log: RunLog,
    log_every: usize,
    finished: bool,
    exhausted: bool,
    last_saved: Option<usize>,
}

fn ckpt_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("node{idx}.ckpt"))
}

impl<'env> Node<'env> {
    fn staged(idx: usize, seed: u64, segment: Vec<Sequence>, cfg: &NodeRunConfig) -> Self {
        Node {
            idx,
            seed,
            state: None,
            source: Source::Segment {
                seqs: segment,
                cursor: 0,
            },
            steps_done: 0,
            drawn: 0,
            kept: 0,
            domain_counts: vec![0; DOMAINS],
            snapshot_version: 0,
            log: RunLog::new(),
            log_every: cfg.log_every.max(1),
            finished: false,
            exhausted: false,
            last_saved: None,
        }
    }

    fn stream(
        idx: usize,
        seed: u64,
        gen: SequenceGen<'env>,
        route_chunk: usize,
        draw_budget: u64,
        cfg: &NodeRunConfig,
    ) -> Self {
        Node {
            idx,
            seed,
            state: None,
            source: Source::Stream {
                gen,
                pool: VecDeque::new(),
                route_chunk: route_chunk.max(1),
                draw_budget,
            },
            steps_done: 0,
            drawn: 0,
            kept: 0,
            domain_counts: vec![0; DOMAINS],
            snapshot_version: 0,
            log: RunLog::new(),
            log_every: cfg.log_every.max(1),
            finished: false,
            exhausted: false,
            last_saved: None,
        }
    }

    fn publish_progress(&self, p: &NodeProgress) {
        p.steps.store(self.steps_done, Ordering::Relaxed);
        p.drawn.store(self.drawn, Ordering::Relaxed);
        p.kept.store(self.kept, Ordering::Relaxed);
        p.snapshot_version
            .store(self.snapshot_version, Ordering::Relaxed);
    }

    fn try_resume(&mut self, cfg: &NodeRunConfig) -> Result<()> {
        let Some(dir) = &cfg.checkpoint_dir else {
            return Ok(());
        };
        let path = ckpt_path(dir, self.idx);
        if !path.exists() {
            return Ok(());
        }
        let ck = load_node_checkpoint(&path)
            .with_context(|| format!("resuming node {} from {}", self.idx, path.display()))?;
        let NodeCheckpoint {
            node,
            mode,
            steps_done,
            cursor,
            stream,
            pool,
            domain_counts,
            drawn,
            kept,
            snapshot_version,
            state,
        } = ck;
        ensure!(
            node as usize == self.idx,
            "checkpoint {} belongs to node {node}, not node {}",
            path.display(),
            self.idx
        );
        let expect_mode = match self.source {
            Source::Segment { .. } => NODE_MODE_STAGED,
            Source::Stream { .. } => NODE_MODE_ASYNC,
        };
        ensure!(
            mode == expect_mode,
            "checkpoint {} was written in mode {mode}, run is mode {expect_mode} \
             (staged=0, async=1)",
            path.display()
        );
        match &mut self.source {
            Source::Segment { cursor: c, .. } => *c = cursor,
            Source::Stream { gen, pool: p, .. } => {
                let pos = stream.with_context(|| {
                    format!("async checkpoint {} missing its stream position", path.display())
                })?;
                gen.seek(&pos);
                *p = pool.into_iter().collect();
            }
        }
        ensure!(
            domain_counts.len() == self.domain_counts.len(),
            "checkpoint domain histogram has {} buckets, corpus has {}",
            domain_counts.len(),
            self.domain_counts.len()
        );
        self.steps_done = steps_done as usize;
        self.drawn = drawn;
        self.kept = kept;
        self.snapshot_version = snapshot_version;
        self.domain_counts = domain_counts;
        self.state = Some(state);
        self.last_saved = Some(self.steps_done);
        Ok(())
    }

    fn save_checkpoint(&mut self, cfg: &NodeRunConfig) -> Result<()> {
        let Some(dir) = &cfg.checkpoint_dir else {
            return Ok(());
        };
        let state = self
            .state
            .as_ref()
            .expect("state initialized before any checkpoint");
        let (mode, cursor, stream, pool): (u8, u64, _, &[Sequence]) = match &mut self.source {
            Source::Segment { cursor, .. } => (NODE_MODE_STAGED, *cursor, None, &[]),
            Source::Stream { gen, pool, .. } => {
                // make_contiguous: a borrowed view of the pool, no token
                // clones per checkpoint
                (NODE_MODE_ASYNC, 0, Some(gen.pos()), &*pool.make_contiguous())
            }
        };
        let view = NodeCheckpointView {
            node: self.idx as u32,
            mode,
            steps_done: self.steps_done as u64,
            cursor,
            stream,
            pool,
            domain_counts: &self.domain_counts,
            drawn: self.drawn,
            kept: self.kept,
            snapshot_version: self.snapshot_version,
            state,
        };
        save_node_checkpoint(&view, ckpt_path(dir, self.idx))
            .with_context(|| format!("checkpointing node {}", self.idx))?;
        self.last_saved = Some(self.steps_done);
        Ok(())
    }

    /// Run up to [`SLICE_STEPS`] training steps, then yield the worker.
    fn run_slice<B: TrainBackend>(
        &mut self,
        backend: &B,
        store: Option<&SnapshotStore>,
        cfg: &NodeRunConfig,
        n_nodes: usize,
        progress: &NodeProgress,
    ) -> Result<()> {
        if let Source::Segment { seqs, .. } = &self.source {
            // same contract (and message) as the classic expert trainer
            ensure!(!seqs.is_empty(), "cannot train on an empty segment");
        }
        if self.state.is_none() {
            self.state = Some(backend.init_expert(self.idx, self.seed)?);
        }
        let bs = backend.train_batch_rows().max(1);
        let mut slice = 0usize;
        while !self.finished && self.steps_done < cfg.steps_per_node && slice < SLICE_STEPS {
            let loss = match &mut self.source {
                Source::Segment { seqs, cursor } => {
                    let batch = segment_batch(seqs, cursor, bs);
                    let state = self.state.as_mut().expect("initialized above");
                    backend.train_step(self.idx, state, &batch)?
                }
                Source::Stream {
                    gen,
                    pool,
                    route_chunk,
                    draw_budget,
                } => {
                    // fill the pool to one batch by drawing + locally
                    // routing chunks of the node's own stream
                    while pool.len() < bs && self.drawn < *draw_budget {
                        let want = (*route_chunk).min((*draw_budget - self.drawn) as usize).max(1);
                        let chunk = gen.batch(want);
                        self.drawn += chunk.len() as u64;
                        let snap = store
                            .expect("stream nodes always run with a snapshot store")
                            .wait_current()?;
                        if snap.version != self.snapshot_version {
                            self.snapshot_version = snap.version;
                            self.log.scalar(
                                "snapshot_version",
                                self.steps_done as f64,
                                snap.version as f64,
                            );
                        }
                        let rows: Vec<&[u32]> =
                            chunk.iter().map(|s| s.tokens.as_slice()).collect();
                        let routes = backend.route_local(&snap, &rows)?;
                        ensure!(
                            routes.len() == rows.len(),
                            "backend routed {} of {} rows",
                            routes.len(),
                            rows.len()
                        );
                        drop(rows);
                        for (seq, &e) in chunk.into_iter().zip(&routes) {
                            ensure!(
                                e < n_nodes,
                                "route index {e} out of range for {n_nodes} expert nodes"
                            );
                            if e == self.idx {
                                pool.push_back(seq);
                                self.kept += 1;
                            }
                        }
                        progress.drawn.store(self.drawn, Ordering::Relaxed);
                        progress.kept.store(self.kept, Ordering::Relaxed);
                        progress
                            .snapshot_version
                            .store(self.snapshot_version, Ordering::Relaxed);
                    }
                    if pool.len() < bs {
                        // draw budget dry before the step budget: finish
                        // early (deterministically — the budget is a
                        // draw count, not a clock)
                        self.exhausted = true;
                        break;
                    }
                    let batch_seqs: Vec<Sequence> = pool.drain(..bs).collect();
                    let rows: Vec<&[u32]> =
                        batch_seqs.iter().map(|s| s.tokens.as_slice()).collect();
                    let state = self.state.as_mut().expect("initialized above");
                    let loss = backend.train_step(self.idx, state, &rows)?;
                    drop(rows);
                    for s in &batch_seqs {
                        if let Some(c) = self.domain_counts.get_mut(s.domain) {
                            *c += 1;
                        }
                    }
                    loss
                }
            };
            self.steps_done += 1;
            progress.steps.store(self.steps_done, Ordering::Relaxed);
            let step0 = self.steps_done - 1;
            if step0 % self.log_every == 0 || self.steps_done == cfg.steps_per_node {
                let st = self.state.as_ref().expect("initialized above");
                self.log.scalar("loss", st.step as f64, loss as f64);
                self.log.scalar(
                    "tokens",
                    (st.step as usize * backend.tokens_per_step()) as f64,
                    loss as f64,
                );
            }
            if cfg.checkpoint_every > 0 && self.steps_done % cfg.checkpoint_every == 0 {
                self.save_checkpoint(cfg)?;
            }
            slice += 1;
        }
        if self.steps_done >= cfg.steps_per_node || self.exhausted {
            if self.exhausted && !self.finished {
                self.log
                    .scalar("stream_exhausted", self.steps_done as f64, 1.0);
            }
            self.finished = true;
            if cfg.checkpoint_dir.is_some() && self.last_saved != Some(self.steps_done) {
                self.save_checkpoint(cfg)?;
            }
        }
        Ok(())
    }

    fn into_outcome(self) -> NodeOutcome {
        NodeOutcome {
            node: self.idx,
            state: self.state.expect("finished nodes are initialized"),
            log: self.log,
            steps_done: self.steps_done,
            drawn: self.drawn,
            kept: self.kept,
            domain_counts: self.domain_counts,
            snapshot_version: self.snapshot_version,
            exhausted: self.exhausted,
        }
    }
}

// -------------------------------------------------------------------------
// the worker pool
// -------------------------------------------------------------------------

/// First-failure slot (flag checked lock-free on hot paths).
#[derive(Default)]
struct ErrSlot {
    set: AtomicBool,
    err: Mutex<Option<anyhow::Error>>,
}

impl ErrSlot {
    fn is_set(&self) -> bool {
        self.set.load(Ordering::Relaxed)
    }

    fn record(&self, e: anyhow::Error) {
        let mut slot = self.err.lock().expect("error slot poisoned");
        if slot.is_none() {
            *slot = Some(e);
        }
        self.set.store(true, Ordering::Relaxed);
    }

    fn take(&self) -> Option<anyhow::Error> {
        self.err.lock().expect("error slot poisoned").take()
    }
}

/// A node leaves the run (finished, errored, or aborted): close the
/// queue once the last one is accounted for, releasing the workers.
fn retire_node(remaining: &AtomicUsize, queue: &WorkQueue<Node<'_>>) {
    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        queue.close();
    }
}

#[allow(clippy::too_many_arguments)]
fn node_worker<'env, B: TrainBackend>(
    backend: &B,
    store: Option<&SnapshotStore>,
    cfg: &NodeRunConfig,
    queue: &WorkQueue<Node<'env>>,
    outcomes: &Mutex<Vec<Option<NodeOutcome>>>,
    progress: &[NodeProgress],
    error: &ErrSlot,
    remaining: &AtomicUsize,
) {
    while let Some(mut node) = queue.pop() {
        if error.is_set() {
            // shutting down: the node keeps its last checkpoint
            retire_node(remaining, queue);
            continue;
        }
        let idx = node.idx;
        match node.run_slice(backend, store, cfg, progress.len(), &progress[idx]) {
            Err(e) => {
                error.record(e.context(format!("trainer node {idx}")));
                if let Some(st) = store {
                    st.close(); // wake any first-publish waiter
                }
                retire_node(remaining, queue);
            }
            Ok(()) => {
                if node.finished {
                    outcomes.lock().expect("outcomes poisoned")[idx] = Some(node.into_outcome());
                    retire_node(remaining, queue);
                } else if error.is_set() || !queue.push(node) {
                    retire_node(remaining, queue);
                }
            }
        }
    }
}

fn run_nodes_inner<'env, B, R, F>(
    backend: &B,
    store: Option<&SnapshotStore>,
    mut nodes: Vec<Node<'env>>,
    cfg: &NodeRunConfig,
    driver: F,
) -> Result<(Vec<NodeOutcome>, R)>
where
    B: TrainBackend,
    F: FnOnce(&TrainerHandle<'_>) -> Result<R>,
{
    let n = nodes.len();
    if cfg.resume {
        for node in &mut nodes {
            node.try_resume(cfg)?;
        }
    }
    let progress: Vec<NodeProgress> = (0..n).map(|_| NodeProgress::default()).collect();
    for node in &nodes {
        node.publish_progress(&progress[node.idx]);
    }
    let queue: WorkQueue<Node<'env>> = WorkQueue::new();
    let outcomes: Mutex<Vec<Option<NodeOutcome>>> = Mutex::new((0..n).map(|_| None).collect());
    let error = ErrSlot::default();
    let remaining = AtomicUsize::new(n);
    let workers = resolve_threads(cfg.threads).max(1).min(n.max(1));
    if n == 0 {
        queue.close();
    }

    let driver_out = std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                node_worker(
                    backend, store, cfg, &queue, &outcomes, &progress, &error, &remaining,
                )
            });
        }
        queue.push_all(nodes);
        // the store must not outlive the router driver un-closed: a node
        // waiting for a first publish that will never come has to wake
        let _close_store = store.map(CloseStoreOnDrop);
        let handle = TrainerHandle {
            store,
            progress: &progress,
            failed: &error.set,
        };
        match driver(&handle) {
            Ok(r) => Some(r),
            Err(e) => {
                error.record(e.context("router driver"));
                None
            }
        }
    });

    if let Some(e) = error.take() {
        return Err(e);
    }
    let driver_out = driver_out.expect("driver result present when no error was recorded");
    let slots = outcomes.into_inner().expect("outcomes poisoned");
    let mut out = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| anyhow!("node {i} finished without an outcome"))?);
    }
    Ok((out, driver_out))
}

/// Staged mode: run each `(seed, segment)` job as a node over the worker
/// pool. Per-node trajectories depend only on their own seed + segment,
/// so outcomes are bit-identical at any worker count — and identical to
/// the classic sequential expert loop.
pub fn run_staged_nodes<B: TrainBackend>(
    backend: &B,
    jobs: Vec<(u64, Vec<Sequence>)>,
    cfg: &NodeRunConfig,
) -> Result<Vec<NodeOutcome>> {
    let nodes: Vec<Node<'static>> = jobs
        .into_iter()
        .enumerate()
        .map(|(e, (seed, segment))| Node::staged(e, seed, segment, cfg))
        .collect();
    let (outcomes, ()) = run_nodes_inner(backend, None, nodes, cfg, |_| Ok(()))?;
    Ok(outcomes)
}

/// Async mode: every `(seed, stream)` job becomes an independent trainer
/// node that draws from its own stream and routes locally against
/// `store`'s latest snapshot; `driver` runs on the calling thread (the
/// router leader) and publishes snapshots while nodes train. Returns the
/// node outcomes plus the driver's result.
pub fn run_async_nodes<'env, B, R, F>(
    backend: &B,
    store: &SnapshotStore,
    jobs: Vec<(u64, SequenceGen<'env>)>,
    cfg: &NodeRunConfig,
    driver: F,
) -> Result<(Vec<NodeOutcome>, R)>
where
    B: TrainBackend,
    F: FnOnce(&TrainerHandle<'_>) -> Result<R>,
{
    let n = jobs.len();
    let bs = backend.train_batch_rows().max(1);
    let auto = (cfg.steps_per_node as u64)
        .saturating_mul(bs as u64)
        .saturating_mul(n.max(1) as u64)
        .saturating_mul(2);
    let draw_budget = if cfg.draw_budget > 0 {
        cfg.draw_budget
    } else {
        auto.max(1)
    };
    let route_chunk = if cfg.route_chunk > 0 { cfg.route_chunk } else { bs };
    let nodes: Vec<Node<'env>> = jobs
        .into_iter()
        .enumerate()
        .map(|(e, (seed, gen))| Node::stream(e, seed, gen, route_chunk, draw_budget, cfg))
        .collect();
    run_nodes_inner(backend, Some(store), nodes, cfg, driver)
}

// -------------------------------------------------------------------------
// production orchestration
// -------------------------------------------------------------------------

/// Which orchestration the trainer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Router EM → leader-sharded corpus → node-pool expert training.
    /// Bit-identical to the classic `run_pipeline` (it is its
    /// implementation now); snapshots refresh only at the EM/shard
    /// boundary, i.e. never during expert training.
    Staged,
    /// Expert nodes start immediately and train continuously against
    /// versioned router snapshots published at EM-round boundaries; no
    /// global barrier, no corpus-wide score all-gather — snapshot
    /// broadcasts are the only inter-node traffic.
    Async,
}

/// Orchestrator configuration on top of a [`PipelineConfig`].
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub mode: TrainMode,
    /// Node-checkpoint directory (`node{e}.ckpt`); `None` disables.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every N steps (0 = final checkpoint only).
    pub checkpoint_every: usize,
    /// Resume nodes from existing checkpoints. Router EM and (in staged
    /// mode) the sharding are deterministically re-derived; only the
    /// expensive expert training resumes mid-run.
    pub resume: bool,
    /// Async: publish a router snapshot every N EM rounds (the final
    /// round always publishes; 0 behaves as 1).
    pub snapshot_every: usize,
    /// Async: sequences per local routing call (0 = router prefix batch).
    pub route_chunk: usize,
    /// Async: per-node stream draw cap (0 = auto; see
    /// [`NodeRunConfig::draw_budget`]).
    pub draw_budget: u64,
}

impl TrainerConfig {
    pub fn staged() -> Self {
        TrainerConfig {
            mode: TrainMode::Staged,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            snapshot_every: 1,
            route_chunk: 0,
            draw_budget: 0,
        }
    }

    pub fn asynchronous() -> Self {
        TrainerConfig {
            mode: TrainMode::Async,
            ..TrainerConfig::staged()
        }
    }
}

/// Run mixture training under either orchestration mode. Staged mode
/// reproduces the classic `run_pipeline` outputs bit-identically; async
/// mode returns the same [`PipelineResult`] shape with the ledger
/// holding snapshot broadcasts instead of score all-gathers, and the
/// segment size/purity diagnostics computed from what each node actually
/// trained on.
pub fn run_trainer(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
) -> Result<PipelineResult> {
    let router_meta = engine.variant(&p.router_variant)?.clone();
    let expert_meta = engine.variant(&p.expert_variant)?.clone();
    ensure!(
        router_meta.seq_len == expert_meta.seq_len,
        "router/expert seq_len mismatch"
    );
    let backend = EngineBackend {
        engine,
        router_meta: router_meta.clone(),
        expert_meta: expert_meta.clone(),
        expert_variant: p.expert_variant.clone(),
        prefix_len: p.prefix_len,
    };
    let em = EmConfig {
        n_routers: p.n_experts,
        rounds: p.em_rounds,
        chunk_size: p.em_chunk,
        steps_per_round: p.em_steps_per_round,
        prefix_len: p.prefix_len,
        seed: p.seed,
        threads: p.threads,
    };
    let run_cfg = NodeRunConfig {
        steps_per_node: p.expert_steps,
        log_every: 10,
        checkpoint_every: t.checkpoint_every,
        checkpoint_dir: t.checkpoint_dir.clone(),
        resume: t.resume,
        threads: p.threads,
        route_chunk: if t.route_chunk > 0 {
            t.route_chunk
        } else {
            router_meta.prefix_batch.max(1)
        },
        draw_budget: t.draw_budget,
    };
    match t.mode {
        TrainMode::Staged => {
            run_trainer_staged(engine, bpe, p, &em, &run_cfg, &backend, expert_meta)
        }
        TrainMode::Async => run_trainer_async(
            engine,
            bpe,
            p,
            t,
            &em,
            &run_cfg,
            &backend,
            router_meta,
            expert_meta,
        ),
    }
}

fn engine_transfer_scalars(engine: &Engine, log: &mut RunLog) {
    // Transfer accounting: engine-lifetime totals at completion, so run
    // records show what the device-resident buffer cache saved.
    let stats = engine.stats();
    log.scalar("engine/h2d_bytes", 0.0, stats.h2d_bytes as f64);
    log.scalar("engine/d2h_bytes", 0.0, stats.d2h_bytes as f64);
    log.scalar("engine/h2d_bytes_avoided", 0.0, stats.h2d_bytes_avoided as f64);
    log.scalar("engine/uploads_avoided", 0.0, stats.uploads_avoided as f64);
    log.scalar("engine/param_uploads", 0.0, stats.param_uploads as f64);
}

fn run_trainer_staged(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    let mut ledger = CommLedger::default();
    let mut log = RunLog::new();

    // Stage 1: routers (Alg. 1 lines 1-10).
    let mut router_gen = SequenceGen::new(bpe, backend.router_meta.seq_len, p.seed ^ 0x52_0000);
    let trained = train_routers(
        engine,
        &p.router_variant,
        em,
        &mut router_gen,
        &mut ledger,
        &mut log,
    )?;

    // Stage 2: shard the expert corpus (lines 12-13); single-epoch data,
    // so the corpus at least covers every expert's step budget.
    let needed = p.n_experts * p.expert_steps * expert_meta.train_batch;
    let n_shard = p.shard_sequences.max(needed);
    let threads = resolve_threads(p.threads);
    let mut shard_gen = SequenceGen::new(bpe, expert_meta.seq_len, p.seed ^ 0x5AD);
    let shards = shard_corpus(
        engine,
        &trained.routers,
        &trained.meta,
        &mut shard_gen,
        n_shard,
        p.prefix_len,
        &mut ledger,
        threads,
    )?;
    let segment_purity = shards.segment_purity();
    let segment_sizes: Vec<usize> = shards.segments.iter().map(Vec::len).collect();

    // Stage 3: independent experts (lines 14-16) as staged nodes on the
    // worker pool — same seeds, same segments, same batch discipline as
    // the classic loop, so outputs are bit-identical at any worker count.
    let jobs: Vec<(u64, Vec<Sequence>)> = shards
        .segments
        .into_iter()
        .enumerate()
        .map(|(e, segment)| (p.seed ^ (0xE0 + e as u64), segment))
        .collect();
    let outcomes = run_staged_nodes(backend, jobs, run_cfg)?;
    let mut experts = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        log.merge_prefixed(&format!("expert{}", o.node), &o.log);
        experts.push(o.state);
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
    })
}

#[allow(clippy::too_many_arguments)]
fn run_trainer_async(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend,
    router_meta: VariantMeta,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    ensure!(
        p.em_rounds > 0,
        "async training needs at least one EM round to publish a router snapshot"
    );
    let mut log = RunLog::new();
    let store = SnapshotStore::new(p.n_experts);
    let every = t.snapshot_every.max(1);
    let rounds = em.rounds;

    // One independent fresh-data stream per node; the router leader keeps
    // the same stream it uses in staged mode.
    let jobs: Vec<_> = (0..p.n_experts)
        .map(|e| {
            (
                p.seed ^ (0xE0 + e as u64),
                SequenceGen::new(bpe, expert_meta.seq_len, p.seed ^ (0xA5_0000 + e as u64)),
            )
        })
        .collect();

    let em_cfg = em.clone();
    let (outcomes, trained) = {
        let log = &mut log;
        let store_ref = &store;
        run_async_nodes(backend, store_ref, jobs, run_cfg, move |_handle| {
            // Router EM runs on this (leader) thread while nodes train.
            // Its score exchanges are leader-local (all routers live
            // here), so they cost the cluster nothing — the broadcasts
            // recorded by the store are the only inter-node traffic.
            let mut local_ledger = CommLedger::default();
            let mut router_gen =
                SequenceGen::new(bpe, router_meta.seq_len, p.seed ^ 0x52_0000);
            train_routers_hooked(
                engine,
                &p.router_variant,
                &em_cfg,
                &mut router_gen,
                &mut local_ledger,
                log,
                |round, routers| {
                    if (round + 1) % every == 0 || round + 1 == rounds {
                        store_ref.publish(routers.to_vec(), round + 1);
                    }
                    Ok(())
                },
            )
        })?
    };

    let ledger = store.take_ledger();
    let mut experts = Vec::with_capacity(outcomes.len());
    let mut segment_purity = Vec::with_capacity(outcomes.len());
    let mut segment_sizes = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        log.merge_prefixed(&format!("expert{}", o.node), &o.log);
        log.scalar(&format!("async/node{}_drawn", o.node), 0.0, o.drawn as f64);
        log.scalar(&format!("async/node{}_kept", o.node), 0.0, o.kept as f64);
        log.scalar(
            &format!("async/node{}_steps", o.node),
            0.0,
            o.steps_done as f64,
        );
        segment_purity.push(o.purity());
        segment_sizes.push(o.trained_sequences() as usize);
        experts.push(o.state);
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_publishes_versions_and_records_broadcasts() {
        let store = SnapshotStore::new(4);
        assert_eq!(store.version(), 0);
        assert!(store.current().is_none());
        let r = TrainState::from_params("r", vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], 0);
        assert_eq!(store.publish(vec![r.clone(), r.clone()], 1), 1);
        assert_eq!(store.publish(vec![r], 2), 2);
        assert_eq!(store.version(), 2);
        let snap = store.current().unwrap();
        assert_eq!(snap.version, 2);
        assert_eq!(snap.em_round, 2);
        let ledger = store.take_ledger();
        // publish 1: two 8-param routers = 64 B/subscriber; publish 2: 32 B
        assert_eq!(
            ledger.rounds(crate::coordinator::comm::CommKind::SnapshotBroadcast),
            2
        );
        assert_eq!(ledger.total_bytes(), 4 * 64 + 4 * 32);
    }

    #[test]
    fn closed_empty_store_fails_waiters() {
        let store = SnapshotStore::new(1);
        store.close();
        let err = store.wait_current().unwrap_err().to_string();
        assert!(err.contains("closed before any"), "{err}");
    }

    #[test]
    fn closed_store_with_snapshot_keeps_serving() {
        let store = SnapshotStore::new(1);
        let r = TrainState::from_params("r", vec![1.0], vec![0.0], vec![0.0], 0);
        store.publish(vec![r], 1);
        store.close();
        assert_eq!(store.wait_current().unwrap().version, 1);
        assert_eq!(store.current().unwrap().version, 1);
    }

    #[test]
    fn trainer_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SnapshotStore>();
        assert_send_sync::<RouterSnapshot>();
        assert_send_sync::<NodeProgress>();
        assert_send_sync::<NodeOutcome>();
    }
}
