//! Event-driven trainer-node orchestration: the paper's "no need to
//! talk" property applied to *training*, not just serving.
//!
//! The classic [`run_pipeline`](super::pipeline::run_pipeline) is three
//! global barriers: EM finishes → a leader shards the whole expert corpus
//! → experts start in lockstep. This module replaces that with a set of
//! independent trainer nodes:
//!
//! * every expert node runs on a long-lived worker (a
//!   [`WorkQueue`]-backed pool — the same substrate the continuous-
//!   batching server uses), executing bounded **slices** of work and
//!   re-queueing itself, so a straggler node delays nobody;
//! * each node pulls fresh sequences from its **own** deterministic
//!   [`SequenceGen`] stream and routes them **locally** against a
//!   versioned router snapshot from the [`SnapshotStore`] — keeping the
//!   sequences whose argmin router is itself, discarding the rest. Nodes
//!   tolerate stale snapshots and pick refreshes up at their next
//!   routing call, without blocking; the broadcast of a snapshot is the
//!   mixture's *only* inter-node traffic
//!   ([`CommKind::SnapshotBroadcast`](super::comm::CommKind));
//! * nodes checkpoint periodically through
//!   [`model::checkpoint`](crate::model::checkpoint): a killed node
//!   resumes from its last checkpoint with a bit-identical continuation
//!   (same stream position via [`StreamPos`], same optimizer state, same
//!   not-yet-trained routed pool).
//!
//! **Staged mode** runs the *same* node machinery over pre-sharded
//! segments with the routers trained up front — reproducing the classic
//! pipeline's outputs bit-identically (it is the reference
//! `run_pipeline` now wraps). **Async mode** overlaps router EM with
//! expert training: the router leader (the orchestrator thread) trains
//! routers and publishes snapshots at EM-round boundaries
//! (`snapshot_every`), while expert nodes train continuously against
//! whatever snapshot they last saw.
//!
//! # Locking order (extends the table in `runtime/engine.rs`)
//!
//! * `SnapshotStore.inner` (Mutex + Condvar) — held only to swap/clone
//!   the `Arc` snapshot or to wait for the first publish; never held
//!   across routing, training, or any other lock.
//! * `SnapshotStore.ledger` (Mutex) — broadcast accounting; taken after
//!   `inner` is *released* during a publish, never nested.
//! * `WorkQueue` internals — queue mutation only (see
//!   `runtime/parallel.rs`); never held across a node slice.
//! * `outcomes` (Mutex) — completion slots, taken by a worker after a
//!   node finishes, never while holding anything else.
//! * `ErrSlot` — first-failure slot; flag checked lock-free, the slot
//!   lock never nested under anything else.
//!
//! Per-node state (stream, pool, cursor, counters, log) is owned by the
//! node object itself, which moves through the queue — exactly one
//! worker touches it at a time, so it needs no lock at all.
//!
//! # Failure model (elastic mode — [`run_elastic_nodes`])
//!
//! Faults are injected deterministically through a
//! [`FaultPlan`](super::chaos::FaultPlan) (step/version-keyed, never
//! wall-clock) and membership changes through an [`ElasticPlan`] /
//! [`ElasticHandle`]; every fault path produces a structured outcome —
//! **never a panic**.
//!
//! **Tolerated** (the run self-heals, bit-identically where stated):
//! * *Node kill*: the seat's replacement adopts the last v2/STLN
//!   checkpoint — exact stream position + routed pool — and resumes; a
//!   kill at a checkpoint boundary loses zero steps and the continuation
//!   is bit-identical. Each adoption records a
//!   [`CheckpointAdopt`](super::comm::CommKind::CheckpointAdopt) ledger
//!   event (bytes = checkpoint file size) plus `steps_lost`/recovery
//!   time in [`ElasticStats`].
//! * *Transient backend errors* (chain downcasts to
//!   [`TransientFault`](super::chaos::TransientFault)): retried with
//!   linear backoff up to [`ElasticPolicy::max_retries`].
//! * *Slow nodes / stalls*: other nodes are never blocked (no barrier);
//!   a stalled node just routes against a staler snapshot.
//! * *Dropped snapshot deliveries*: the node keeps routing under the
//!   last snapshot it actually received; only adoption *timing* shifts —
//!   ledger accounting is unaffected (the publisher did send it).
//! * *Leave + rejoin*: a departing node's checkpoint anchors its seat;
//!   its offline trajectory merges back through a delayed-Nesterov outer
//!   update (Async Local-SGD) recorded as a
//!   [`ParamMerge`](super::comm::CommKind::ParamMerge) event carrying
//!   the snapshot-version staleness of the merge.
//! * *Join / expert-count growth*: a new seat is seeded from the nearest
//!   router snapshot via [`TrainBackend::init_joiner`].
//!
//! **Degrades** (run completes, quality reduced, recorded in the
//! report): a node whose retries exhaust — or that hits a non-transient
//! error — ends as [`NodeEnd::Failed`] with whatever state could be
//! salvaged; surviving nodes finish normally. The run returns `Ok` as
//! long as **at least one node survives**.
//!
//! **Aborts** (structured `Err`, never a hang): every node failed; the
//! router driver itself failed; or a node is orphaned — waiting on a
//! first snapshot longer than [`NodeRunConfig::snapshot_wait_us`] after
//! the store closed or timed out.
//!
//! # Shard-level failure model (fleet mode — [`run_sharded_nodes`](super::fleet::run_sharded_nodes))
//!
//! [`super::fleet`] partitions the expert seats across several
//! `SnapshotStore` domains — one router leader per shard — and makes the
//! *shard* a fault unit on top of the node-level model above:
//!
//! * **Fault units.** Node faults stay node-scoped (a shard-local
//!   [`FaultPlan`] derived from the fleet plan by membership). Shard
//!   faults — `partition`, `leader loss`, `shard kill` — are keyed on EM
//!   rounds or local steps, never wall-clock, so fleet replays are
//!   bit-identical under [`FaultPlan::reset`].
//! * **Partition.** A partitioned shard neither sends nor receives
//!   cross-shard router publishes for the cut rounds (a symmetric cut).
//!   Its members keep training against stale held copies of foreign
//!   router blocks; on heal, each healed edge catches up through the
//!   same delayed-Nesterov outer update as rejoin merges, with
//!   *staleness = rounds missed* recorded on the
//!   [`CrossShardPublish`](super::comm::CommKind::CrossShardPublish)
//!   event. Each shard stays authoritative for its own router block, so
//!   the final global router set is partition-independent.
//! * **Promotion (leader loss).** At the faulted round boundary the next
//!   surviving member is promoted deterministically (member order), and
//!   adopts the dead leader's router checkpoint — one
//!   [`ShardAdopt`](super::comm::CommKind::ShardAdopt) transfer of the
//!   block. The round's publish is re-derived by the promoted member, so
//!   promotion perturbs accounting, never math.
//! * **Shard kill.** Every seat of the shard dies at the planned local
//!   step; each seat is re-adopted from its member checkpoint (the
//!   node-level adoption machinery), with the transfers audited as
//!   `ShardAdopt` (a fault-domain crossing) instead of in-shard
//!   `CheckpointAdopt`, and re-done steps counted in
//!   [`ElasticStats::steps_lost`].
//! * **Ledger contract.** [`CommLedger`] partitions exactly into
//!   intra-shard bytes (snapshot broadcasts, in-shard adoptions, merges)
//!   and inter-shard bytes (`CrossShardPublish` + `ShardAdopt`); cross-
//!   shard events carry their EM round as `step` and are recorded *only*
//!   at round boundaries — inter-shard bytes between boundaries are
//!   structurally zero. A fleet run returns `Ok` whenever at least one
//!   shard survives.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::chaos::{is_transient, FaultPlan, TransientFault};
use super::comm::CommLedger;
use super::em::{train_routers, train_routers_hooked, EmConfig};
use super::expert::segment_batch;
use super::inference::Mixture;
use super::pipeline::{PipelineConfig, PipelineResult};
use super::scoring::score_matrix_rows_threaded;
use super::sharding::shard_corpus;
use crate::data::{Sequence, SequenceGen, DOMAINS};
use crate::metrics::RunLog;
use crate::model::checkpoint::{
    load_node_checkpoint, save_node_checkpoint, sweep_stale_temps, NodeCheckpoint,
    NodeCheckpointView, NODE_MODE_ASYNC, NODE_MODE_STAGED,
};
use crate::runtime::parallel::{resolve_threads, WorkQueue};
use crate::runtime::{Engine, TrainState, VariantMeta};
use crate::tokenizer::Bpe;

// -------------------------------------------------------------------------
// router snapshots
// -------------------------------------------------------------------------

/// One immutable, versioned copy of the router set — what an expert node
/// routes against. Nodes hold whatever version they last fetched; routing
/// under an older version than the store's latest is *expected* (that is
/// the "almost asynchronous" relaxation) and converges as nodes pick up
/// refreshes at their next routing call.
pub struct RouterSnapshot {
    /// Monotonic publish counter (1-based).
    pub version: u64,
    /// EM rounds completed when this snapshot was taken.
    pub em_round: usize,
    pub routers: Vec<TrainState>,
}

struct StoreInner {
    snap: Option<Arc<RouterSnapshot>>,
    closed: bool,
}

/// `Arc`-swapped registry of the latest router snapshot plus the comm
/// ledger of its broadcasts. Readers clone the `Arc` under a
/// momentarily-held lock (no blocking on publishers mid-routing);
/// [`SnapshotStore::wait_current`] blocks only before the *first*
/// publish. Closing the store (automatic when the orchestrator's router
/// driver returns) wakes any first-publish waiters; an already-published
/// snapshot keeps serving after close.
pub struct SnapshotStore {
    /// Live subscriber count — atomic because elastic runs adjust it as
    /// nodes join and leave, and each publish records its broadcast
    /// against the count *at publish time* (the ledger stays exact under
    /// churn).
    subscribers: AtomicUsize,
    /// Which fleet shard this store serves (`None` = the single-fleet
    /// case). Purely diagnostic: it rides on waiter errors so multi-
    /// shard failures are attributable from the error chain alone.
    shard: Option<usize>,
    inner: Mutex<StoreInner>,
    cv: Condvar,
    ledger: Mutex<CommLedger>,
}

impl SnapshotStore {
    /// A store broadcasting to `subscribers` expert nodes.
    pub fn new(subscribers: usize) -> Self {
        SnapshotStore {
            subscribers: AtomicUsize::new(subscribers),
            shard: None,
            inner: Mutex::new(StoreInner {
                snap: None,
                closed: false,
            }),
            cv: Condvar::new(),
            ledger: Mutex::new(CommLedger::default()),
        }
    }

    /// A store serving one fleet shard: like [`SnapshotStore::new`], but
    /// waiter errors carry the shard id.
    pub fn new_sharded(subscribers: usize, shard: usize) -> Self {
        SnapshotStore {
            shard: Some(shard),
            ..SnapshotStore::new(subscribers)
        }
    }

    /// The shard this store serves, if it belongs to a fleet.
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }

    pub fn subscribers(&self) -> usize {
        self.subscribers.load(Ordering::Relaxed)
    }

    /// Reset the live subscriber count (elastic run setup).
    pub fn set_subscribers(&self, n: usize) {
        self.subscribers.store(n, Ordering::Relaxed);
    }

    /// Adjust the live subscriber count by `delta` (a node joined or
    /// left), returning the new count. Saturates at zero.
    pub fn adjust_subscribers(&self, delta: isize) -> usize {
        if delta >= 0 {
            self.subscribers
                .fetch_add(delta as usize, Ordering::Relaxed)
                + delta as usize
        } else {
            let sub = (-delta) as usize;
            let mut cur = self.subscribers.load(Ordering::Relaxed);
            loop {
                let next = cur.saturating_sub(sub);
                match self.subscribers.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => return next,
                    Err(now) => cur = now,
                }
            }
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, StoreInner> {
        self.inner.lock().expect("snapshot store poisoned")
    }

    /// Publish a new snapshot, returning its version. Records one
    /// [`SnapshotBroadcast`](super::comm::CommKind::SnapshotBroadcast):
    /// the full router parameter set (f32) to every subscriber.
    pub fn publish(&self, routers: Vec<TrainState>, em_round: usize) -> u64 {
        let bytes: u64 = routers.iter().map(|r| r.params.len() as u64 * 4).sum();
        let mut g = self.lock();
        let version = g.snap.as_ref().map(|s| s.version).unwrap_or(0) + 1;
        g.snap = Some(Arc::new(RouterSnapshot {
            version,
            em_round,
            routers,
        }));
        drop(g);
        self.cv.notify_all();
        self.ledger
            .lock()
            .expect("snapshot ledger poisoned")
            .record_snapshot_broadcast(self.subscribers(), bytes, version);
        version
    }

    /// The latest snapshot, if any was ever published. Never blocks.
    pub fn current(&self) -> Option<Arc<RouterSnapshot>> {
        self.lock().snap.clone()
    }

    /// Latest published version (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.lock().snap.as_ref().map(|s| s.version).unwrap_or(0)
    }

    /// The latest snapshot, blocking until the first publish. Errors if
    /// the store is closed while still empty (the router driver exited
    /// without ever publishing).
    pub fn wait_current(&self) -> Result<Arc<RouterSnapshot>> {
        self.wait_current_for(None)
    }

    /// [`wait_current`](SnapshotStore::wait_current) with an optional
    /// deadline: an orphaned node (its publisher died without closing
    /// the store) errors structurally after `timeout` instead of
    /// blocking forever. `None` waits indefinitely.
    pub fn wait_current_for(&self, timeout: Option<Duration>) -> Result<Arc<RouterSnapshot>> {
        self.wait_current_ctx(timeout, None)
    }

    /// The attributability suffix for waiter errors: which shard, which
    /// node, and which snapshot version the waiter was blocked on.
    fn wait_ctx(&self, node: Option<usize>, version: u64) -> String {
        let shard = match self.shard {
            Some(s) => format!("shard {s}"),
            None => "unsharded".to_string(),
        };
        let node = match node {
            Some(n) => format!("node {n}"),
            None => "external waiter".to_string(),
        };
        format!("{shard}, {node}, waited on snapshot version >= {version}, store at version 0")
    }

    /// [`wait_current_for`](SnapshotStore::wait_current_for) with the
    /// waiting node's identity attached to any close/timeout error, so a
    /// multi-shard failure names its shard, node, and the snapshot
    /// version waited on from the error chain alone.
    pub fn wait_current_ctx(
        &self,
        timeout: Option<Duration>,
        node: Option<usize>,
    ) -> Result<Arc<RouterSnapshot>> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut g = self.lock();
        loop {
            if let Some(s) = &g.snap {
                return Ok(s.clone());
            }
            if g.closed {
                bail!(
                    "snapshot store closed before any router snapshot was published ({})",
                    self.wait_ctx(node, 1)
                );
            }
            match deadline {
                None => g = self.cv.wait(g).expect("snapshot store poisoned"),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        bail!(
                            "timed out after {:?} waiting for the first router snapshot \
                             ({}; node orphaned: is the publisher alive?)",
                            timeout.expect("deadline implies timeout"),
                            self.wait_ctx(node, 1)
                        );
                    }
                    let (guard, _) = self
                        .cv
                        .wait_timeout(g, d - now)
                        .expect("snapshot store poisoned");
                    g = guard;
                }
            }
        }
    }

    /// Close the store: wakes first-publish waiters. An existing snapshot
    /// keeps serving; only an empty closed store makes
    /// [`wait_current`](SnapshotStore::wait_current) fail.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Drain the broadcast ledger (the async run's full inter-node
    /// communication record).
    pub fn take_ledger(&self) -> CommLedger {
        std::mem::take(&mut *self.ledger.lock().expect("snapshot ledger poisoned"))
    }
}

struct CloseStoreOnDrop<'a>(&'a SnapshotStore);

impl Drop for CloseStoreOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

// -------------------------------------------------------------------------
// the model side, abstracted (testable without compiled artifacts)
// -------------------------------------------------------------------------

/// What a trainer node needs from the model side. The production
/// implementation is [`EngineBackend`]; tier-1 tests substitute
/// deterministic stubs so the orchestration (slicing, local routing,
/// checkpoint/resume, comm accounting) is testable without compiled
/// artifacts — the same pattern as the server's `ServeBackend`.
pub trait TrainBackend: Sync {
    /// Rows per training batch.
    fn train_batch_rows(&self) -> usize;
    /// Tokens consumed per training step (the `tokens` log series x-axis).
    fn tokens_per_step(&self) -> usize;
    /// Fresh expert state for `node` (deterministic per seed).
    fn init_expert(&self, node: usize, seed: u64) -> Result<TrainState>;
    /// State for a node joining a *live* run (elastic expert-count
    /// growth): re-seeded from the nearest router snapshot, so a
    /// newcomer starts consistent with the routing the cluster is
    /// already using. The default ignores the snapshot and falls back to
    /// [`init_expert`](TrainBackend::init_expert); backends with
    /// distillation-style warm starts override it.
    fn init_joiner(&self, node: usize, seed: u64, _snap: &RouterSnapshot) -> Result<TrainState> {
        self.init_expert(node, seed)
    }
    /// One SGD step of `state` on `batch`; returns the batch loss.
    fn train_step(&self, node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32>;
    /// Local routing: the winning expert index per row under `snap`'s
    /// routers. Runs *inside* one node's worker — implementations should
    /// not fan out across threads of their own.
    fn route_local(&self, snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>>;
}

/// The real backend: engine-executed training steps and argmin
/// prefix-NLL routing (Eq. 4) under the snapshot's routers.
pub struct EngineBackend<'a> {
    pub engine: &'a Engine,
    pub router_meta: VariantMeta,
    pub expert_meta: VariantMeta,
    pub expert_variant: String,
    /// Routing prefix length M (training-time).
    pub prefix_len: usize,
}

impl TrainBackend for EngineBackend<'_> {
    fn train_batch_rows(&self) -> usize {
        self.expert_meta.train_batch
    }

    fn tokens_per_step(&self) -> usize {
        self.expert_meta.tokens_per_step()
    }

    fn init_expert(&self, _node: usize, seed: u64) -> Result<TrainState> {
        TrainState::init(self.engine, &self.expert_variant, seed)
    }

    fn train_step(&self, _node: usize, state: &mut TrainState, batch: &[&[u32]]) -> Result<f32> {
        state.train_step(self.engine, batch, &self.expert_meta)
    }

    fn route_local(&self, snap: &RouterSnapshot, rows: &[&[u32]]) -> Result<Vec<usize>> {
        // one thread: the node *is* the unit of parallelism
        let nll = score_matrix_rows_threaded(
            self.engine,
            &snap.routers,
            &self.router_meta,
            rows,
            self.prefix_len,
            1,
        )?;
        Ok(nll
            .iter()
            .map(|row| {
                let mut best = 0usize;
                for (e, &v) in row.iter().enumerate() {
                    if v < row[best] {
                        best = e;
                    }
                }
                best
            })
            .collect())
    }
}

// -------------------------------------------------------------------------
// node run configuration / progress / outcomes
// -------------------------------------------------------------------------

/// Knobs shared by both orchestration modes (async-only fields are
/// ignored by staged runs).
#[derive(Clone, Debug)]
pub struct NodeRunConfig {
    /// SGD steps per node.
    pub steps_per_node: usize,
    /// Log the loss every `log_every` steps (and on the final step).
    pub log_every: usize,
    /// Checkpoint every `checkpoint_every` steps (0 = only the final
    /// checkpoint, which is always written when a directory is set).
    pub checkpoint_every: usize,
    /// Where node checkpoints live (`node{e}.ckpt`); `None` disables
    /// checkpointing entirely.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume each node from its checkpoint if one exists.
    pub resume: bool,
    /// Worker threads (0 = auto); nodes multiplex over fewer workers.
    pub threads: usize,
    /// Async: sequences drawn + locally routed per routing call
    /// (0 = the training batch size).
    pub route_chunk: usize,
    /// Async: max sequences a node may draw from its stream — the
    /// starvation valve for routers that assign a node (almost) nothing.
    /// 0 = auto: `2 × steps × batch × n_nodes` (twice the expected need
    /// at a uniform 1/E keep rate). Deterministic, so resume-exactness
    /// is unaffected.
    pub draw_budget: u64,
    /// Async: how long (µs) a node waits for the *first* router snapshot
    /// before erroring structurally — the orphaned-node valve. 0 = wait
    /// forever. Default 60 s.
    pub snapshot_wait_us: u64,
    /// Fleet back-compat: a pre-shard flat checkpoint directory to fall
    /// back to when `checkpoint_dir` (shard-namespaced) holds no
    /// checkpoint for a node yet. Only sound when global seat ids equal
    /// local ones (a one-shard fleet) — the fleet layer sets it exactly
    /// then. `None` everywhere else.
    pub legacy_flat_dir: Option<PathBuf>,
}

impl Default for NodeRunConfig {
    fn default() -> Self {
        NodeRunConfig {
            steps_per_node: 0,
            log_every: 10,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            threads: 0,
            route_chunk: 0,
            draw_budget: 0,
            snapshot_wait_us: 60_000_000,
            legacy_flat_dir: None,
        }
    }
}

/// Lock-free per-node progress counters, readable by the router driver
/// through [`TrainerHandle`] while nodes run.
#[derive(Default)]
pub struct NodeProgress {
    steps: AtomicUsize,
    drawn: AtomicU64,
    kept: AtomicU64,
    snapshot_version: AtomicU64,
}

impl NodeProgress {
    pub fn steps(&self) -> usize {
        self.steps.load(Ordering::Relaxed)
    }
    pub fn drawn(&self) -> u64 {
        self.drawn.load(Ordering::Relaxed)
    }
    pub fn kept(&self) -> u64 {
        self.kept.load(Ordering::Relaxed)
    }
    pub fn snapshot_version(&self) -> u64 {
        self.snapshot_version.load(Ordering::Relaxed)
    }
}

/// What the orchestration driver (the router trainer) can observe while
/// expert nodes run: the snapshot store, live per-node progress, and
/// whether the run has already failed (so a polling driver can stop
/// waiting for progress that will never come).
pub struct TrainerHandle<'a> {
    store: Option<&'a SnapshotStore>,
    progress: &'a [NodeProgress],
    failed: &'a AtomicBool,
}

impl TrainerHandle<'_> {
    pub fn n_nodes(&self) -> usize {
        self.progress.len()
    }

    pub fn store(&self) -> Option<&SnapshotStore> {
        self.store
    }

    pub fn node(&self, node: usize) -> &NodeProgress {
        &self.progress[node]
    }

    /// Training steps completed across all nodes so far.
    pub fn total_steps_done(&self) -> usize {
        self.progress.iter().map(NodeProgress::steps).sum()
    }

    /// A node (or the driver itself, on a previous poll) already failed;
    /// the run will return that error once the pool drains.
    pub fn failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }
}

/// Everything one finished node produced.
pub struct NodeOutcome {
    pub node: usize,
    pub state: TrainState,
    pub log: RunLog,
    pub steps_done: usize,
    /// Sequences drawn from the node's stream (0 in staged mode).
    pub drawn: u64,
    /// Sequences the node routed to itself (0 in staged mode).
    pub kept: u64,
    /// Ground-truth domain histogram of the sequences actually trained
    /// on (async mode; empty-equivalent zeros in staged mode).
    pub domain_counts: Vec<u64>,
    /// Last snapshot version the node routed under.
    pub snapshot_version: u64,
    /// The node stopped early because its draw budget ran dry before the
    /// step budget was met.
    pub exhausted: bool,
}

impl NodeOutcome {
    /// Sequences this node trained on.
    pub fn trained_sequences(&self) -> u64 {
        self.domain_counts.iter().sum()
    }

    /// Plurality-domain fraction of the trained-on sequences (the async
    /// analogue of the staged segments' purity diagnostic).
    pub fn purity(&self) -> f64 {
        let total: u64 = self.domain_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let max = self.domain_counts.iter().copied().max().unwrap_or(0);
        max as f64 / total as f64
    }
}

// -------------------------------------------------------------------------
// the node itself
// -------------------------------------------------------------------------

/// Steps per scheduling slice: a node yields its worker after at most
/// this many training steps so siblings multiplex fairly over a smaller
/// worker pool. Pure scheduling granularity — results are identical at
/// any value.
const SLICE_STEPS: usize = 8;

/// Why a node handed its worker back.
enum SliceOutcome {
    /// Slice budget spent; re-queue the node.
    Progress,
    /// Step budget met (or stream exhausted): the node is done.
    Finished,
    /// Elastic only: a [`FaultPlan`] kill fired at the top of a step;
    /// carries the index of the [`KillSpec`](super::chaos::KillSpec)
    /// that fired (fleet runs tag some indices as whole-shard kills).
    Killed(usize),
    /// Elastic only: the node left the run (index into the
    /// [`ElasticPlan::leaves`] schedule). Its checkpoint was written so
    /// an adopter can resume this exact position.
    Left(usize),
}

/// Orphan guard: how long a node may block on the first snapshot.
/// `0` means wait forever (the pre-elastic behavior).
fn snapshot_wait(cfg: &NodeRunConfig) -> Option<Duration> {
    (cfg.snapshot_wait_us != 0).then(|| Duration::from_micros(cfg.snapshot_wait_us))
}

/// One training step with the elastic retry/backoff contract: injected
/// transients from the fault plan — and genuine backend errors whose
/// chain downcasts to [`TransientFault`] — are retried with linear
/// backoff up to [`ElasticPolicy::max_retries`]; anything else (or
/// exhausted retries) propagates. Outside elastic runs this is a plain
/// `train_step` call. Retries assume the backend leaves `state`
/// untouched on error (true of the engine: errors happen before the
/// optimizer update lands).
fn step_with_retries<B: TrainBackend>(
    backend: &B,
    idx: usize,
    step: u64,
    state: &mut TrainState,
    rows: &[&[u32]],
    elastic: Option<&ElasticCtx<'_, '_>>,
) -> Result<f32> {
    let Some(ctx) = elastic else {
        return backend.train_step(idx, state, rows);
    };
    let mut retries = 0u32;
    loop {
        let result = if ctx.faults.transient_failure(idx, step) {
            Err(anyhow::Error::new(TransientFault { node: idx, step }))
        } else {
            backend.train_step(idx, state, rows)
        };
        match result {
            Ok(loss) => return Ok(loss),
            Err(e) if is_transient(&e) && retries < ctx.policy.max_retries => {
                retries += 1;
                ctx.stats.transient_retries.fetch_add(1, Ordering::Relaxed);
                if ctx.policy.retry_backoff_us > 0 {
                    std::thread::sleep(Duration::from_micros(
                        ctx.policy.retry_backoff_us * retries as u64,
                    ));
                }
            }
            Err(e) => {
                return Err(e.context(format!(
                    "train step {step} failed after {retries} retries"
                )))
            }
        }
    }
}

enum Source<'env> {
    /// Staged mode: a pre-sharded segment, cycled by cursor (the classic
    /// pipeline's batch discipline — bit-identical to `train_expert`).
    Segment { seqs: Vec<Sequence>, cursor: u64 },
    /// Async mode: the node's own fresh-sequence stream plus the pool of
    /// sequences already routed to this node but not yet trained on.
    Stream {
        gen: SequenceGen<'env>,
        pool: VecDeque<Sequence>,
        route_chunk: usize,
        draw_budget: u64,
    },
}

struct Node<'env> {
    idx: usize,
    seed: u64,
    state: Option<TrainState>,
    source: Source<'env>,
    steps_done: usize,
    drawn: u64,
    kept: u64,
    domain_counts: Vec<u64>,
    snapshot_version: u64,
    log: RunLog,
    log_every: usize,
    finished: bool,
    exhausted: bool,
    last_saved: Option<usize>,
    /// Elastic: initialize via [`TrainBackend::init_joiner`] from the
    /// nearest snapshot (a node joining a live run) instead of
    /// `init_expert`.
    joiner: bool,
    /// Elastic: the last snapshot actually *delivered* to this node —
    /// what the node falls back to when a delivery is dropped by the
    /// fault plan. Unused (None) outside elastic runs.
    held_snap: Option<Arc<RouterSnapshot>>,
}

pub(crate) fn ckpt_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("node{idx}.ckpt"))
}

impl<'env> Node<'env> {
    fn staged(idx: usize, seed: u64, segment: Vec<Sequence>, cfg: &NodeRunConfig) -> Self {
        Node {
            idx,
            seed,
            state: None,
            source: Source::Segment {
                seqs: segment,
                cursor: 0,
            },
            steps_done: 0,
            drawn: 0,
            kept: 0,
            domain_counts: vec![0; DOMAINS],
            snapshot_version: 0,
            log: RunLog::new(),
            log_every: cfg.log_every.max(1),
            finished: false,
            exhausted: false,
            last_saved: None,
            joiner: false,
            held_snap: None,
        }
    }

    fn stream(
        idx: usize,
        seed: u64,
        gen: SequenceGen<'env>,
        route_chunk: usize,
        draw_budget: u64,
        cfg: &NodeRunConfig,
    ) -> Self {
        Node {
            idx,
            seed,
            state: None,
            source: Source::Stream {
                gen,
                pool: VecDeque::new(),
                route_chunk: route_chunk.max(1),
                draw_budget,
            },
            steps_done: 0,
            drawn: 0,
            kept: 0,
            domain_counts: vec![0; DOMAINS],
            snapshot_version: 0,
            log: RunLog::new(),
            log_every: cfg.log_every.max(1),
            finished: false,
            exhausted: false,
            last_saved: None,
            joiner: false,
            held_snap: None,
        }
    }

    fn publish_progress(&self, p: &NodeProgress) {
        p.steps.store(self.steps_done, Ordering::Relaxed);
        p.drawn.store(self.drawn, Ordering::Relaxed);
        p.kept.store(self.kept, Ordering::Relaxed);
        p.snapshot_version
            .store(self.snapshot_version, Ordering::Relaxed);
    }

    fn try_resume(&mut self, cfg: &NodeRunConfig) -> Result<()> {
        let Some(dir) = &cfg.checkpoint_dir else {
            return Ok(());
        };
        let mut path = ckpt_path(dir, self.idx);
        if !path.exists() {
            // one-shard fleets may point at a pre-shard flat layout: the
            // old `node{e}.ckpt` files still load (global == local there)
            match cfg.legacy_flat_dir.as_ref().map(|d| ckpt_path(d, self.idx)) {
                Some(flat) if flat.exists() => path = flat,
                _ => return Ok(()),
            }
        }
        let ck = load_node_checkpoint(&path)
            .with_context(|| format!("resuming node {} from {}", self.idx, path.display()))?;
        let NodeCheckpoint {
            node,
            mode,
            steps_done,
            cursor,
            stream,
            pool,
            domain_counts,
            drawn,
            kept,
            snapshot_version,
            state,
        } = ck;
        ensure!(
            node as usize == self.idx,
            "checkpoint {} belongs to node {node}, not node {}",
            path.display(),
            self.idx
        );
        let expect_mode = match self.source {
            Source::Segment { .. } => NODE_MODE_STAGED,
            Source::Stream { .. } => NODE_MODE_ASYNC,
        };
        ensure!(
            mode == expect_mode,
            "checkpoint {} was written in mode {mode}, run is mode {expect_mode} \
             (staged=0, async=1)",
            path.display()
        );
        match &mut self.source {
            Source::Segment { cursor: c, .. } => *c = cursor,
            Source::Stream { gen, pool: p, .. } => {
                let pos = stream.with_context(|| {
                    format!("async checkpoint {} missing its stream position", path.display())
                })?;
                gen.seek(&pos);
                *p = pool.into_iter().collect();
            }
        }
        ensure!(
            domain_counts.len() == self.domain_counts.len(),
            "checkpoint domain histogram has {} buckets, corpus has {}",
            domain_counts.len(),
            self.domain_counts.len()
        );
        self.steps_done = steps_done as usize;
        self.drawn = drawn;
        self.kept = kept;
        self.snapshot_version = snapshot_version;
        self.domain_counts = domain_counts;
        self.state = Some(state);
        self.last_saved = Some(self.steps_done);
        Ok(())
    }

    fn save_checkpoint(&mut self, cfg: &NodeRunConfig) -> Result<()> {
        let Some(dir) = &cfg.checkpoint_dir else {
            return Ok(());
        };
        let state = self
            .state
            .as_ref()
            .expect("state initialized before any checkpoint");
        let (mode, cursor, stream, pool): (u8, u64, _, &[Sequence]) = match &mut self.source {
            Source::Segment { cursor, .. } => (NODE_MODE_STAGED, *cursor, None, &[]),
            Source::Stream { gen, pool, .. } => {
                // make_contiguous: a borrowed view of the pool, no token
                // clones per checkpoint
                (NODE_MODE_ASYNC, 0, Some(gen.pos()), &*pool.make_contiguous())
            }
        };
        let view = NodeCheckpointView {
            node: self.idx as u32,
            mode,
            steps_done: self.steps_done as u64,
            cursor,
            stream,
            pool,
            domain_counts: &self.domain_counts,
            drawn: self.drawn,
            kept: self.kept,
            snapshot_version: self.snapshot_version,
            state,
        };
        save_node_checkpoint(&view, ckpt_path(dir, self.idx))
            .with_context(|| format!("checkpointing node {}", self.idx))?;
        self.last_saved = Some(self.steps_done);
        Ok(())
    }

    /// Run up to [`SLICE_STEPS`] training steps, then yield the worker.
    ///
    /// `elastic` is `None` on classic runs (bit-identical legacy
    /// behavior) and `Some` under [`run_elastic_nodes`], where the
    /// fault plan, leave schedule and pending merges are consulted at
    /// deterministic points (node-local step counts, never the clock).
    fn run_slice<B: TrainBackend>(
        &mut self,
        backend: &B,
        store: Option<&SnapshotStore>,
        cfg: &NodeRunConfig,
        n_nodes: usize,
        progress: &NodeProgress,
        elastic: Option<&ElasticCtx<'env, '_>>,
    ) -> Result<SliceOutcome> {
        if let Source::Segment { seqs, .. } = &self.source {
            // same contract (and message) as the classic expert trainer
            ensure!(!seqs.is_empty(), "cannot train on an empty segment");
        }
        if self.state.is_none() {
            self.state = Some(if self.joiner {
                // a joiner seeds itself from the live router snapshot
                // instead of a cold init, so it starts stale-consistent
                // with the fleet it is joining
                let snap = store
                    .expect("joiners only exist in stream runs, which have a store")
                    .wait_current_ctx(snapshot_wait(cfg), Some(self.idx))?;
                let st = backend.init_joiner(self.idx, self.seed, &snap)?;
                self.held_snap = Some(snap);
                st
            } else {
                backend.init_expert(self.idx, self.seed)?
            });
        }
        if let Some(ctx) = elastic {
            if let Some(pm) = ctx.take_due_merge(self.idx, self.steps_done) {
                self.apply_pending_merge(backend, store, ctx, pm)?;
            }
        }
        let bs = backend.train_batch_rows().max(1);
        let mut slice = 0usize;
        while !self.finished && self.steps_done < cfg.steps_per_node && slice < SLICE_STEPS {
            if let Some(ctx) = elastic {
                let step = self.steps_done as u64;
                if let Some(ki) = ctx.faults.take_kill_indexed(self.idx, step) {
                    // die without checkpointing: the adopter resumes
                    // from the last *saved* boundary, losing exactly
                    // the steps since then
                    return Ok(SliceOutcome::Killed(ki));
                }
                if let Some(li) = ctx.take_leave(self.idx, self.steps_done) {
                    if cfg.checkpoint_dir.is_some() && self.last_saved != Some(self.steps_done) {
                        self.save_checkpoint(cfg)?;
                    }
                    return Ok(SliceOutcome::Left(li));
                }
                let stall = ctx.faults.take_stall_micros(self.idx, step);
                if stall > 0 {
                    // slow-node stall: purely a scheduling perturbation,
                    // the math is unaffected
                    std::thread::sleep(Duration::from_micros(stall));
                }
            }
            let loss = match &mut self.source {
                Source::Segment { seqs, cursor } => {
                    let batch = segment_batch(seqs, cursor, bs);
                    let step = self.steps_done as u64;
                    let state = self.state.as_mut().expect("initialized above");
                    step_with_retries(backend, self.idx, step, state, &batch, elastic)?
                }
                Source::Stream {
                    gen,
                    pool,
                    route_chunk,
                    draw_budget,
                } => {
                    // fill the pool to one batch by drawing + locally
                    // routing chunks of the node's own stream
                    while pool.len() < bs && self.drawn < *draw_budget {
                        let want = (*route_chunk).min((*draw_budget - self.drawn) as usize).max(1);
                        let chunk = gen.batch(want);
                        self.drawn += chunk.len() as u64;
                        let latest = store
                            .expect("stream nodes always run with a snapshot store")
                            .wait_current_ctx(snapshot_wait(cfg), Some(self.idx))?;
                        let snap = match elastic {
                            Some(ctx) if ctx.faults.drops_delivery(self.idx, latest.version) => {
                                // dropped delivery: keep routing against
                                // the last snapshot we did receive (or
                                // the latest, if nothing was ever held —
                                // a node cannot route against nothing)
                                self.held_snap.clone().unwrap_or(latest)
                            }
                            Some(_) => {
                                self.held_snap = Some(Arc::clone(&latest));
                                latest
                            }
                            None => latest,
                        };
                        if snap.version != self.snapshot_version {
                            self.snapshot_version = snap.version;
                            self.log.scalar(
                                "snapshot_version",
                                self.steps_done as f64,
                                snap.version as f64,
                            );
                        }
                        let rows: Vec<&[u32]> =
                            chunk.iter().map(|s| s.tokens.as_slice()).collect();
                        let routes = backend.route_local(&snap, &rows)?;
                        ensure!(
                            routes.len() == rows.len(),
                            "backend routed {} of {} rows",
                            routes.len(),
                            rows.len()
                        );
                        drop(rows);
                        // in a fleet shard, routing runs in the global
                        // seat space: keep rows routed to this node's
                        // *global* seat, not its local index
                        let (keep_id, route_space) = match elastic {
                            Some(ctx) => ctx.route_identity(self.idx, n_nodes),
                            None => (self.idx, n_nodes),
                        };
                        for (seq, &e) in chunk.into_iter().zip(&routes) {
                            ensure!(
                                e < route_space,
                                "route index {e} out of range for {route_space} expert seats"
                            );
                            if e == keep_id {
                                pool.push_back(seq);
                                self.kept += 1;
                            }
                        }
                        progress.drawn.store(self.drawn, Ordering::Relaxed);
                        progress.kept.store(self.kept, Ordering::Relaxed);
                        progress
                            .snapshot_version
                            .store(self.snapshot_version, Ordering::Relaxed);
                    }
                    if pool.len() < bs {
                        // draw budget dry before the step budget: finish
                        // early (deterministically — the budget is a
                        // draw count, not a clock)
                        self.exhausted = true;
                        break;
                    }
                    let batch_seqs: Vec<Sequence> = pool.drain(..bs).collect();
                    let rows: Vec<&[u32]> =
                        batch_seqs.iter().map(|s| s.tokens.as_slice()).collect();
                    let step = self.steps_done as u64;
                    let state = self.state.as_mut().expect("initialized above");
                    let loss = step_with_retries(backend, self.idx, step, state, &rows, elastic)?;
                    drop(rows);
                    for s in &batch_seqs {
                        if let Some(c) = self.domain_counts.get_mut(s.domain) {
                            *c += 1;
                        }
                    }
                    loss
                }
            };
            self.steps_done += 1;
            progress.steps.store(self.steps_done, Ordering::Relaxed);
            let step0 = self.steps_done - 1;
            if step0 % self.log_every == 0 || self.steps_done == cfg.steps_per_node {
                let st = self.state.as_ref().expect("initialized above");
                self.log.scalar("loss", st.step as f64, loss as f64);
                self.log.scalar(
                    "tokens",
                    (st.step as usize * backend.tokens_per_step()) as f64,
                    loss as f64,
                );
            }
            if cfg.checkpoint_every > 0 && self.steps_done % cfg.checkpoint_every == 0 {
                self.save_checkpoint(cfg)?;
            }
            slice += 1;
        }
        if self.steps_done >= cfg.steps_per_node || self.exhausted {
            if self.exhausted && !self.finished {
                self.log
                    .scalar("stream_exhausted", self.steps_done as f64, 1.0);
            }
            self.finished = true;
            if cfg.checkpoint_dir.is_some() && self.last_saved != Some(self.steps_done) {
                self.save_checkpoint(cfg)?;
            }
        }
        Ok(if self.finished {
            SliceOutcome::Finished
        } else {
            SliceOutcome::Progress
        })
    }

    /// Fold a rejoining node's offline trajectory back into the live
    /// parameters with a delayed-Nesterov outer update (Async
    /// Local-SGD): `d = offline − anchor; v = μ·v + d; θ += γ·(d + μ·v)`
    /// where γ/μ are [`ElasticPolicy::outer_lr`] /
    /// [`ElasticPolicy::outer_momentum`]. Staleness (router snapshot
    /// versions the leaver missed) is recorded on the ledger event.
    fn apply_pending_merge<B: TrainBackend>(
        &mut self,
        backend: &B,
        store: Option<&SnapshotStore>,
        ctx: &ElasticCtx<'env, '_>,
        pm: PendingMerge,
    ) -> Result<()> {
        let store = store.expect("merges only occur in stream runs, which have a store");
        let PendingMerge {
            seat,
            anchor,
            held,
            offline_steps,
            left_version,
            ..
        } = pm;
        let offline = train_offline(backend, ctx, seat, anchor.clone(), &held, offline_steps)
            .with_context(|| format!("offline leg of the node {seat} rejoin"))?;
        let state = self
            .state
            .as_mut()
            .expect("state initialized before any merge");
        ensure!(
            offline.params.len() == state.params.len(),
            "rejoin merge shape mismatch: offline has {} params, live node has {}",
            offline.params.len(),
            state.params.len()
        );
        let gamma = ctx.policy.outer_lr as f32;
        let mu = ctx.policy.outer_momentum as f32;
        {
            let mut outer = ctx.outer_v.lock().expect("outer momentum lock");
            let v = outer[seat].get_or_insert_with(|| vec![0.0; state.params.len()]);
            ensure!(
                v.len() == state.params.len(),
                "outer momentum buffer for seat {seat} has {} entries, node has {}",
                v.len(),
                state.params.len()
            );
            for i in 0..state.params.len() {
                let d = offline.params[i] - anchor.params[i];
                v[i] = mu * v[i] + d;
                state.params[i] += gamma * (d + mu * v[i]);
            }
        }
        let staleness = store.version().saturating_sub(left_version);
        let param_bytes = (state.params.len() * 4) as u64;
        ctx.ledger
            .lock()
            .expect("elastic ledger lock")
            .record_param_merge(seat, param_bytes, state.step, staleness);
        ctx.stats.merges.fetch_add(1, Ordering::Relaxed);
        self.log
            .scalar("merge_staleness", self.steps_done as f64, staleness as f64);
        Ok(())
    }

    fn into_outcome(self) -> NodeOutcome {
        NodeOutcome {
            node: self.idx,
            state: self.state.expect("finished nodes are initialized"),
            log: self.log,
            steps_done: self.steps_done,
            drawn: self.drawn,
            kept: self.kept,
            domain_counts: self.domain_counts,
            snapshot_version: self.snapshot_version,
            exhausted: self.exhausted,
        }
    }
}

// -------------------------------------------------------------------------
// the worker pool
// -------------------------------------------------------------------------

/// First-failure slot (flag checked lock-free on hot paths).
#[derive(Default)]
struct ErrSlot {
    set: AtomicBool,
    err: Mutex<Option<anyhow::Error>>,
}

impl ErrSlot {
    fn is_set(&self) -> bool {
        self.set.load(Ordering::Relaxed)
    }

    fn record(&self, e: anyhow::Error) {
        let mut slot = self.err.lock().expect("error slot poisoned");
        if slot.is_none() {
            *slot = Some(e);
        }
        self.set.store(true, Ordering::Relaxed);
    }

    fn take(&self) -> Option<anyhow::Error> {
        self.err.lock().expect("error slot poisoned").take()
    }
}

/// A node leaves the run (finished, errored, or aborted): close the
/// queue once the last one is accounted for, releasing the workers.
fn retire_node(remaining: &AtomicUsize, queue: &WorkQueue<Node<'_>>) {
    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        queue.close();
    }
}

#[allow(clippy::too_many_arguments)]
fn node_worker<'env, B: TrainBackend>(
    backend: &B,
    store: Option<&SnapshotStore>,
    cfg: &NodeRunConfig,
    queue: &WorkQueue<Node<'env>>,
    outcomes: &Mutex<Vec<Option<NodeOutcome>>>,
    progress: &[NodeProgress],
    error: &ErrSlot,
    remaining: &AtomicUsize,
) {
    while let Some(mut node) = queue.pop() {
        if error.is_set() {
            // shutting down: the node keeps its last checkpoint
            retire_node(remaining, queue);
            continue;
        }
        let idx = node.idx;
        match node.run_slice(backend, store, cfg, progress.len(), &progress[idx], None) {
            Err(e) => {
                error.record(e.context(format!("trainer node {idx}")));
                if let Some(st) = store {
                    st.close(); // wake any first-publish waiter
                }
                retire_node(remaining, queue);
            }
            Ok(SliceOutcome::Finished) => {
                outcomes.lock().expect("outcomes poisoned")[idx] = Some(node.into_outcome());
                retire_node(remaining, queue);
            }
            // Killed/Left cannot fire without an elastic context
            Ok(_) => {
                if error.is_set() || !queue.push(node) {
                    retire_node(remaining, queue);
                }
            }
        }
    }
}

fn run_nodes_inner<'env, B, R, F>(
    backend: &B,
    store: Option<&SnapshotStore>,
    mut nodes: Vec<Node<'env>>,
    cfg: &NodeRunConfig,
    driver: F,
) -> Result<(Vec<NodeOutcome>, R)>
where
    B: TrainBackend,
    F: FnOnce(&TrainerHandle<'_>) -> Result<R>,
{
    let n = nodes.len();
    if let Some(dir) = &cfg.checkpoint_dir {
        // a crash mid-`write_atomic` leaves a `.tmp` orphan behind; clear
        // them before anyone resumes so a dead partial write can never be
        // mistaken for (or block) a live checkpoint
        let swept = sweep_stale_temps(dir).context("sweeping stale checkpoint temp files")?;
        if swept > 0 {
            eprintln!("[trainer] swept {swept} stale checkpoint temp file(s)");
        }
    }
    if cfg.resume {
        for node in &mut nodes {
            node.try_resume(cfg)?;
        }
    }
    let progress: Vec<NodeProgress> = (0..n).map(|_| NodeProgress::default()).collect();
    for node in &nodes {
        node.publish_progress(&progress[node.idx]);
    }
    let queue: WorkQueue<Node<'env>> = WorkQueue::new();
    let outcomes: Mutex<Vec<Option<NodeOutcome>>> = Mutex::new((0..n).map(|_| None).collect());
    let error = ErrSlot::default();
    let remaining = AtomicUsize::new(n);
    let workers = resolve_threads(cfg.threads).max(1).min(n.max(1));
    if n == 0 {
        queue.close();
    }

    let driver_out = std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                node_worker(
                    backend, store, cfg, &queue, &outcomes, &progress, &error, &remaining,
                )
            });
        }
        queue.push_all(nodes);
        // the store must not outlive the router driver un-closed: a node
        // waiting for a first publish that will never come has to wake
        let _close_store = store.map(CloseStoreOnDrop);
        let handle = TrainerHandle {
            store,
            progress: &progress,
            failed: &error.set,
        };
        match driver(&handle) {
            Ok(r) => Some(r),
            Err(e) => {
                error.record(e.context("router driver"));
                None
            }
        }
    });

    if let Some(e) = error.take() {
        return Err(e);
    }
    let driver_out = driver_out.expect("driver result present when no error was recorded");
    let slots = outcomes.into_inner().expect("outcomes poisoned");
    let mut out = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| anyhow!("node {i} finished without an outcome"))?);
    }
    Ok((out, driver_out))
}

/// Staged mode: run each `(seed, segment)` job as a node over the worker
/// pool. Per-node trajectories depend only on their own seed + segment,
/// so outcomes are bit-identical at any worker count — and identical to
/// the classic sequential expert loop.
pub fn run_staged_nodes<B: TrainBackend>(
    backend: &B,
    jobs: Vec<(u64, Vec<Sequence>)>,
    cfg: &NodeRunConfig,
) -> Result<Vec<NodeOutcome>> {
    let nodes: Vec<Node<'static>> = jobs
        .into_iter()
        .enumerate()
        .map(|(e, (seed, segment))| Node::staged(e, seed, segment, cfg))
        .collect();
    let (outcomes, ()) = run_nodes_inner(backend, None, nodes, cfg, |_| Ok(()))?;
    Ok(outcomes)
}

/// Async mode: every `(seed, stream)` job becomes an independent trainer
/// node that draws from its own stream and routes locally against
/// `store`'s latest snapshot; `driver` runs on the calling thread (the
/// router leader) and publishes snapshots while nodes train. Returns the
/// node outcomes plus the driver's result.
pub fn run_async_nodes<'env, B, R, F>(
    backend: &B,
    store: &SnapshotStore,
    jobs: Vec<(u64, SequenceGen<'env>)>,
    cfg: &NodeRunConfig,
    driver: F,
) -> Result<(Vec<NodeOutcome>, R)>
where
    B: TrainBackend,
    F: FnOnce(&TrainerHandle<'_>) -> Result<R>,
{
    let n = jobs.len();
    let bs = backend.train_batch_rows().max(1);
    let auto = (cfg.steps_per_node as u64)
        .saturating_mul(bs as u64)
        .saturating_mul(n.max(1) as u64)
        .saturating_mul(2);
    let draw_budget = if cfg.draw_budget > 0 {
        cfg.draw_budget
    } else {
        auto.max(1)
    };
    let route_chunk = if cfg.route_chunk > 0 { cfg.route_chunk } else { bs };
    let nodes: Vec<Node<'env>> = jobs
        .into_iter()
        .enumerate()
        .map(|(e, (seed, gen))| Node::stream(e, seed, gen, route_chunk, draw_budget, cfg))
        .collect();
    run_nodes_inner(backend, Some(store), nodes, cfg, driver)
}

// -------------------------------------------------------------------------
// elastic membership + failure tolerance
// -------------------------------------------------------------------------

/// A leaver that comes back: how long it trains offline and when its
/// seat folds the result back in (see the failure model in the module
/// docs and [`Node::apply_pending_merge`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rejoin {
    /// Steps the leaver trains offline — against its own stream and the
    /// router snapshot it held when it left (routers frozen, exactly the
    /// Async Local-SGD inner loop).
    pub offline_steps: usize,
    /// The live seat merges the offline leg at its first fault-check
    /// once `steps_done >= merge_at_step`.
    pub merge_at_step: usize,
}

/// A scheduled departure from an elastic run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaveEvent {
    pub node: usize,
    /// Fires at the top of this node-local step (deterministic).
    pub at_step: usize,
    /// Immediately re-fill the seat from the checkpoint the leaver
    /// writes on its way out (a replacement node adopts it).
    pub adopt: bool,
    /// Merge the leaver's offline trajectory back in later.
    pub rejoin: Option<Rejoin>,
}

/// Knobs for the elastic machinery's tolerance paths.
#[derive(Clone, Copy, Debug)]
pub struct ElasticPolicy {
    /// Retries per training step on transient backend errors.
    pub max_retries: u32,
    /// Linear backoff unit between retries (sleep = unit × attempt).
    pub retry_backoff_us: u64,
    /// γ of the delayed-Nesterov outer update applied at rejoin merges.
    pub outer_lr: f64,
    /// μ of the delayed-Nesterov outer update.
    pub outer_momentum: f64,
    /// Spare seats beyond the initial fleet that
    /// [`ElasticHandle::join_new_node`] may fill mid-run.
    pub max_extra_nodes: usize,
}

impl Default for ElasticPolicy {
    fn default() -> Self {
        ElasticPolicy {
            max_retries: 3,
            retry_backoff_us: 100,
            outer_lr: 0.5,
            outer_momentum: 0.9,
            max_extra_nodes: 0,
        }
    }
}

/// Global routing identity for shard-local runs: a fleet shard runs its
/// nodes at local indices `0..k`, but routing happens in the *global*
/// seat space (the published snapshot concatenates every shard's router
/// block). `global[local]` is the seat a local node keeps rows for, and
/// `space` is the total seat count route indices are validated against.
#[derive(Clone, Debug, Default)]
pub struct SeatIdentity {
    pub global: Vec<usize>,
    pub space: usize,
}

/// Everything an elastic run is told up front: the seeded fault plan,
/// the membership (leave/rejoin) schedule, and the tolerance policy.
#[derive(Default)]
pub struct ElasticPlan {
    pub faults: FaultPlan,
    pub leaves: Vec<LeaveEvent>,
    pub policy: ElasticPolicy,
    /// Fleet runs: kill-spec indices in `faults.kills` that belong to a
    /// whole-shard kill — their recoveries are audited as
    /// [`ShardAdopt`](super::comm::CommKind::ShardAdopt) (a fault-domain
    /// crossing) instead of in-shard `CheckpointAdopt` events.
    pub shard_kill_indices: Vec<usize>,
    /// Fleet runs: local-seat → global-seat routing identity. `None`
    /// (the single-fleet case) routes in the local index space.
    pub seat_identity: Option<SeatIdentity>,
}

/// A node that could not be carried to the end of the run.
pub struct NodeFailure {
    pub node: usize,
    /// Steps it had completed when it failed.
    pub steps_done: usize,
    pub error: anyhow::Error,
    /// Whatever trained state could be recovered from the wreck (None if
    /// the node died before initializing).
    pub salvage: Option<TrainState>,
}

/// How one seat ended an elastic run.
pub enum NodeEnd {
    /// Met its step budget (or drained its stream) normally.
    Completed(NodeOutcome),
    /// Left on schedule and nobody adopted the seat.
    Left(NodeOutcome),
    /// Failed structurally (retries exhausted or a non-transient error).
    Failed(NodeFailure),
}

impl NodeEnd {
    pub fn node(&self) -> usize {
        match self {
            NodeEnd::Completed(o) | NodeEnd::Left(o) => o.node,
            NodeEnd::Failed(f) => f.node,
        }
    }

    /// The trained outcome, if this end produced one.
    pub fn outcome(&self) -> Option<&NodeOutcome> {
        match self {
            NodeEnd::Completed(o) | NodeEnd::Left(o) => Some(o),
            NodeEnd::Failed(_) => None,
        }
    }
}

/// Counters the elastic machinery accumulates across a run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ElasticStats {
    pub kills: u64,
    pub adoptions: u64,
    pub leaves: u64,
    pub joins: u64,
    pub merges: u64,
    /// Steps re-done because a kill landed past the last checkpoint.
    pub steps_lost: u64,
    pub transient_retries: u64,
    /// Wall-clock spent in checkpoint adoption (the only stat that is
    /// time-, not step-, denominated; it never feeds back into the run).
    pub recovery_micros: u64,
}

#[derive(Default)]
struct StatsAtomic {
    kills: AtomicU64,
    adoptions: AtomicU64,
    leaves: AtomicU64,
    joins: AtomicU64,
    merges: AtomicU64,
    steps_lost: AtomicU64,
    transient_retries: AtomicU64,
    recovery_micros: AtomicU64,
}

impl StatsAtomic {
    fn snapshot(&self) -> ElasticStats {
        ElasticStats {
            kills: self.kills.load(Ordering::Relaxed),
            adoptions: self.adoptions.load(Ordering::Relaxed),
            leaves: self.leaves.load(Ordering::Relaxed),
            joins: self.joins.load(Ordering::Relaxed),
            merges: self.merges.load(Ordering::Relaxed),
            steps_lost: self.steps_lost.load(Ordering::Relaxed),
            transient_retries: self.transient_retries.load(Ordering::Relaxed),
            recovery_micros: self.recovery_micros.load(Ordering::Relaxed),
        }
    }
}

/// What [`run_elastic_nodes`] returns alongside the driver's result.
pub struct ElasticReport {
    /// One entry per seat that ever ran, sorted by seat index. A seat
    /// whose leaver was adopted reports the *replacement*'s end (the
    /// departure itself is counted in [`ElasticStats::leaves`]).
    pub ends: Vec<NodeEnd>,
    pub stats: ElasticStats,
    /// `CheckpointAdopt` + `ParamMerge` events (snapshot broadcasts stay
    /// on the store's own ledger; callers merge the two).
    pub ledger: CommLedger,
}

/// A leaver's parked trajectory, waiting for its seat to merge it.
struct PendingMerge {
    seat: usize,
    /// The state the seat had at departure — the merge baseline.
    anchor: TrainState,
    /// The snapshot the leaver routes against while offline (frozen).
    held: Arc<RouterSnapshot>,
    offline_steps: usize,
    merge_at_step: usize,
    /// Store version at departure; merge staleness is measured from it.
    left_version: u64,
}

/// Stream salt for offline rejoin legs: the leaver draws from a stream
/// disjoint (by construction of the factory's salt mixing) from every
/// live seat's, so a merge never replays data the seat already saw.
const OFFLINE_STREAM_SALT: u64 = 0x0FF1;

/// Shared context wired into every elastic worker (the `'p` borrows live
/// on the [`run_elastic_nodes`] stack frame, outliving the scope).
struct ElasticCtx<'env, 'p> {
    faults: &'p FaultPlan,
    leaves: &'p [LeaveEvent],
    /// One-shot latch per leave event: a replacement that resumes at (or
    /// re-crosses) `at_step` must not leave again.
    leaves_fired: Mutex<Vec<bool>>,
    policy: ElasticPolicy,
    stats: StatsAtomic,
    /// `CheckpointAdopt`/`ParamMerge` accounting (broadcasts stay on the
    /// store's ledger). Taken last, never nested under another lock.
    ledger: Mutex<CommLedger>,
    pending: Mutex<Vec<PendingMerge>>,
    /// Per-seat delayed-Nesterov outer momentum, lazily allocated.
    outer_v: Mutex<Vec<Option<Vec<f32>>>>,
    /// Per-seat stream seeds (spare seats are filled at join time).
    seeds: Mutex<Vec<u64>>,
    /// `(seat, salt) -> SequenceGen`: respawns and offline legs rebuild
    /// deterministic streams without threading generators around.
    factory: &'p (dyn Fn(usize, u64) -> SequenceGen<'env> + Sync),
    route_chunk: usize,
    draw_budget: u64,
    /// Fleet runs: which kill indices are whole-shard kills (see
    /// [`ElasticPlan::shard_kill_indices`]).
    shard_kill_indices: &'p [usize],
    /// Fleet runs: local→global routing identity.
    seat_identity: Option<&'p SeatIdentity>,
}

impl<'env> ElasticCtx<'env, '_> {
    /// The `(keep-id, route-space)` a seat routes under: its global seat
    /// id in a fleet, its local index otherwise.
    fn route_identity(&self, seat: usize, n_nodes: usize) -> (usize, usize) {
        match self.seat_identity {
            Some(si) => (si.global.get(seat).copied().unwrap_or(seat), si.space),
            None => (seat, n_nodes),
        }
    }
    /// Fire the first unfired leave scheduled for `node` at or before
    /// `step` (one-shot; see `leaves_fired`).
    fn take_leave(&self, node: usize, step: usize) -> Option<usize> {
        let mut fired = self.leaves_fired.lock().expect("leave latch poisoned");
        for (i, ev) in self.leaves.iter().enumerate() {
            if !fired[i] && ev.node == node && step >= ev.at_step {
                fired[i] = true;
                return Some(i);
            }
        }
        None
    }

    /// Pull the first pending merge due on `seat` at `step`, if any.
    fn take_due_merge(&self, seat: usize, step: usize) -> Option<PendingMerge> {
        let mut pending = self.pending.lock().expect("pending merges poisoned");
        let at = pending
            .iter()
            .position(|pm| pm.seat == seat && step >= pm.merge_at_step)?;
        Some(pending.remove(at))
    }
}

/// The offline half of a leave/rejoin: train `steps` more steps from
/// `state`, drawing from the leaver's salted stream and routing under
/// its *frozen* held snapshot (keeping only rows routed to `seat`). This
/// is exactly the node's inner loop minus snapshot refreshes — which is
/// what makes the delayed-Nesterov merge sound.
fn train_offline<'env, B: TrainBackend>(
    backend: &B,
    ctx: &ElasticCtx<'env, '_>,
    seat: usize,
    mut state: TrainState,
    held: &RouterSnapshot,
    steps: usize,
) -> Result<TrainState> {
    let mut gen = (ctx.factory)(seat, OFFLINE_STREAM_SALT);
    let bs = backend.train_batch_rows().max(1);
    let n_routers = held.routers.len().max(1);
    // same keep-rate expectation (1/n_routers) as the live loop, with
    // 4x headroom; the budget is a draw count, so the leg stays
    // deterministic even when the stream runs dry early
    let budget = (steps as u64)
        .saturating_mul(bs as u64)
        .saturating_mul(n_routers as u64)
        .saturating_mul(4)
        .max(1);
    let mut drawn = 0u64;
    let mut pool: Vec<Sequence> = Vec::new();
    for _ in 0..steps {
        while pool.len() < bs && drawn < budget {
            let want = ctx.route_chunk.min((budget - drawn) as usize).max(1);
            let chunk = gen.batch(want);
            drawn += chunk.len() as u64;
            let rows: Vec<&[u32]> = chunk.iter().map(|s| s.tokens.as_slice()).collect();
            let routes = backend.route_local(held, &rows)?;
            ensure!(
                routes.len() == rows.len(),
                "backend routed {} of {} rows",
                routes.len(),
                rows.len()
            );
            drop(rows);
            let (keep_id, _) = ctx.route_identity(seat, n_routers);
            for (seq, &e) in chunk.into_iter().zip(&routes) {
                if e == keep_id {
                    pool.push(seq);
                }
            }
        }
        if pool.len() < bs {
            break; // stream dry: a shorter offline leg, merged as-is
        }
        let batch: Vec<Sequence> = pool.drain(..bs).collect();
        let rows: Vec<&[u32]> = batch.iter().map(|s| s.tokens.as_slice()).collect();
        backend.train_step(seat, &mut state, &rows)?;
    }
    Ok(state)
}

/// Build a replacement node for `seat` and resume it from the seat's
/// checkpoint if one exists (a missing checkpoint restarts the seat from
/// scratch — still a structured recovery, just a costlier one). Returns
/// the node, the adopted checkpoint's size in bytes (0 if none), and the
/// step it resumed at.
fn respawn_from_checkpoint<'env>(
    cfg: &NodeRunConfig,
    seat: usize,
    ctx: &ElasticCtx<'env, '_>,
) -> Result<(Node<'env>, u64, usize)> {
    let dir = cfg
        .checkpoint_dir
        .as_ref()
        .context("elastic adoption requires a checkpoint directory")?;
    let seed = ctx.seeds.lock().expect("seat seeds poisoned")[seat];
    let gen = (ctx.factory)(seat, 0);
    let mut node = Node::stream(seat, seed, gen, ctx.route_chunk, ctx.draw_budget, cfg);
    let path = ckpt_path(dir, seat);
    let mut ckpt_bytes = 0u64;
    if path.exists() {
        ckpt_bytes = std::fs::metadata(&path)
            .with_context(|| format!("sizing checkpoint {}", path.display()))?
            .len();
        node.try_resume(cfg)?;
    }
    let resumed = node.steps_done;
    Ok((node, ckpt_bytes, resumed))
}

/// The elastic worker loop: like [`node_worker`], but node failures are
/// *absorbed* (recorded as [`NodeEnd::Failed`], the store stays open,
/// survivors keep running) and [`SliceOutcome::Killed`]/`Left` trigger
/// the adoption / departure machinery. Only a driver failure aborts the
/// run through the [`ErrSlot`].
#[allow(clippy::too_many_arguments)]
fn elastic_node_worker<'env, B: TrainBackend>(
    backend: &B,
    store: &SnapshotStore,
    cfg: &NodeRunConfig,
    ctx: &ElasticCtx<'env, '_>,
    queue: &WorkQueue<Node<'env>>,
    ends: &Mutex<Vec<Option<NodeEnd>>>,
    progress: &[NodeProgress],
    error: &ErrSlot,
    remaining: &AtomicUsize,
) {
    while let Some(mut node) = queue.pop() {
        if error.is_set() {
            retire_node(remaining, queue);
            continue;
        }
        let idx = node.idx;
        let slice = node.run_slice(backend, Some(store), cfg, progress.len(), &progress[idx], Some(ctx));
        match slice {
            Err(e) => {
                // degradation contract: record the failure and keep the
                // run alive — never close the store, never abort
                ends.lock().expect("ends poisoned")[idx] = Some(NodeEnd::Failed(NodeFailure {
                    node: idx,
                    steps_done: node.steps_done,
                    error: e.context(format!("trainer node {idx}")),
                    salvage: node.state.take(),
                }));
                store.adjust_subscribers(-1);
                retire_node(remaining, queue);
            }
            Ok(SliceOutcome::Finished) => {
                ends.lock().expect("ends poisoned")[idx] =
                    Some(NodeEnd::Completed(node.into_outcome()));
                retire_node(remaining, queue);
            }
            Ok(SliceOutcome::Progress) => {
                if error.is_set() || !queue.push(node) {
                    retire_node(remaining, queue);
                }
            }
            Ok(SliceOutcome::Killed(ki)) => {
                ctx.stats.kills.fetch_add(1, Ordering::Relaxed);
                let died_at = node.steps_done;
                drop(node); // the dead process: its in-memory state is gone
                let t0 = Instant::now();
                match respawn_from_checkpoint(cfg, idx, ctx) {
                    Ok((replacement, ckpt_bytes, resumed)) => {
                        ctx.stats.adoptions.fetch_add(1, Ordering::Relaxed);
                        ctx.stats
                            .steps_lost
                            .fetch_add(died_at.saturating_sub(resumed) as u64, Ordering::Relaxed);
                        ctx.stats
                            .recovery_micros
                            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                        let mut ledger =
                            ctx.ledger.lock().expect("elastic ledger poisoned");
                        if ctx.shard_kill_indices.contains(&ki) {
                            // a whole-shard kill: the recovery crosses
                            // the shard's fault-domain boundary
                            ledger.record_shard_adopt(idx, ckpt_bytes, resumed as u64);
                        } else {
                            ledger.record_checkpoint_adopt(idx, ckpt_bytes, resumed as u64);
                        }
                        drop(ledger);
                        replacement.publish_progress(&progress[idx]);
                        // no subscriber adjustment: the seat was never
                        // vacant from the broadcast ledger's viewpoint
                        if error.is_set() || !queue.push(replacement) {
                            retire_node(remaining, queue);
                        }
                    }
                    Err(e) => {
                        ends.lock().expect("ends poisoned")[idx] =
                            Some(NodeEnd::Failed(NodeFailure {
                                node: idx,
                                steps_done: died_at,
                                error: e
                                    .context(format!("adopting the checkpoint of killed node {idx}")),
                                salvage: None,
                            }));
                        store.adjust_subscribers(-1);
                        retire_node(remaining, queue);
                    }
                }
            }
            Ok(SliceOutcome::Left(li)) => {
                ctx.stats.leaves.fetch_add(1, Ordering::Relaxed);
                let ev = ctx.leaves[li];
                if let Some(rejoin) = ev.rejoin {
                    // park the offline leg: anchor state + frozen
                    // snapshot, merged back when the seat reaches
                    // `merge_at_step`
                    let held = node.held_snap.clone().or_else(|| store.current());
                    let anchor = node.state.clone();
                    if let (Some(held), Some(anchor)) = (held, anchor) {
                        ctx.pending
                            .lock()
                            .expect("pending merges poisoned")
                            .push(PendingMerge {
                                seat: idx,
                                anchor,
                                held,
                                offline_steps: rejoin.offline_steps,
                                merge_at_step: rejoin.merge_at_step,
                                left_version: store.version(),
                            });
                    }
                }
                if ev.adopt {
                    // hand the seat straight to a replacement resuming
                    // the checkpoint the leaver wrote on its way out —
                    // a zero-loss, bit-identical handoff
                    let t0 = Instant::now();
                    match respawn_from_checkpoint(cfg, idx, ctx) {
                        Ok((replacement, ckpt_bytes, resumed)) => {
                            ctx.stats.adoptions.fetch_add(1, Ordering::Relaxed);
                            ctx.stats
                                .recovery_micros
                                .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                            ctx.ledger
                                .lock()
                                .expect("elastic ledger poisoned")
                                .record_checkpoint_adopt(idx, ckpt_bytes, resumed as u64);
                            replacement.publish_progress(&progress[idx]);
                            if error.is_set() || !queue.push(replacement) {
                                retire_node(remaining, queue);
                            }
                        }
                        Err(e) => {
                            ends.lock().expect("ends poisoned")[idx] =
                                Some(NodeEnd::Failed(NodeFailure {
                                    node: idx,
                                    steps_done: node.steps_done,
                                    error: e.context(format!(
                                        "adopting the checkpoint of departed node {idx}"
                                    )),
                                    salvage: node.state.take(),
                                }));
                            store.adjust_subscribers(-1);
                            retire_node(remaining, queue);
                        }
                    }
                } else {
                    store.adjust_subscribers(-1);
                    ends.lock().expect("ends poisoned")[idx] =
                        Some(NodeEnd::Left(node.into_outcome()));
                    retire_node(remaining, queue);
                }
            }
        }
    }
}

/// Run an elastic, failure-tolerant async fleet: `seeds.len()` initial
/// stream nodes (streams built by `stream_factory(seat, salt)` — salt 0
/// for live streams) plus up to [`ElasticPolicy::max_extra_nodes`] spare
/// seats, under `plan`'s fault/membership schedule. `driver` runs on the
/// calling thread with an [`ElasticHandle`] that can also join/adopt
/// nodes mid-run. Returns the [`ElasticReport`] plus the driver result;
/// `Ok` as long as the driver succeeded and at least one node survived.
pub fn run_elastic_nodes<'env, B, R, G, F>(
    backend: &B,
    store: &SnapshotStore,
    seeds: &[u64],
    stream_factory: G,
    cfg: &NodeRunConfig,
    plan: &ElasticPlan,
    driver: F,
) -> Result<(ElasticReport, R)>
where
    B: TrainBackend,
    G: Fn(usize, u64) -> SequenceGen<'env> + Sync,
    F: FnOnce(&ElasticHandle<'_, 'env>) -> Result<R>,
{
    let n = seeds.len();
    let seats = n + plan.policy.max_extra_nodes;
    let bs = backend.train_batch_rows().max(1);
    let auto = (cfg.steps_per_node as u64)
        .saturating_mul(bs as u64)
        .saturating_mul(n.max(1) as u64)
        .saturating_mul(2);
    let draw_budget = if cfg.draw_budget > 0 {
        cfg.draw_budget
    } else {
        auto.max(1)
    };
    let route_chunk = if cfg.route_chunk > 0 { cfg.route_chunk } else { bs };
    let mut seat_seeds = seeds.to_vec();
    seat_seeds.resize(seats, 0); // spare seats get a real seed at join time
    plan.faults.reset();
    let ctx = ElasticCtx {
        faults: &plan.faults,
        leaves: &plan.leaves,
        leaves_fired: Mutex::new(vec![false; plan.leaves.len()]),
        policy: plan.policy,
        stats: StatsAtomic::default(),
        ledger: Mutex::new(CommLedger::default()),
        pending: Mutex::new(Vec::new()),
        outer_v: Mutex::new(vec![None; seats]),
        seeds: Mutex::new(seat_seeds),
        factory: &stream_factory,
        route_chunk,
        draw_budget,
        shard_kill_indices: &plan.shard_kill_indices,
        seat_identity: plan.seat_identity.as_ref(),
    };
    if let Some(si) = &plan.seat_identity {
        ensure!(
            si.global.len() >= seats,
            "seat identity covers {} seats, run has {seats}",
            si.global.len()
        );
    }
    if let Some(dir) = &cfg.checkpoint_dir {
        let swept = sweep_stale_temps(dir).context("sweeping stale checkpoint temp files")?;
        if swept > 0 {
            eprintln!("[trainer] swept {swept} stale checkpoint temp file(s)");
        }
    }
    let mut nodes: Vec<Node<'env>> = (0..n)
        .map(|e| Node::stream(e, seeds[e], (ctx.factory)(e, 0), route_chunk, draw_budget, cfg))
        .collect();
    if cfg.resume {
        for node in &mut nodes {
            node.try_resume(cfg)?;
        }
    }
    let progress: Vec<NodeProgress> = (0..seats).map(|_| NodeProgress::default()).collect();
    for node in &nodes {
        node.publish_progress(&progress[node.idx]);
    }
    store.set_subscribers(n);
    let queue: WorkQueue<Node<'env>> = WorkQueue::new();
    let ends: Mutex<Vec<Option<NodeEnd>>> = Mutex::new((0..seats).map(|_| None).collect());
    let error = ErrSlot::default();
    let remaining = AtomicUsize::new(n);
    let next_seat = AtomicUsize::new(n);
    let workers = resolve_threads(cfg.threads).max(1).min(seats.max(1));
    if n == 0 {
        queue.close();
    }

    let driver_out = std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                elastic_node_worker(
                    backend, store, cfg, &ctx, &queue, &ends, &progress, &error, &remaining,
                )
            });
        }
        queue.push_all(nodes);
        let _close_store = CloseStoreOnDrop(store);
        let handle = ElasticHandle {
            store,
            progress: &progress,
            cfg,
            ctx: &ctx,
            queue: &queue,
            remaining: &remaining,
            next_seat: &next_seat,
            failed: &error.set,
            base_nodes: n,
        };
        match driver(&handle) {
            Ok(r) => Some(r),
            Err(e) => {
                error.record(e.context("router driver"));
                None
            }
        }
    });

    if let Some(e) = error.take() {
        return Err(e);
    }
    let driver_out = driver_out.expect("driver result present when no error was recorded");
    let slots = ends.into_inner().expect("ends poisoned");
    let mut end_list: Vec<NodeEnd> = slots.into_iter().flatten().collect();
    end_list.sort_by_key(NodeEnd::node);
    let survivors = end_list
        .iter()
        .filter(|e| !matches!(e, NodeEnd::Failed(_)))
        .count();
    if n > 0 && survivors == 0 {
        // the degradation floor: Ok requires at least one survivor
        let first = end_list.into_iter().find_map(|e| match e {
            NodeEnd::Failed(f) => Some(f.error),
            _ => None,
        });
        return Err(match first {
            Some(e) => e.context("every trainer node failed"),
            None => anyhow!("elastic run ended with no node outcomes"),
        });
    }
    let ElasticCtx { ledger, stats, .. } = ctx;
    Ok((
        ElasticReport {
            ends: end_list,
            stats: stats.snapshot(),
            ledger: ledger.into_inner().expect("elastic ledger poisoned"),
        },
        driver_out,
    ))
}

/// What the elastic driver can see *and do* while nodes run: everything
/// [`TrainerHandle`] offers, plus live membership — joining brand-new
/// nodes and re-adopting vacant seats.
pub struct ElasticHandle<'h, 'env> {
    store: &'h SnapshotStore,
    progress: &'h [NodeProgress],
    cfg: &'h NodeRunConfig,
    ctx: &'h ElasticCtx<'env, 'h>,
    queue: &'h WorkQueue<Node<'env>>,
    remaining: &'h AtomicUsize,
    next_seat: &'h AtomicUsize,
    failed: &'h AtomicBool,
    base_nodes: usize,
}

impl<'env> ElasticHandle<'_, 'env> {
    pub fn store(&self) -> &SnapshotStore {
        self.store
    }

    /// Total seats (initial fleet + spares), the progress-slot count.
    pub fn n_seats(&self) -> usize {
        self.progress.len()
    }

    /// Size of the initial fleet (seats below this started occupied).
    pub fn base_nodes(&self) -> usize {
        self.base_nodes
    }

    pub fn node(&self, seat: usize) -> &NodeProgress {
        &self.progress[seat]
    }

    /// Training steps completed across all seats so far.
    pub fn total_steps_done(&self) -> usize {
        self.progress.iter().map(NodeProgress::steps).sum()
    }

    /// Seats currently in the run (not yet finished/failed/left).
    pub fn live_nodes(&self) -> usize {
        self.remaining.load(Ordering::Relaxed)
    }

    /// The driver itself already failed on a previous poll.
    pub fn failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> ElasticStats {
        self.ctx.stats.snapshot()
    }

    /// Grow the fleet: claim the next spare seat and start a brand-new
    /// node on it. The newcomer seeds its expert from the current router
    /// snapshot ([`TrainBackend::init_joiner`]) instead of a cold init.
    /// Fails if no spare seat remains or the run has already drained.
    pub fn join_new_node(&self, seed: u64) -> Result<usize> {
        let seat = self.next_seat.fetch_add(1, Ordering::AcqRel);
        ensure!(
            seat < self.n_seats(),
            "no spare seat left for a joiner ({} seats; raise ElasticPolicy::max_extra_nodes)",
            self.n_seats()
        );
        self.ctx.seeds.lock().expect("seat seeds poisoned")[seat] = seed;
        let gen = (self.ctx.factory)(seat, 0);
        let mut node = Node::stream(
            seat,
            seed,
            gen,
            self.ctx.route_chunk,
            self.ctx.draw_budget,
            self.cfg,
        );
        node.joiner = true;
        node.publish_progress(&self.progress[seat]);
        // count the seat in *before* pushing: the queue must not close
        // underneath a node that is about to enter it
        self.remaining.fetch_add(1, Ordering::AcqRel);
        if !self.queue.push(node) {
            self.remaining.fetch_sub(1, Ordering::AcqRel);
            bail!("cannot join a new node: the run has already drained");
        }
        self.store.adjust_subscribers(1);
        self.ctx.stats.joins.fetch_add(1, Ordering::Relaxed);
        Ok(seat)
    }

    /// Re-fill a vacant seat (one whose node left without `adopt`) from
    /// its checkpoint. Returns the step the adopter resumed at.
    pub fn adopt_vacant(&self, seat: usize) -> Result<usize> {
        ensure!(seat < self.n_seats(), "seat {seat} out of range");
        let t0 = Instant::now();
        let (node, ckpt_bytes, resumed) = respawn_from_checkpoint(self.cfg, seat, self.ctx)?;
        node.publish_progress(&self.progress[seat]);
        self.remaining.fetch_add(1, Ordering::AcqRel);
        if !self.queue.push(node) {
            self.remaining.fetch_sub(1, Ordering::AcqRel);
            bail!("cannot adopt seat {seat}: the run has already drained");
        }
        self.store.adjust_subscribers(1);
        self.ctx.stats.adoptions.fetch_add(1, Ordering::Relaxed);
        self.ctx
            .ledger
            .lock()
            .expect("elastic ledger poisoned")
            .record_checkpoint_adopt(seat, ckpt_bytes, resumed as u64);
        self.ctx
            .stats
            .recovery_micros
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        Ok(resumed)
    }
}

// -------------------------------------------------------------------------
// production orchestration
// -------------------------------------------------------------------------

/// Which orchestration the trainer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Router EM → leader-sharded corpus → node-pool expert training.
    /// Bit-identical to the classic `run_pipeline` (it is its
    /// implementation now); snapshots refresh only at the EM/shard
    /// boundary, i.e. never during expert training.
    Staged,
    /// Expert nodes start immediately and train continuously against
    /// versioned router snapshots published at EM-round boundaries; no
    /// global barrier, no corpus-wide score all-gather — snapshot
    /// broadcasts are the only inter-node traffic.
    Async,
}

/// Orchestrator configuration on top of a [`PipelineConfig`].
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub mode: TrainMode,
    /// Node-checkpoint directory (`node{e}.ckpt`); `None` disables.
    pub checkpoint_dir: Option<PathBuf>,
    /// Checkpoint every N steps (0 = final checkpoint only).
    pub checkpoint_every: usize,
    /// Resume nodes from existing checkpoints. Router EM and (in staged
    /// mode) the sharding are deterministically re-derived; only the
    /// expensive expert training resumes mid-run.
    pub resume: bool,
    /// Async: publish a router snapshot every N EM rounds (the final
    /// round always publishes; 0 behaves as 1).
    pub snapshot_every: usize,
    /// Async: sequences per local routing call (0 = router prefix batch).
    pub route_chunk: usize,
    /// Async: per-node stream draw cap (0 = auto; see
    /// [`NodeRunConfig::draw_budget`]).
    pub draw_budget: u64,
    /// Async: JSON fault-plan spec for the elastic chaos harness
    /// (`None` and no leave/join schedule = the plain async path).
    pub chaos_spec: Option<PathBuf>,
    /// Async: schedule the last node to leave at this local step
    /// (0 = nobody leaves).
    pub leave_after: usize,
    /// Async: re-adopt the departed seat once the fleet has this many
    /// total steps (0 = no adoption).
    pub join_after: usize,
    /// Async: partition the expert seats across this many independent
    /// `SnapshotStore` fault domains (1 = single-fleet; see
    /// [`run_sharded_nodes`](super::fleet::run_sharded_nodes)).
    pub shards: usize,
}

impl TrainerConfig {
    pub fn staged() -> Self {
        TrainerConfig {
            mode: TrainMode::Staged,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
            snapshot_every: 1,
            route_chunk: 0,
            draw_budget: 0,
            chaos_spec: None,
            leave_after: 0,
            join_after: 0,
            shards: 1,
        }
    }

    pub fn asynchronous() -> Self {
        TrainerConfig {
            mode: TrainMode::Async,
            ..TrainerConfig::staged()
        }
    }
}

/// Run mixture training under either orchestration mode. Staged mode
/// reproduces the classic `run_pipeline` outputs bit-identically; async
/// mode returns the same [`PipelineResult`] shape with the ledger
/// holding snapshot broadcasts instead of score all-gathers, and the
/// segment size/purity diagnostics computed from what each node actually
/// trained on.
pub fn run_trainer(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
) -> Result<PipelineResult> {
    let router_meta = engine.variant(&p.router_variant)?.clone();
    let expert_meta = engine.variant(&p.expert_variant)?.clone();
    ensure!(
        router_meta.seq_len == expert_meta.seq_len,
        "router/expert seq_len mismatch"
    );
    let backend = EngineBackend {
        engine,
        router_meta: router_meta.clone(),
        expert_meta: expert_meta.clone(),
        expert_variant: p.expert_variant.clone(),
        prefix_len: p.prefix_len,
    };
    let em = EmConfig {
        n_routers: p.n_experts,
        rounds: p.em_rounds,
        chunk_size: p.em_chunk,
        steps_per_round: p.em_steps_per_round,
        prefix_len: p.prefix_len,
        seed: p.seed,
        threads: p.threads,
    };
    let run_cfg = NodeRunConfig {
        steps_per_node: p.expert_steps,
        log_every: 10,
        checkpoint_every: t.checkpoint_every,
        checkpoint_dir: t.checkpoint_dir.clone(),
        resume: t.resume,
        threads: p.threads,
        route_chunk: if t.route_chunk > 0 {
            t.route_chunk
        } else {
            router_meta.prefix_batch.max(1)
        },
        draw_budget: t.draw_budget,
        snapshot_wait_us: NodeRunConfig::default().snapshot_wait_us,
        legacy_flat_dir: None,
    };
    ensure!(
        t.shards <= 1 || matches!(t.mode, TrainMode::Async),
        "--shards requires async mode (staged mode has a single coordinator)"
    );
    let elastic = t.chaos_spec.is_some() || t.leave_after > 0 || t.join_after > 0;
    match t.mode {
        TrainMode::Staged => {
            run_trainer_staged(engine, bpe, p, &em, &run_cfg, &backend, expert_meta)
        }
        TrainMode::Async if t.shards > 1 => super::fleet::run_trainer_async_sharded(
            engine,
            bpe,
            p,
            t,
            &em,
            &run_cfg,
            &backend,
            router_meta,
            expert_meta,
        ),
        TrainMode::Async if elastic => run_trainer_async_elastic(
            engine,
            bpe,
            p,
            t,
            &em,
            &run_cfg,
            &backend,
            router_meta,
            expert_meta,
        ),
        TrainMode::Async => run_trainer_async(
            engine,
            bpe,
            p,
            t,
            &em,
            &run_cfg,
            &backend,
            router_meta,
            expert_meta,
        ),
    }
}

pub(crate) fn engine_transfer_scalars(engine: &Engine, log: &mut RunLog) {
    // Transfer accounting: engine-lifetime totals at completion, so run
    // records show what the device-resident buffer cache saved.
    let stats = engine.stats();
    log.scalar("engine/h2d_bytes", 0.0, stats.h2d_bytes as f64);
    log.scalar("engine/d2h_bytes", 0.0, stats.d2h_bytes as f64);
    log.scalar("engine/h2d_bytes_avoided", 0.0, stats.h2d_bytes_avoided as f64);
    log.scalar("engine/uploads_avoided", 0.0, stats.uploads_avoided as f64);
    log.scalar("engine/param_uploads", 0.0, stats.param_uploads as f64);
}

fn run_trainer_staged(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    let mut ledger = CommLedger::default();
    let mut log = RunLog::new();

    // Stage 1: routers (Alg. 1 lines 1-10).
    let mut router_gen = SequenceGen::new(bpe, backend.router_meta.seq_len, p.seed ^ 0x52_0000);
    let trained = train_routers(
        engine,
        &p.router_variant,
        em,
        &mut router_gen,
        &mut ledger,
        &mut log,
    )?;

    // Stage 2: shard the expert corpus (lines 12-13); single-epoch data,
    // so the corpus at least covers every expert's step budget.
    let needed = p.n_experts * p.expert_steps * expert_meta.train_batch;
    let n_shard = p.shard_sequences.max(needed);
    let threads = resolve_threads(p.threads);
    let mut shard_gen = SequenceGen::new(bpe, expert_meta.seq_len, p.seed ^ 0x5AD);
    let shards = shard_corpus(
        engine,
        &trained.routers,
        &trained.meta,
        &mut shard_gen,
        n_shard,
        p.prefix_len,
        &mut ledger,
        threads,
    )?;
    let segment_purity = shards.segment_purity();
    let segment_sizes: Vec<usize> = shards.segments.iter().map(Vec::len).collect();

    // Stage 3: independent experts (lines 14-16) as staged nodes on the
    // worker pool — same seeds, same segments, same batch discipline as
    // the classic loop, so outputs are bit-identical at any worker count.
    let jobs: Vec<(u64, Vec<Sequence>)> = shards
        .segments
        .into_iter()
        .enumerate()
        .map(|(e, segment)| (p.seed ^ (0xE0 + e as u64), segment))
        .collect();
    let outcomes = run_staged_nodes(backend, jobs, run_cfg)?;
    let mut experts = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        log.merge_prefixed(&format!("expert{}", o.node), &o.log);
        experts.push(o.state);
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
        elastic: None,
    })
}

#[allow(clippy::too_many_arguments)]
fn run_trainer_async(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend,
    router_meta: VariantMeta,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    ensure!(
        p.em_rounds > 0,
        "async training needs at least one EM round to publish a router snapshot"
    );
    let mut log = RunLog::new();
    let store = SnapshotStore::new(p.n_experts);
    let every = t.snapshot_every.max(1);
    let rounds = em.rounds;

    // One independent fresh-data stream per node; the router leader keeps
    // the same stream it uses in staged mode.
    let jobs: Vec<_> = (0..p.n_experts)
        .map(|e| {
            (
                p.seed ^ (0xE0 + e as u64),
                SequenceGen::new(bpe, expert_meta.seq_len, p.seed ^ (0xA5_0000 + e as u64)),
            )
        })
        .collect();

    let em_cfg = em.clone();
    let (outcomes, trained) = {
        let log = &mut log;
        let store_ref = &store;
        run_async_nodes(backend, store_ref, jobs, run_cfg, move |_handle| {
            // Router EM runs on this (leader) thread while nodes train.
            // Its score exchanges are leader-local (all routers live
            // here), so they cost the cluster nothing — the broadcasts
            // recorded by the store are the only inter-node traffic.
            let mut local_ledger = CommLedger::default();
            let mut router_gen =
                SequenceGen::new(bpe, router_meta.seq_len, p.seed ^ 0x52_0000);
            train_routers_hooked(
                engine,
                &p.router_variant,
                &em_cfg,
                &mut router_gen,
                &mut local_ledger,
                log,
                |round, routers| {
                    if (round + 1) % every == 0 || round + 1 == rounds {
                        store_ref.publish(routers.to_vec(), round + 1);
                    }
                    Ok(())
                },
            )
        })?
    };

    let ledger = store.take_ledger();
    let mut experts = Vec::with_capacity(outcomes.len());
    let mut segment_purity = Vec::with_capacity(outcomes.len());
    let mut segment_sizes = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        log.merge_prefixed(&format!("expert{}", o.node), &o.log);
        log.scalar(&format!("async/node{}_drawn", o.node), 0.0, o.drawn as f64);
        log.scalar(&format!("async/node{}_kept", o.node), 0.0, o.kept as f64);
        log.scalar(
            &format!("async/node{}_steps", o.node),
            0.0,
            o.steps_done as f64,
        );
        segment_purity.push(o.purity());
        segment_sizes.push(o.trained_sequences() as usize);
        experts.push(o.state);
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
        elastic: None,
    })
}

/// Async training through the elastic machinery: same shape as
/// [`run_trainer_async`], but nodes run under a [`FaultPlan`] (loaded
/// from [`TrainerConfig::chaos_spec`]) and an optional leave/adopt
/// schedule (`leave_after`/`join_after`). The returned ledger holds the
/// snapshot broadcasts *plus* the `CheckpointAdopt`/`ParamMerge` events
/// the recovery paths produced; failed seats degrade to their last
/// checkpoint (or a cold init) instead of failing the run.
#[allow(clippy::too_many_arguments)]
fn run_trainer_async_elastic(
    engine: &Engine,
    bpe: &Bpe,
    p: &PipelineConfig,
    t: &TrainerConfig,
    em: &EmConfig,
    run_cfg: &NodeRunConfig,
    backend: &EngineBackend,
    router_meta: VariantMeta,
    expert_meta: VariantMeta,
) -> Result<PipelineResult> {
    ensure!(
        p.em_rounds > 0,
        "async training needs at least one EM round to publish a router snapshot"
    );
    let faults = match &t.chaos_spec {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading chaos spec {}", path.display()))?;
            FaultPlan::from_json_str(&text)
                .with_context(|| format!("parsing chaos spec {}", path.display()))?
        }
        None => FaultPlan::none(),
    };
    let mut leaves = Vec::new();
    if t.leave_after > 0 {
        ensure!(p.n_experts > 0, "cannot schedule a leave with zero experts");
        leaves.push(LeaveEvent {
            node: p.n_experts - 1,
            at_step: t.leave_after,
            adopt: false,
            rejoin: None,
        });
    }
    let plan = ElasticPlan {
        faults,
        leaves,
        ..ElasticPlan::default()
    };

    let mut log = RunLog::new();
    let store = SnapshotStore::new(p.n_experts);
    let every = t.snapshot_every.max(1);
    let rounds = em.rounds;
    let seeds: Vec<u64> = (0..p.n_experts).map(|e| p.seed ^ (0xE0 + e as u64)).collect();
    // salt 0 reproduces the plain async streams exactly; nonzero salts
    // (offline rejoin legs) mix into a disjoint stream
    let factory = |e: usize, salt: u64| {
        SequenceGen::new(
            bpe,
            expert_meta.seq_len,
            p.seed ^ (0xA5_0000 + e as u64) ^ salt.wrapping_mul(0x9E37_79B9),
        )
    };

    let em_cfg = em.clone();
    let (report, trained) = {
        let log = &mut log;
        let plan_ref = &plan;
        run_elastic_nodes(backend, &store, &seeds, factory, run_cfg, &plan, |handle| {
            let mut local_ledger = CommLedger::default();
            let mut router_gen = SequenceGen::new(bpe, router_meta.seq_len, p.seed ^ 0x52_0000);
            let mut next_version: u64 = 0;
            let mut adopted = t.join_after == 0;
            train_routers_hooked(
                engine,
                &p.router_variant,
                &em_cfg,
                &mut router_gen,
                &mut local_ledger,
                log,
                |round, routers| {
                    if !adopted
                        && t.leave_after > 0
                        && handle.stats().leaves > 0
                        && handle.total_steps_done() >= t.join_after
                    {
                        // hot-spare adoption: re-fill the departed seat
                        // from its checkpoint (best-effort — the run may
                        // already have drained)
                        adopted = true;
                        if let Err(e) = handle.adopt_vacant(p.n_experts - 1) {
                            eprintln!("[trainer] hot-spare adoption skipped: {e:#}");
                        }
                    }
                    if (round + 1) % every == 0 || round + 1 == rounds {
                        next_version += 1;
                        if let Some(min) = plan_ref.faults.publish_gate(next_version) {
                            // delayed publish: hold this snapshot until
                            // the fleet has trained `min` total steps —
                            // deterministic in steps, not wall-clock
                            while (handle.total_steps_done() as u64) < min
                                && handle.live_nodes() > 0
                                && !handle.failed()
                            {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                        handle.store().publish(routers.to_vec(), round + 1);
                    }
                    Ok(())
                },
            )
        })?
    };

    let ElasticReport {
        ends,
        stats,
        ledger: elastic_ledger,
    } = report;
    let mut ledger = store.take_ledger();
    ledger.events.extend(elastic_ledger.events);
    log.scalar("elastic/kills", 0.0, stats.kills as f64);
    log.scalar("elastic/adoptions", 0.0, stats.adoptions as f64);
    log.scalar("elastic/leaves", 0.0, stats.leaves as f64);
    log.scalar("elastic/joins", 0.0, stats.joins as f64);
    log.scalar("elastic/merges", 0.0, stats.merges as f64);
    log.scalar("elastic/steps_lost", 0.0, stats.steps_lost as f64);
    log.scalar(
        "elastic/transient_retries",
        0.0,
        stats.transient_retries as f64,
    );
    log.scalar(
        "elastic/recovery_micros",
        0.0,
        stats.recovery_micros as f64,
    );

    let mut slots: Vec<Option<NodeEnd>> = (0..p.n_experts).map(|_| None).collect();
    for end in ends {
        let seat = end.node();
        if seat < slots.len() {
            slots[seat] = Some(end);
        }
    }
    let mut experts = Vec::with_capacity(p.n_experts);
    let mut segment_purity = Vec::with_capacity(p.n_experts);
    let mut segment_sizes = Vec::with_capacity(p.n_experts);
    for (e, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(NodeEnd::Completed(o)) | Some(NodeEnd::Left(o)) => {
                log.merge_prefixed(&format!("expert{e}"), &o.log);
                log.scalar(&format!("async/node{e}_drawn"), 0.0, o.drawn as f64);
                log.scalar(&format!("async/node{e}_kept"), 0.0, o.kept as f64);
                log.scalar(&format!("async/node{e}_steps"), 0.0, o.steps_done as f64);
                segment_purity.push(o.purity());
                segment_sizes.push(o.trained_sequences() as usize);
                experts.push(o.state);
            }
            other => {
                // degraded seat: serve the best state we can find —
                // salvage from the failure, else its checkpoint, else a
                // cold init — and mark it in the log
                if let Some(NodeEnd::Failed(f)) = &other {
                    eprintln!("[trainer] node {e} degraded: {:#}", f.error);
                }
                log.scalar(&format!("elastic/node{e}_degraded"), 0.0, 1.0);
                segment_purity.push(0.0);
                segment_sizes.push(0);
                let salvage = match other {
                    Some(NodeEnd::Failed(f)) => f.salvage,
                    _ => None,
                };
                let state = match salvage {
                    Some(s) => s,
                    None => {
                        let from_ckpt = run_cfg
                            .checkpoint_dir
                            .as_ref()
                            .map(|d| ckpt_path(d, e))
                            .filter(|path| path.exists());
                        match from_ckpt {
                            Some(path) => {
                                load_node_checkpoint(&path)
                                    .with_context(|| {
                                        format!("recovering degraded node {e} from its checkpoint")
                                    })?
                                    .state
                            }
                            None => backend.init_expert(e, p.seed ^ (0xE0 + e as u64))?,
                        }
                    }
                };
                experts.push(state);
            }
        }
    }

    engine_transfer_scalars(engine, &mut log);
    Ok(PipelineResult {
        mixture: Mixture {
            routers: trained.routers,
            router_meta: trained.meta,
            experts,
            expert_meta,
        },
        ledger,
        log,
        segment_purity,
        segment_sizes,
        elastic: Some(super::fleet::ElasticSummary {
            stats,
            shards: Vec::new(),
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_publishes_versions_and_records_broadcasts() {
        let store = SnapshotStore::new(4);
        assert_eq!(store.version(), 0);
        assert!(store.current().is_none());
        let r = TrainState::from_params("r", vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], 0);
        assert_eq!(store.publish(vec![r.clone(), r.clone()], 1), 1);
        assert_eq!(store.publish(vec![r], 2), 2);
        assert_eq!(store.version(), 2);
        let snap = store.current().unwrap();
        assert_eq!(snap.version, 2);
        assert_eq!(snap.em_round, 2);
        let ledger = store.take_ledger();
        // publish 1: two 8-param routers = 64 B/subscriber; publish 2: 32 B
        assert_eq!(
            ledger.rounds(crate::coordinator::comm::CommKind::SnapshotBroadcast),
            2
        );
        assert_eq!(ledger.total_bytes(), 4 * 64 + 4 * 32);
    }

    #[test]
    fn closed_empty_store_fails_waiters() {
        let store = SnapshotStore::new(1);
        store.close();
        let err = store.wait_current().unwrap_err().to_string();
        assert!(err.contains("closed before any"), "{err}");
        assert!(err.contains("unsharded"), "{err}");
        assert!(err.contains("external waiter"), "{err}");
    }

    #[test]
    fn closed_store_with_snapshot_keeps_serving() {
        let store = SnapshotStore::new(1);
        let r = TrainState::from_params("r", vec![1.0], vec![0.0], vec![0.0], 0);
        store.publish(vec![r], 1);
        store.close();
        assert_eq!(store.wait_current().unwrap().version, 1);
        assert_eq!(store.current().unwrap().version, 1);
    }

    #[test]
    fn trainer_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SnapshotStore>();
        assert_send_sync::<RouterSnapshot>();
        assert_send_sync::<NodeProgress>();
        assert_send_sync::<NodeOutcome>();
        assert_send_sync::<ElasticStats>();
        assert_send_sync::<ElasticPlan>();
    }

    #[test]
    fn publish_with_zero_subscribers_costs_nothing() {
        let store = SnapshotStore::new(0);
        let r = TrainState::from_params("r", vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], 0);
        assert_eq!(store.publish(vec![r], 1), 1);
        let ledger = store.take_ledger();
        // the publisher event is still recorded (the round happened), but
        // nothing was sent and no receive events exist
        assert_eq!(
            ledger.rounds(crate::coordinator::comm::CommKind::SnapshotBroadcast),
            1
        );
        assert_eq!(ledger.total_bytes(), 0);
        assert_eq!(ledger.events.len(), 1);
    }

    #[test]
    fn take_ledger_mid_run_drains_without_losing_later_events() {
        let store = SnapshotStore::new(2);
        let r = TrainState::from_params("r", vec![0.0; 4], vec![0.0; 4], vec![0.0; 4], 0);
        store.publish(vec![r.clone()], 1);
        let first = store.take_ledger();
        assert_eq!(first.total_bytes(), 2 * 16);
        // draining mid-run must leave the store fully functional
        store.publish(vec![r.clone(), r], 2);
        let second = store.take_ledger();
        assert_eq!(second.total_bytes(), 2 * 32);
        assert!(second.events.iter().all(|e| e.step == 2));
        assert_eq!(store.take_ledger().events.len(), 0);
    }

    #[test]
    fn double_close_is_idempotent_and_late_publish_still_serves() {
        let store = SnapshotStore::new(1);
        store.close();
        store.close();
        assert!(store.wait_current().is_err());
        // a publish that raced the close still lands and serves waiters
        let r = TrainState::from_params("r", vec![1.0], vec![0.0], vec![0.0], 0);
        store.publish(vec![r], 1);
        assert_eq!(store.wait_current().unwrap().version, 1);
    }

    #[test]
    fn broadcast_byte_totals_exact_under_subscriber_churn() {
        let store = SnapshotStore::new(3);
        let r = TrainState::from_params("r", vec![0.0; 8], vec![0.0; 8], vec![0.0; 8], 0);
        let b = 32u64; // one 8-param f32 router
        store.publish(vec![r.clone()], 1);
        assert_eq!(store.adjust_subscribers(-1), 2); // a node left
        store.publish(vec![r.clone()], 2);
        assert_eq!(store.adjust_subscribers(2), 4); // two joined
        store.publish(vec![r.clone()], 3);
        // saturating floor: over-removal can never underflow
        assert_eq!(store.adjust_subscribers(-100), 0);
        store.publish(vec![r], 4);
        let ledger = store.take_ledger();
        assert_eq!(ledger.total_bytes(), 3 * b + 2 * b + 4 * b);
        assert_eq!(
            ledger.rounds(crate::coordinator::comm::CommKind::SnapshotBroadcast),
            4
        );
    }

    #[test]
    fn wait_current_for_times_out_structurally() {
        let store = SnapshotStore::new(1);
        let err = store
            .wait_current_for(Some(Duration::from_millis(5)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("timed out"), "{err}");
        // an anonymous waiter on an unsharded store still gets full context
        assert!(err.contains("unsharded"), "{err}");
        assert!(err.contains("external waiter"), "{err}");
        assert!(err.contains("version >= 1"), "{err}");
    }

    #[test]
    fn wait_errors_carry_shard_and_node_context() {
        let store = SnapshotStore::new_sharded(2, 1);
        assert_eq!(store.shard(), Some(1));
        let err = store
            .wait_current_ctx(Some(Duration::from_millis(5)), Some(3))
            .unwrap_err()
            .to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(err.contains("shard 1"), "{err}");
        assert!(err.contains("node 3"), "{err}");
        assert!(err.contains("version >= 1"), "{err}");

        let closed = SnapshotStore::new_sharded(1, 0);
        closed.close();
        let err = closed
            .wait_current_ctx(None, Some(0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("closed before any"), "{err}");
        assert!(err.contains("shard 0"), "{err}");
        assert!(err.contains("node 0"), "{err}");
    }
}
