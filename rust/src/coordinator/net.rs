//! TCP/JSONL wire front-end over the continuous-batching scheduler
//! ([`super::server`]).
//!
//! Std-only (the build is offline — no tokio/hyper): a listener thread
//! accepts connections, per-connection reader threads lex requests
//! straight off the socket buffer with the zero-copy lexer
//! ([`crate::util::lex`] — no `Json` tree on the request path), and
//! responses stream back through [`run_server_streaming`]'s sink the
//! moment each completes. **No request ever waits for a wave**, and no
//! response waits for drain.
//!
//! # Wire protocol
//!
//! Newline-delimited JSON in both directions; one request or response
//! object per line, `\r\n` tolerated, blank lines ignored. A request line
//! is an object with an integer `id` and exactly one body field:
//!
//! ```text
//! {"id": 7, "tokens": [17, 4, 1093, ...]}     pre-tokenized
//! {"id": 8, "text": "the quick brown fox"}    server-side BPE encode
//! ```
//!
//! `id` is the client's correlation key (`u64`, full precision): the
//! server echoes it verbatim and never interprets it, so ids need not be
//! unique across connections — internally every request is re-keyed.
//! Responses arrive **in completion order**, not submission order; one
//! line per request line, always one of:
//!
//! ```text
//! {"id":7,"expert":2,"nll":3.125,"queue_micros":41,"route_micros":12,"exec_micros":97}
//! {"code":429,"error":"shed","id":7}          arrival queue past high water
//! {"code":400,"error":"bad_request","detail":"..."}   unparseable/invalid line
//! {"code":503,"error":"draining","id":7}      submitted while shutting down
//! ```
//!
//! A connection refused by the connection limit receives a single
//! `{"code":503,"error":"too_many_connections"}` line and is closed.
//! Non-finite NLLs are encoded as `null`.
//!
//! # Shedding
//!
//! Requests enter the scheduler through
//! [`ServerClient::try_submit`]: when the arrival queue already holds
//! `high_water` entries the request is refused with the 429-style line
//! above (counted in [`SchedStats::shed`] and
//! [`NetReport::shed_lines`]) instead of queueing unboundedly — the
//! client sees a structured answer, never a hang or a dropped
//! connection.
//!
//! # Fairness
//!
//! Each connection owns a lane in a round-robin multiplexer
//! ([`FairMux`]): the single pump thread that feeds the arrival queue
//! rotates over lanes, taking one request per turn, so a client
//! streaming thousands of lines cannot starve a client sending one.
//!
//! # Drain
//!
//! [`NetHandle::shutdown`] stops the accept loop, half-closes every
//! connection's read side (readers see EOF after lexing what already
//! arrived), drains the multiplexer, and returns from the scheduler
//! driver — at which point the scheduler answers **everything already
//! admitted** through the sink before the sockets close. Every request
//! line read before the half-close gets exactly one response line.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::inference::{Request, Response};
use super::server::{
    run_server_streaming, SchedStats, ServeBackend, ServerClient, ServerConfig, SubmitOutcome,
};
use crate::util::lex::{parse_request_line, LineBuf};
use crate::util::Json;

/// Server-side text → token-row encoder for `{"id","text"}` requests
/// (wraps the BPE encoder in `main.rs`; `None` disables the text path).
pub type Encode<'a> = &'a (dyn Fn(&str) -> Result<Vec<u32>> + Sync);

/// Front-end knobs (the scheduler's own knobs ride in `server`).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `"127.0.0.1:7878"` (`:0` picks a free port —
    /// read it back from [`NetHandle::addr`]).
    pub listen: String,
    /// Max simultaneously served connections; further connects get the
    /// `too_many_connections` line and close. `0` = unlimited.
    pub max_conns: usize,
    /// Arrival-queue high-water mark: a request arriving while the queue
    /// holds this many entries is shed. `0` sheds everything (useful only
    /// in tests).
    pub high_water: usize,
    /// When set, requests whose token row length differs are rejected
    /// with a 400 line (the fixed-shape engines want `seq_len + 1` rows;
    /// stub backends take anything).
    pub want_tokens: Option<usize>,
    /// Scheduler knobs behind the socket.
    pub server: ServerConfig,
}

/// Remote control for a running [`serve_net`]: the bound address and the
/// shutdown trigger. Cloneable; handed to the caller via `on_ready`.
#[derive(Clone)]
pub struct NetHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
}

impl NetHandle {
    /// The actually-bound address (resolves `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful drain (idempotent): stop accepting, answer
    /// everything admitted, then return from [`serve_net`].
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

/// Wire-side counters (the socket analogue of [`SchedStats`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetReport {
    /// Connections accepted and served.
    pub connections: usize,
    /// Connections refused by the connection limit.
    pub conns_refused: usize,
    /// Successful response lines written.
    pub ok_lines: usize,
    /// 429-style shed lines written (equals the scheduler's
    /// [`SchedStats::shed`] plus any drain-time refusals).
    pub shed_lines: usize,
    /// 400-style bad-request lines written.
    pub bad_lines: usize,
}

#[derive(Default)]
struct Counters {
    connections: AtomicUsize,
    conns_refused: AtomicUsize,
    ok_lines: AtomicUsize,
    shed_lines: AtomicUsize,
    bad_lines: AtomicUsize,
}

impl Counters {
    fn snapshot(&self) -> NetReport {
        NetReport {
            connections: self.connections.load(Ordering::Relaxed),
            conns_refused: self.conns_refused.load(Ordering::Relaxed),
            ok_lines: self.ok_lines.load(Ordering::Relaxed),
            shed_lines: self.shed_lines.load(Ordering::Relaxed),
            bad_lines: self.bad_lines.load(Ordering::Relaxed),
        }
    }
}

// ----------------------------------------------------------------------
// Per-client round-robin multiplexer
// ----------------------------------------------------------------------

struct MuxState<T> {
    lanes: Vec<VecDeque<T>>,
    /// Next lane the rotating scan starts from.
    cursor: usize,
    draining: bool,
}

/// Round-robin fair multiplexer: each connection registers a lane, the
/// pump pops one item per turn rotating over lanes. `next` blocks while
/// every lane is empty and returns `None` only after
/// [`drain`](FairMux::drain) with all lanes exhausted.
///
/// Fairness contract (asserted by `rust/tests/net.rs`): one pop serves
/// at most one item from a lane before the scan moves past it, so a lane
/// holding a single item waits at most one full rotation behind any
/// backlog the other lanes have — a firehose client cannot starve a
/// trickle client.
pub struct FairMux<T> {
    state: Mutex<MuxState<T>>,
    cv: Condvar,
}

impl<T> Default for FairMux<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FairMux<T> {
    pub fn new() -> Self {
        FairMux {
            state: Mutex::new(MuxState {
                lanes: Vec::new(),
                cursor: 0,
                draining: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MuxState<T>> {
        self.state.lock().expect("mux poisoned")
    }

    /// Open a new lane and return its index.
    pub fn register(&self) -> usize {
        let mut st = self.lock();
        st.lanes.push(VecDeque::new());
        st.lanes.len() - 1
    }

    /// Queue `item` on `lane` and wake any blocked [`next`](FairMux::next).
    pub fn push(&self, lane: usize, item: T) {
        let mut st = self.lock();
        st.lanes[lane].push_back(item);
        drop(st);
        self.cv.notify_all();
    }

    /// Pop the next item, rotating over lanes; blocks while every lane is
    /// empty, returns `None` only after [`drain`](FairMux::drain).
    pub fn next(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            let n = st.lanes.len();
            for k in 0..n {
                let lane = (st.cursor + k) % n;
                if let Some(item) = st.lanes[lane].pop_front() {
                    // advance past the served lane so its next item waits
                    // a full rotation
                    st.cursor = (lane + 1) % n;
                    return Some(item);
                }
            }
            if st.draining {
                return None;
            }
            st = self.cv.wait(st).expect("mux poisoned");
        }
    }

    /// Switch to drain mode: [`next`](FairMux::next) stops blocking and
    /// returns `None` once every lane is exhausted.
    pub fn drain(&self) {
        self.lock().draining = true;
        self.cv.notify_all();
    }
}

// ----------------------------------------------------------------------
// Wire plumbing
// ----------------------------------------------------------------------

/// A parsed request staged between a reader thread and the pump. The
/// internal id re-keys the request (client ids need not be unique across
/// connections); the original id is echoed on the response line.
struct Staged {
    internal_id: u64,
    orig_id: u64,
    tokens: Vec<u32>,
    writer: Arc<Mutex<TcpStream>>,
}

/// Where to send a response once the scheduler completes the request.
struct PendingEntry {
    orig_id: u64,
    writer: Arc<Mutex<TcpStream>>,
}

type PendingMap = Mutex<HashMap<u64, PendingEntry>>;

/// Write one response line (single `write_all`, so concurrent writers on
/// the shared half never interleave bytes). A write error means the
/// client went away — not a server error; the line is dropped.
fn write_line(writer: &Mutex<TcpStream>, line: &str) {
    let mut framed = String::with_capacity(line.len() + 1);
    framed.push_str(line);
    framed.push('\n');
    let mut w = writer.lock().expect("writer poisoned");
    let _ = w.write_all(framed.as_bytes());
}

/// f32 → JSON number text; non-finite values become `null` (JSON has no
/// NaN/inf).
fn json_f32(v: f32) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn ok_line(orig_id: u64, r: &Response) -> String {
    format!(
        r#"{{"id":{},"expert":{},"nll":{},"queue_micros":{},"route_micros":{},"exec_micros":{}}}"#,
        orig_id,
        r.expert,
        json_f32(r.nll),
        r.queue_micros,
        r.route_micros,
        r.exec_micros
    )
}

fn shed_line(orig_id: u64) -> String {
    format!(r#"{{"code":429,"error":"shed","id":{orig_id}}}"#)
}

fn draining_line(orig_id: u64) -> String {
    format!(r#"{{"code":503,"error":"draining","id":{orig_id}}}"#)
}

/// 400 line; `detail` is arbitrary error text, so this one goes through
/// the tree writer for escaping.
fn bad_request_line(detail: &str) -> String {
    Json::obj(vec![
        ("code", Json::num(400.0)),
        ("error", Json::str("bad_request")),
        ("detail", Json::str(detail)),
    ])
    .to_string()
}

const REFUSED_LINE: &str = r#"{"code":503,"error":"too_many_connections"}"#;

/// Serve `backend` over TCP until [`NetHandle::shutdown`]: bind
/// `cfg.listen`, hand the caller a [`NetHandle`] through `on_ready`
/// (called on the serving thread once the socket is listening — spawn or
/// stash, don't block), then accept/read/schedule/respond per the module
/// protocol. Returns the scheduler counters and the wire counters after
/// a graceful drain; the first backend error aborts serving and returns
/// it instead.
pub fn serve_net<B: ServeBackend>(
    backend: &B,
    cfg: &NetConfig,
    encode: Option<Encode<'_>>,
    on_ready: impl FnOnce(NetHandle) + Send,
) -> Result<(SchedStats, NetReport)> {
    let listener = TcpListener::bind(&cfg.listen)
        .with_context(|| format!("binding serve listener on {}", cfg.listen))?;
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    let addr = listener.local_addr().context("listener local_addr")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let handle = NetHandle {
        addr,
        shutdown: Arc::clone(&shutdown),
    };

    let counters = Counters::default();
    let pending: PendingMap = Mutex::new(HashMap::new());
    let next_internal = AtomicU64::new(0);
    let mux: FairMux<Staged> = FairMux::new();
    let live_conns = AtomicUsize::new(0);

    let sink = |_seq: usize, resp: Response| {
        // the pump inserts the entry before try_submit, so it is always
        // present by the time the scheduler answers
        let entry = pending
            .lock()
            .expect("pending map poisoned")
            .remove(&resp.id);
        if let Some(entry) = entry {
            write_line(&entry.writer, &ok_line(entry.orig_id, &resp));
            counters.ok_lines.fetch_add(1, Ordering::Relaxed);
        }
    };

    let (stats, ()) = run_server_streaming(backend, &cfg.server, sink, |client| {
        on_ready(handle);
        let counters = &counters;
        let pending = &pending;
        let mux = &mux;
        let next_internal = &next_internal;
        let live_conns = &live_conns;
        let shutdown = &shutdown;
        std::thread::scope(|s| {
            // pump: lane-fair feed of the arrival queue
            s.spawn(|| pump_loop(client, mux, pending, cfg.high_water, counters));

            // accept loop on the driver thread
            let mut readers = Vec::new();
            let mut read_halves: Vec<Arc<Mutex<TcpStream>>> = Vec::new();
            while !shutdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // the accepted socket must block (reads park the
                        // reader thread, not spin)
                        let _ = stream.set_nonblocking(false);
                        if cfg.max_conns != 0
                            && live_conns.load(Ordering::Relaxed) >= cfg.max_conns
                        {
                            counters.conns_refused.fetch_add(1, Ordering::Relaxed);
                            write_line(&Mutex::new(stream), REFUSED_LINE);
                            continue; // dropped = closed
                        }
                        let writer = match stream.try_clone() {
                            Ok(w) => Arc::new(Mutex::new(w)),
                            Err(_) => continue, // dying socket: drop it
                        };
                        counters.connections.fetch_add(1, Ordering::Relaxed);
                        live_conns.fetch_add(1, Ordering::Relaxed);
                        // a second arc of the same socket, kept by the
                        // accept loop purely to half-close reads at drain
                        read_halves.push(Arc::clone(&writer));
                        let lane = mux.register();
                        let want_tokens = cfg.want_tokens;
                        let writer_for_reader = Arc::clone(&writer);
                        readers.push(s.spawn(move || {
                            reader_loop(
                                stream,
                                lane,
                                mux,
                                next_internal,
                                writer_for_reader,
                                encode,
                                want_tokens,
                                counters,
                            );
                            live_conns.fetch_sub(1, Ordering::Relaxed);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => {
                        // transient accept failure (e.g. EMFILE): back off
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }

            // graceful drain: EOF the readers (they lex what already
            // arrived, then exit), join them, then let the pump finish
            // the staged backlog
            for half in &read_halves {
                let _ = half
                    .lock()
                    .expect("writer poisoned")
                    .shutdown(Shutdown::Read);
            }
            for r in readers {
                let _ = r.join();
            }
            mux.drain();
            // the pump joins at scope exit; the scheduler then drains
            // everything admitted and the sink flushes the last ok lines
        });
    })?;

    Ok((stats, counters.snapshot()))
}

/// One connection's reader: blocking socket reads → [`LineBuf`] →
/// zero-copy request extraction → the connection's mux lane. Malformed
/// lines get their 400 response right here (the scheduler never sees
/// them); EOF or a read error ends the connection.
#[allow(clippy::too_many_arguments)]
fn reader_loop(
    mut stream: TcpStream,
    lane: usize,
    mux: &FairMux<Staged>,
    next_internal: &AtomicU64,
    writer: Arc<Mutex<TcpStream>>,
    encode: Option<Encode<'_>>,
    want_tokens: Option<usize>,
    counters: &Counters,
) {
    let mut buf = LineBuf::new();
    let mut chunk = [0u8; 4096];
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => return, // EOF (client done, or drain half-close)
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return, // reset/aborted: nothing more to read
        };
        buf.feed(&chunk[..n]);
        while let Some(line) = buf.next_line() {
            if line.iter().all(|b| b.is_ascii_whitespace()) {
                continue;
            }
            let wire = match parse_request_line(line) {
                Ok(w) => w,
                Err(e) => {
                    counters.bad_lines.fetch_add(1, Ordering::Relaxed);
                    write_line(&writer, &bad_request_line(&e.to_string()));
                    continue;
                }
            };
            let orig_id = wire.id;
            let tokens = match (wire.tokens, wire.text) {
                (Some(t), _) => t,
                (None, Some(text)) => {
                    let Some(enc) = encode else {
                        counters.bad_lines.fetch_add(1, Ordering::Relaxed);
                        write_line(
                            &writer,
                            &bad_request_line("this server accepts \"tokens\" only"),
                        );
                        continue;
                    };
                    match enc(&text) {
                        Ok(t) => t,
                        Err(e) => {
                            counters.bad_lines.fetch_add(1, Ordering::Relaxed);
                            write_line(&writer, &bad_request_line(&format!("encode: {e}")));
                            continue;
                        }
                    }
                }
                (None, None) => unreachable!("extractor guarantees one body field"),
            };
            if let Some(n) = want_tokens {
                if tokens.len() != n {
                    counters.bad_lines.fetch_add(1, Ordering::Relaxed);
                    write_line(
                        &writer,
                        &bad_request_line(&format!(
                            "expected exactly {n} tokens, got {}",
                            tokens.len()
                        )),
                    );
                    continue;
                }
            }
            mux.push(
                lane,
                Staged {
                    internal_id: next_internal.fetch_add(1, Ordering::Relaxed),
                    orig_id,
                    tokens,
                    writer: Arc::clone(&writer),
                },
            );
        }
    }
}

/// The single pump thread: rotate fairly over lanes, submit each staged
/// request with the high-water probe, answer sheds/drain refusals
/// immediately. Registering the pending entry **before** `try_submit`
/// closes the race with the sink (a response can complete the instant
/// the request is admitted).
fn pump_loop(
    client: &ServerClient<'_>,
    mux: &FairMux<Staged>,
    pending: &PendingMap,
    high_water: usize,
    counters: &Counters,
) {
    while let Some(staged) = mux.next() {
        pending.lock().expect("pending map poisoned").insert(
            staged.internal_id,
            PendingEntry {
                orig_id: staged.orig_id,
                writer: Arc::clone(&staged.writer),
            },
        );
        let req = Request {
            id: staged.internal_id,
            tokens: staged.tokens,
        };
        match client.try_submit(req, high_water) {
            SubmitOutcome::Accepted => {}
            SubmitOutcome::Shed => {
                pending
                    .lock()
                    .expect("pending map poisoned")
                    .remove(&staged.internal_id);
                counters.shed_lines.fetch_add(1, Ordering::Relaxed);
                write_line(&staged.writer, &shed_line(staged.orig_id));
            }
            SubmitOutcome::Closed => {
                // only reachable after a backend error force-closed the
                // arrival queue: still answer the line
                pending
                    .lock()
                    .expect("pending map poisoned")
                    .remove(&staged.internal_id);
                counters.shed_lines.fetch_add(1, Ordering::Relaxed);
                write_line(&staged.writer, &draining_line(staged.orig_id));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fair_mux_round_robins_across_lanes() {
        let mux: FairMux<&'static str> = FairMux::new();
        let a = mux.register();
        let b = mux.register();
        mux.push(a, "a1");
        mux.push(a, "a2");
        mux.push(a, "a3");
        mux.push(b, "b1");
        // one per lane per rotation: the backlogged lane cannot starve
        // the light one
        assert_eq!(mux.next(), Some("a1"));
        assert_eq!(mux.next(), Some("b1"));
        assert_eq!(mux.next(), Some("a2"));
        assert_eq!(mux.next(), Some("a3"));
        mux.drain();
        assert_eq!(mux.next(), None);
    }

    #[test]
    fn fair_mux_drain_wakes_blocked_consumer() {
        let mux: FairMux<u32> = FairMux::new();
        std::thread::scope(|s| {
            let h = s.spawn(|| mux.next());
            std::thread::sleep(Duration::from_millis(10));
            mux.drain();
            assert_eq!(h.join().unwrap(), None);
        });
    }

    #[test]
    fn response_lines_parse_back_and_round_trip_values() {
        let r = Response {
            id: 5,
            expert: 2,
            nll: 2017.25,
            queue_micros: 41,
            route_micros: 12,
            exec_micros: 97,
        };
        // orig id on the wire, not the internal key
        let line = ok_line(9_000_000_000, &r);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(9e9));
        assert_eq!(j.get("expert").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("nll").and_then(Json::as_f64), Some(2017.25));
        assert_eq!(j.get("queue_micros").and_then(Json::as_f64), Some(41.0));

        let nan = Response { nll: f32::NAN, ..r };
        let j = Json::parse(&ok_line(1, &nan)).unwrap();
        assert_eq!(j.get("nll"), Some(&Json::Null), "non-finite nll is null");

        let j = Json::parse(&shed_line(7)).unwrap();
        assert_eq!(j.get("code").and_then(Json::as_f64), Some(429.0));
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(7.0));

        // detail with quotes/backslashes must come back intact
        let j = Json::parse(&bad_request_line(r#"bad "\u" escape at byte 3"#)).unwrap();
        assert_eq!(j.get("code").and_then(Json::as_f64), Some(400.0));
        assert_eq!(
            j.get("detail").and_then(Json::as_str),
            Some(r#"bad "\u" escape at byte 3"#)
        );
        assert!(
            !bad_request_line("x\ny").contains('\n'),
            "a response line must never contain a raw newline"
        );
    }
}
