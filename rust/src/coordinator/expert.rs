//! Independent expert training (Algorithm 1, lines 11–16).
//!
//! Each expert is a virtual "node": it sees only its own dataset segment,
//! performs SGD locally, and never communicates (the defining property of
//! the method). On this single-core testbed the nodes run sequentially;
//! the comm ledger still models the cluster topology (zero events here).

use anyhow::Result;

use crate::data::Sequence;
use crate::metrics::RunLog;
use crate::runtime::{Engine, TrainState, VariantMeta};

/// Training budget for one expert node.
#[derive(Clone, Debug)]
pub struct ExpertConfig {
    pub steps: usize,
    pub seed: u64,
    /// Log the loss every `log_every` steps.
    pub log_every: usize,
}

impl Default for ExpertConfig {
    fn default() -> Self {
        ExpertConfig {
            steps: 100,
            seed: 23,
            log_every: 10,
        }
    }
}

/// Train one expert on its segment. `segment` is this node's private data
/// shard; batches cycle deterministically through it.
///
/// Returns the trained state; appends `loss` (by step) and `tokens` (by
/// cumulative tokens) series to `log`.
pub fn train_expert(
    engine: &Engine,
    variant: &str,
    cfg: &ExpertConfig,
    segment: &[Sequence],
    log: &mut RunLog,
) -> Result<TrainState> {
    let meta: VariantMeta = engine.variant(variant)?.clone();
    let mut state = TrainState::init(engine, variant, cfg.seed)?;
    train_expert_continue(engine, &mut state, cfg, segment, &meta, log)?;
    Ok(state)
}

/// Assemble the next `rows`-row training batch by cycling `cursor`
/// through `segment` **by reference** (no token clones) — the batch
/// discipline shared by the pipeline's expert loop and the trainer
/// nodes' staged mode (whose resumable cursor is a `u64` so it
/// serializes into node checkpoints).
///
/// `segment` must be non-empty (asserted with a clear message; both
/// callers surface the structured "cannot train on an empty segment"
/// error before ever reaching this).
pub fn segment_batch<'a>(segment: &'a [Sequence], cursor: &mut u64, rows: usize) -> Vec<&'a [u32]> {
    assert!(!segment.is_empty(), "segment_batch requires a non-empty segment");
    let mut batch = Vec::with_capacity(rows);
    for _ in 0..rows {
        let i = (*cursor % segment.len() as u64) as usize;
        batch.push(segment[i].tokens.as_slice());
        *cursor += 1;
    }
    batch
}

/// Continue training an existing state (used by FLOPs-matched baselines
/// and the perf bench).
pub fn train_expert_continue(
    engine: &Engine,
    state: &mut TrainState,
    cfg: &ExpertConfig,
    segment: &[Sequence],
    meta: &VariantMeta,
    log: &mut RunLog,
) -> Result<f32> {
    anyhow::ensure!(!segment.is_empty(), "cannot train on an empty segment");
    let mut cursor = 0u64;
    let mut last = 0.0f32;
    for step in 0..cfg.steps {
        let batch = segment_batch(segment, &mut cursor, meta.train_batch);
        last = state.train_step(engine, &batch, meta)?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.scalar("loss", state.step as f64, last as f64);
            log.scalar(
                "tokens",
                (state.step as usize * meta.tokens_per_step()) as f64,
                last as f64,
            );
        }
    }
    Ok(last)
}
