//! TF-IDF document encoding (first stage of the Gururangan et al. (2023)
//! routing baseline, Fig. 4c).
//!
//! Documents are token sequences (we operate on the BPE ids the routers
//! see, so both routing methods get exactly the same input). The vocabulary
//! is the tokenizer's; term frequency is L2-normalized and weighted by
//! smoothed inverse document frequency.

/// Fitted TF-IDF vocabulary statistics.
#[derive(Clone, Debug)]
pub struct TfIdf {
    pub vocab: usize,
    /// idf[t] = ln((1 + n_docs) / (1 + df[t])) + 1 (smooth idf)
    pub idf: Vec<f64>,
}

impl TfIdf {
    /// Fit document frequencies over token-id documents.
    pub fn fit(docs: &[&[u32]], vocab: usize) -> TfIdf {
        let mut df = vec![0u64; vocab];
        let mut seen = vec![u32::MAX; vocab];
        for (i, doc) in docs.iter().enumerate() {
            for &t in doc.iter() {
                let t = t as usize;
                if t < vocab && seen[t] != i as u32 {
                    seen[t] = i as u32;
                    df[t] += 1;
                }
            }
        }
        let n = docs.len() as f64;
        let idf = df
            .iter()
            .map(|&d| ((1.0 + n) / (1.0 + d as f64)).ln() + 1.0)
            .collect();
        TfIdf { vocab, idf }
    }

    /// Encode one document as a dense L2-normalized tf-idf vector.
    pub fn encode(&self, doc: &[u32]) -> Vec<f64> {
        let mut tf = vec![0.0f64; self.vocab];
        for &t in doc {
            let t = t as usize;
            if t < self.vocab {
                tf[t] += 1.0;
            }
        }
        if doc.is_empty() {
            return tf;
        }
        for (t, v) in tf.iter_mut().enumerate() {
            *v = *v / doc.len() as f64 * self.idf[t];
        }
        let norm = tf.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for v in tf.iter_mut() {
                *v /= norm;
            }
        }
        tf
    }

    /// Encode a batch into a row-major matrix.
    pub fn encode_all(&self, docs: &[&[u32]]) -> Vec<Vec<f64>> {
        docs.iter().map(|d| self.encode(d)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idf_downweights_ubiquitous_tokens() {
        // token 0 in every doc, token 1 in one doc
        let docs: Vec<Vec<u32>> = vec![vec![0, 1], vec![0, 2], vec![0, 3]];
        let refs: Vec<&[u32]> = docs.iter().map(|d| d.as_slice()).collect();
        let t = TfIdf::fit(&refs, 8);
        assert!(t.idf[0] < t.idf[1]);
        assert_eq!(t.idf[1], t.idf[2]);
    }

    #[test]
    fn encoding_is_unit_norm() {
        let docs: Vec<Vec<u32>> = vec![vec![0, 1, 1, 2], vec![3, 3, 3]];
        let refs: Vec<&[u32]> = docs.iter().map(|d| d.as_slice()).collect();
        let t = TfIdf::fit(&refs, 8);
        for d in &refs {
            let v = t.encode(d);
            let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9, "{norm}");
        }
    }

    #[test]
    fn empty_doc_is_zero_vector() {
        let docs: Vec<Vec<u32>> = vec![vec![0, 1]];
        let refs: Vec<&[u32]> = docs.iter().map(|d| d.as_slice()).collect();
        let t = TfIdf::fit(&refs, 4);
        assert!(t.encode(&[]).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn similar_docs_have_high_cosine() {
        let a: &[u32] = &[1, 2, 3, 1, 2, 3];
        let b: &[u32] = &[1, 2, 3, 3, 2];
        let c: &[u32] = &[7, 6, 5, 4];
        let t = TfIdf::fit(&[a, b, c], 8);
        let (va, vb, vc) = (t.encode(a), t.encode(b), t.encode(c));
        let dot = |x: &[f64], y: &[f64]| -> f64 { x.iter().zip(y).map(|(a, b)| a * b).sum() };
        assert!(dot(&va, &vb) > dot(&va, &vc));
    }

    #[test]
    fn out_of_vocab_tokens_ignored() {
        let a: &[u32] = &[1, 999];
        let t = TfIdf::fit(&[a], 4);
        let v = t.encode(a);
        assert_eq!(v.len(), 4);
        assert!(v[1] > 0.0);
    }
}
