//! Baselines the paper compares against.
//!
//! * [`dense`] — the FLOPs-matched dense model (Fig. 2 / Table 3): one
//!   model of the expert's architecture trained on E× the tokens.
//! * [`tfidf`] + [`svd`] + [`kmeans`] — the Gururangan et al. (2023)
//!   routing comparator of Fig. 4c: TF-IDF document encoding → truncated
//!   SVD projection → balanced K-Means clustering.

pub mod dense;
pub mod kmeans;
pub mod svd;
pub mod tfidf;

pub use dense::{train_dense, train_dense_batched};
pub use kmeans::{balanced_kmeans, KMeansResult};
pub use svd::truncated_svd;
pub use tfidf::TfIdf;
