//! Balanced K-Means clustering (final stage of the Fig. 4c baseline).
//!
//! Standard Lloyd iterations with k-means++ seeding, but the assignment
//! step enforces per-cluster capacity `ceil(n/k)` using the same
//! best-score-first greedy the paper's balanced assignment uses — so both
//! routing methods face identical balance constraints.

use crate::coordinator::assignment::balanced_assign;
use crate::util::rng::Rng;

/// Clustering output.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub assignment: Vec<usize>,
    pub centroids: Vec<Vec<f64>>,
    pub inertia: f64,
    pub iterations: usize,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn kmeanspp_init(points: &[Vec<f64>], k: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    let n = points.len();
    let mut centroids = Vec::with_capacity(k);
    centroids.push(points[rng.usize_below(n)].clone());
    let mut d2: Vec<f64> = points.iter().map(|p| sq_dist(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            points[rng.usize_below(n)].clone()
        } else {
            points[rng.weighted(&d2)].clone()
        };
        centroids.push(next);
        for (i, p) in points.iter().enumerate() {
            let d = sq_dist(p, centroids.last().unwrap());
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Balanced K-Means: capacity-constrained Lloyd iterations.
pub fn balanced_kmeans(points: &[Vec<f64>], k: usize, max_iters: usize, seed: u64) -> KMeansResult {
    assert!(k > 0 && !points.is_empty());
    let mut rng = Rng::new(seed);
    let mut centroids = kmeanspp_init(points, k, &mut rng);
    let mut assignment: Vec<usize> = vec![0; points.len()];
    let mut last_inertia = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..max_iters {
        iterations = it + 1;
        // capacity-constrained assignment via the shared balanced greedy
        let dists: Vec<Vec<f32>> = points
            .iter()
            .map(|p| centroids.iter().map(|c| sq_dist(p, c) as f32).collect())
            .collect();
        let a = balanced_assign(&dists, None);
        assignment = a.expert_of;

        // recompute centroids
        let dim = points[0].len();
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, &c) in assignment.iter().enumerate() {
            counts[c] += 1;
            for (j, &x) in points[i].iter().enumerate() {
                sums[c][j] += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..dim {
                    sums[c][j] /= counts[c] as f64;
                }
                centroids[c] = sums[c].clone();
            }
        }

        let inertia: f64 = assignment
            .iter()
            .enumerate()
            .map(|(i, &c)| sq_dist(&points[i], &centroids[c]))
            .sum();
        if (last_inertia - inertia).abs() < 1e-9 {
            last_inertia = inertia;
            break;
        }
        last_inertia = inertia;
    }

    KMeansResult {
        assignment,
        centroids,
        inertia: last_inertia,
        iterations,
    }
}

/// Assign new points to the nearest centroid (inference-time routing for
/// the TF-IDF baseline — unconstrained, like Eq. 4 at inference).
pub fn nearest_centroid(points: &[Vec<f64>], centroids: &[Vec<f64>]) -> Vec<usize> {
    points
        .iter()
        .map(|p| {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = sq_dist(p, cent);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs(n_per: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        let mut pts = Vec::new();
        for _ in 0..n_per {
            pts.push(vec![1.0 + 0.1 * rng.normal(), 1.0 + 0.1 * rng.normal()]);
        }
        for _ in 0..n_per {
            pts.push(vec![-1.0 + 0.1 * rng.normal(), -1.0 + 0.1 * rng.normal()]);
        }
        pts
    }

    #[test]
    fn separates_two_blobs() {
        let pts = two_blobs(20, 3);
        let r = balanced_kmeans(&pts, 2, 20, 7);
        // first 20 all same cluster, last 20 all the other
        let c0 = r.assignment[0];
        assert!(r.assignment[..20].iter().all(|&c| c == c0));
        assert!(r.assignment[20..].iter().all(|&c| c != c0));
    }

    #[test]
    fn balanced_capacities() {
        let pts = two_blobs(25, 5);
        let r = balanced_kmeans(&pts, 4, 15, 9);
        let mut counts = vec![0usize; 4];
        for &c in &r.assignment {
            counts[c] += 1;
        }
        let cap = 50usize.div_ceil(4);
        assert!(counts.iter().all(|&c| c <= cap), "{counts:?}");
    }

    #[test]
    fn inertia_decreases_or_converges() {
        let pts = two_blobs(30, 11);
        let r1 = balanced_kmeans(&pts, 2, 1, 13);
        let r5 = balanced_kmeans(&pts, 2, 15, 13);
        assert!(r5.inertia <= r1.inertia + 1e-9);
    }

    #[test]
    fn nearest_centroid_routes_to_closest() {
        let cents = vec![vec![0.0, 0.0], vec![10.0, 10.0]];
        let pts = vec![vec![1.0, 0.0], vec![9.0, 9.5]];
        assert_eq!(nearest_centroid(&pts, &cents), vec![0, 1]);
    }

    #[test]
    fn deterministic_in_seed() {
        let pts = two_blobs(10, 17);
        let a = balanced_kmeans(&pts, 2, 10, 5);
        let b = balanced_kmeans(&pts, 2, 10, 5);
        assert_eq!(a.assignment, b.assignment);
    }
}
