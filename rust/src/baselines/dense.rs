//! FLOPs-matched dense baseline (§3.1 "Comparison to the Dense Model").
//!
//! The dense model has the *same architecture as one expert* and trains on
//! the *same total token volume* as the whole mixture: `E × expert_steps`
//! SGD steps on the unpartitioned stream. Inference cost is therefore
//! identical to a single expert's; training FLOPs match the mixture's
//! expert stage (the router overhead is the paper's ≤4% delta, accounted
//! in `flops/`).
//!
//! The baseline shares nothing with the mixture — its own `TrainState`,
//! its own data stream — and the engine is `Sync`, so `smalltalk e2e`
//! trains it concurrently with the mixture pipeline when more than one
//! worker thread is configured (identical results, shorter wall clock).

use anyhow::Result;

use crate::data::SequenceGen;
use crate::metrics::RunLog;
use crate::runtime::{Engine, TrainState};
use crate::tokenizer::Bpe;

/// Train a dense baseline for `total_steps` on the raw (unrouted) stream
/// at the expert's native batch size.
pub fn train_dense(
    engine: &Engine,
    bpe: &Bpe,
    variant: &str,
    total_steps: usize,
    seed: u64,
    log: &mut RunLog,
) -> Result<TrainState> {
    let meta = engine.variant(variant)?.clone();
    train_dense_batched(engine, bpe, variant, total_steps, meta.train_batch, seed, log)
}

/// Train a dense baseline with an explicit batch size (must be the
/// expert's native batch or one of the compiled `dense_batches`). The
/// paper's comparator (Table 2) is `batch = E x expert_batch` for the
/// same number of steps — same total tokens, same step count.
pub fn train_dense_batched(
    engine: &Engine,
    bpe: &Bpe,
    variant: &str,
    total_steps: usize,
    batch_rows: usize,
    seed: u64,
    log: &mut RunLog,
) -> Result<TrainState> {
    let meta = engine.variant(variant)?.clone();
    let mut state = TrainState::init(engine, variant, seed)?;
    let mut gen = SequenceGen::new(bpe, meta.seq_len, seed ^ 0xDE5E);

    // Single-epoch: the dense model never revisits a sequence, matching
    // the paper's regime; data is drawn in bounded chunks.
    let mut remaining = total_steps;
    while remaining > 0 {
        let steps = remaining.min(32);
        let rows = gen.batch(steps * batch_rows);
        for s in 0..steps {
            let batch: Vec<&[u32]> = rows[s * batch_rows..(s + 1) * batch_rows]
                .iter()
                .map(|r| r.tokens.as_slice())
                .collect();
            let loss = state.train_step_auto(engine, &batch, &meta)?;
            if state.step % 10 == 0 || remaining - s <= 1 {
                log.scalar("loss", state.step as f64, loss as f64);
                log.scalar(
                    "tokens",
                    (state.step as usize * batch_rows * meta.seq_len) as f64,
                    loss as f64,
                );
            }
        }
        remaining -= steps;
    }
    Ok(state)
}
