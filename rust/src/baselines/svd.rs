//! Truncated SVD by randomized subspace iteration (Halko et al. 2011).
//!
//! Projects TF-IDF vectors to a low-dimensional space before K-Means,
//! exactly as the Gururangan et al. (2023) pipeline does. Implemented
//! from scratch: random Gaussian sketch, a few power iterations with
//! Gram–Schmidt re-orthonormalization, then projection.

use crate::util::rng::Rng;

/// Row-major dense matrix helper.
fn matmul_at_a_q(rows: &[Vec<f64>], q: &[Vec<f64>]) -> Vec<Vec<f64>> {
    // computes A^T (A q) for each column of q; rows: n x d, q: d x k
    let d = rows.first().map(|r| r.len()).unwrap_or(0);
    let k = q.first().map(|c| c.len()).unwrap_or(0);
    let mut out = vec![vec![0.0; k]; d];
    for row in rows {
        // s = row . q  (1 x k)
        let mut s = vec![0.0; k];
        for (j, &x) in row.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            for c in 0..k {
                s[c] += x * q[j][c];
            }
        }
        // out += row^T s
        for (j, &x) in row.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            for c in 0..k {
                out[j][c] += x * s[c];
            }
        }
    }
    out
}

/// Modified Gram–Schmidt orthonormalization of the columns of `m` (d x k).
fn orthonormalize(m: &mut [Vec<f64>]) {
    let d = m.len();
    let k = m.first().map(|r| r.len()).unwrap_or(0);
    for c in 0..k {
        // subtract projections on previous columns
        for p in 0..c {
            let mut dot = 0.0;
            for r in 0..d {
                dot += m[r][c] * m[r][p];
            }
            for r in 0..d {
                m[r][c] -= dot * m[r][p];
            }
        }
        let mut norm = 0.0;
        for r in 0..d {
            norm += m[r][c] * m[r][c];
        }
        let norm = norm.sqrt().max(1e-12);
        for r in 0..d {
            m[r][c] /= norm;
        }
    }
}

/// Compute a rank-`k` orthonormal basis `V` (d x k) of the row space of
/// `rows` (n x d) and return the projected rows (n x k).
pub fn truncated_svd(rows: &[Vec<f64>], k: usize, iters: usize, seed: u64) -> Vec<Vec<f64>> {
    let n = rows.len();
    let d = rows.first().map(|r| r.len()).unwrap_or(0);
    if n == 0 || d == 0 || k == 0 {
        return vec![vec![]; n];
    }
    let k = k.min(d).min(n);
    let mut rng = Rng::new(seed);
    // random start: d x k Gaussian
    let mut q: Vec<Vec<f64>> = (0..d)
        .map(|_| (0..k).map(|_| rng.normal()).collect())
        .collect();
    orthonormalize(&mut q);
    for _ in 0..iters {
        q = matmul_at_a_q(rows, &q);
        orthonormalize(&mut q);
    }
    // project: each row (1 x d) times q (d x k)
    rows.iter()
        .map(|row| {
            (0..k)
                .map(|c| {
                    row.iter()
                        .enumerate()
                        .map(|(j, &x)| x * q[j][c])
                        .sum::<f64>()
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_has_requested_rank() {
        let mut rng = Rng::new(1);
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|_| (0..10).map(|_| rng.normal()).collect())
            .collect();
        let p = truncated_svd(&rows, 3, 3, 7);
        assert_eq!(p.len(), 20);
        assert!(p.iter().all(|r| r.len() == 3));
    }

    #[test]
    fn separates_two_orthogonal_clusters() {
        // cluster A lives on axes 0-1, cluster B on axes 8-9
        let mut rng = Rng::new(2);
        let mut rows = Vec::new();
        for _ in 0..15 {
            let mut r = vec![0.0; 10];
            r[0] = 1.0 + 0.05 * rng.normal();
            r[1] = 0.5 + 0.05 * rng.normal();
            rows.push(r);
        }
        for _ in 0..15 {
            let mut r = vec![0.0; 10];
            r[8] = 1.0 + 0.05 * rng.normal();
            r[9] = -0.7 + 0.05 * rng.normal();
            rows.push(r);
        }
        let p = truncated_svd(&rows, 2, 4, 3);
        // distance within cluster << distance across clusters
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        let within = dist(&p[0], &p[1]);
        let across = dist(&p[0], &p[20]);
        assert!(across > 5.0 * within, "within={within} across={across}");
    }

    #[test]
    fn preserves_pairwise_structure_for_full_rank() {
        let rows = vec![
            vec![1.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![1.0, 1.0, 0.0],
        ];
        let p = truncated_svd(&rows, 2, 5, 11);
        // row2 = row0 + row1 must hold approximately in the projection
        for c in 0..2 {
            assert!((p[2][c] - (p[0][c] + p[1][c])).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_input_safe() {
        let p = truncated_svd(&[], 4, 2, 1);
        assert!(p.is_empty());
    }

    #[test]
    fn deterministic_in_seed() {
        let rows: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64, 1.0, 0.0]).collect();
        assert_eq!(
            truncated_svd(&rows, 2, 3, 42),
            truncated_svd(&rows, 2, 3, 42)
        );
    }
}
