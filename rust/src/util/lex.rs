//! Zero-copy incremental JSON lexing for the wire path
//! ([`crate::coordinator::net`]).
//!
//! The TCP front-end parses untrusted bytes at line rate; building a
//! [`Json`](super::json::Json) tree per request would allocate a node
//! per token and copy every string. This module lexes straight off the
//! connection's read buffer instead — a slice lexer with escape-aware
//! borrowed strings — and exposes a small typed extractor for the one
//! request shape the server understands: lazy field scans, no
//! intermediate tree.
//!
//! Three layers:
//!
//! * [`LineBuf`] — an incremental JSONL splitter: `feed` socket reads as
//!   they arrive, pop complete lines. A line split across any number of
//!   reads lexes identically to one contiguous write.
//! * [`Lexer`] — a pull lexer over one line: structural tokens, raw
//!   number slices (validated, parsed lazily by the consumer at the
//!   width it needs), and strings that borrow the input whenever they
//!   contain no escapes.
//! * [`parse_request_line`] — the typed extractor:
//!   `{"id": N, "tokens": [..]}` / `{"id": N, "text": "..."}` →
//!   [`WireRequest`] in one pass. Unknown fields are skipped without
//!   materialization; nesting is depth-bounded like the tree parser.
//!
//! The string/`\u` machinery (surrogate pairs, strict 4-hex-digit
//! validation) is shared with [`super::json`], so the two parsers accept
//! the same documents — asserted by the adversarial corpus in
//! `rust/tests/net.rs`.

use std::borrow::Cow;

use super::json::{decode_unicode_escape, ParseError, MAX_DEPTH};

/// Incremental JSONL splitter over socket reads: [`feed`](LineBuf::feed)
/// appends raw bytes, [`next_line`](LineBuf::next_line) pops the next
/// complete `\n`-terminated line (with a trailing `\r` trimmed). Bytes
/// after the last newline stay buffered until more input arrives, so a
/// request split across read boundaries parses identically to one
/// delivered whole.
#[derive(Default)]
pub struct LineBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl LineBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one socket read. Consumed lines are compacted away first,
    /// so between feeds the buffer holds at most one partial line.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete line, or `None` until one arrives. The
    /// returned slice borrows the read buffer — lex it before the next
    /// [`feed`](LineBuf::feed).
    pub fn next_line(&mut self) -> Option<&[u8]> {
        let rest = &self.buf[self.pos..];
        let nl = rest.iter().position(|&b| b == b'\n')?;
        let mut line = &self.buf[self.pos..self.pos + nl];
        self.pos += nl + 1;
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        Some(line)
    }

    /// Bytes of a partial trailing line still buffered.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// One lexical token. `Str` borrows the input when the string contains
/// no escapes; `Num` always borrows the raw (pre-validated) text so the
/// consumer can parse it at exactly the width it needs — `u64` ids keep
/// full precision instead of routing through `f64`.
#[derive(Clone, Debug, PartialEq)]
pub enum Token<'a> {
    ObjOpen,
    ObjClose,
    ArrOpen,
    ArrClose,
    Colon,
    Comma,
    Str(Cow<'a, str>),
    Num(&'a str),
    Bool(bool),
    Null,
}

/// Pull lexer over one slice (a JSONL line). Grammar-agnostic: it hands
/// out tokens; shape checks belong to the consumer (e.g.
/// [`parse_request_line`]).
pub struct Lexer<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(b: &'a [u8]) -> Self {
        Lexer { b, pos: 0 }
    }

    /// Current byte offset (for error positions).
    pub fn pos(&self) -> usize {
        self.pos
    }

    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while matches!(
            self.b.get(self.pos),
            Some(b' ' | b'\t' | b'\n' | b'\r')
        ) {
            self.pos += 1;
        }
    }

    /// Next token, or `Ok(None)` at end of input.
    pub fn next_token(&mut self) -> Result<Option<Token<'a>>, ParseError> {
        self.skip_ws();
        let Some(c) = self.b.get(self.pos).copied() else {
            return Ok(None);
        };
        match c {
            b'{' | b'}' | b'[' | b']' | b':' | b',' => {
                self.pos += 1;
                Ok(Some(match c {
                    b'{' => Token::ObjOpen,
                    b'}' => Token::ObjClose,
                    b'[' => Token::ArrOpen,
                    b']' => Token::ArrClose,
                    b':' => Token::Colon,
                    _ => Token::Comma,
                }))
            }
            b'"' => Ok(Some(Token::Str(self.string()?))),
            b't' => self.lit(b"true", Token::Bool(true)),
            b'f' => self.lit(b"false", Token::Bool(false)),
            b'n' => self.lit(b"null", Token::Null),
            b'-' | b'0'..=b'9' => Ok(Some(Token::Num(self.number()?))),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(
        &mut self,
        word: &'static [u8],
        tok: Token<'a>,
    ) -> Result<Option<Token<'a>>, ParseError> {
        if self.b[self.pos..].starts_with(word) {
            self.pos += word.len();
            Ok(Some(tok))
        } else {
            Err(self.err("bad literal"))
        }
    }

    /// Lex a number, returning its raw text. Validated here (so the
    /// slice is trustworthy downstream) with the same charset as the
    /// tree parser.
    fn number(&mut self) -> Result<&'a str, ParseError> {
        let start = self.pos;
        if self.b.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while matches!(
            self.b.get(self.pos),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .expect("number charset is pure ASCII");
        if s.parse::<f64>().is_err() {
            self.pos = start;
            return Err(self.err("bad number"));
        }
        Ok(s)
    }

    /// Lex one string. Escape-free content is borrowed straight from the
    /// input; escapes fall back to an owned decode sharing the hardened
    /// `\u` machinery (surrogate pairs and all) with the tree parser.
    fn string(&mut self) -> Result<Cow<'a, str>, ParseError> {
        debug_assert_eq!(self.b.get(self.pos), Some(&b'"'));
        self.pos += 1;
        let start = self.pos;
        // fast scan: locate the closing quote, noting whether any escape
        // occurs (an escaped quote is not a closer)
        let mut i = start;
        let mut has_escape = false;
        loop {
            match self.b.get(i) {
                None => {
                    self.pos = i;
                    return Err(self.err("unterminated string"));
                }
                Some(b'"') => break,
                Some(b'\\') => {
                    has_escape = true;
                    i += 2;
                }
                Some(_) => i += 1,
            }
        }
        if !has_escape {
            let s = std::str::from_utf8(&self.b[start..i]).map_err(|_| ParseError {
                pos: start,
                msg: "invalid utf-8 in string".to_string(),
            })?;
            self.pos = i + 1;
            return Ok(Cow::Borrowed(s));
        }
        // slow path: decode escapes into an owned buffer
        let mut out = String::with_capacity(i.saturating_sub(start));
        let mut p = start;
        loop {
            match self.b.get(p) {
                None => {
                    self.pos = p;
                    return Err(self.err("unterminated string"));
                }
                Some(b'"') => {
                    self.pos = p + 1;
                    return Ok(Cow::Owned(out));
                }
                Some(b'\\') => {
                    p += 1;
                    match self.b.get(p) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let (c, used) = decode_unicode_escape(self.b, p)?;
                            out.push(c);
                            p += used;
                        }
                        _ => {
                            self.pos = p;
                            return Err(self.err("bad escape"));
                        }
                    }
                    p += 1;
                }
                Some(_) => {
                    // decode one UTF-8 char without validating past it: a
                    // char is at most 4 bytes, and a valid prefix of the
                    // window is enough
                    let end = (p + 4).min(self.b.len());
                    let chunk = &self.b[p..end];
                    let valid = match std::str::from_utf8(chunk) {
                        Ok(s) => s,
                        Err(e) if e.valid_up_to() > 0 => {
                            std::str::from_utf8(&chunk[..e.valid_up_to()])
                                .expect("prefix reported valid")
                        }
                        Err(_) => {
                            self.pos = p;
                            return Err(self.err("invalid utf-8 in string"));
                        }
                    };
                    let c = valid.chars().next().expect("non-empty valid prefix");
                    out.push(c);
                    p += c.len_utf8();
                }
            }
        }
    }

    /// Skip one complete JSON value of any shape without materializing
    /// it (unknown request fields). Balance-checked and depth-bounded
    /// like the tree parser, so adversarially nested input is a
    /// structured error rather than a blown stack; interior punctuation
    /// is not shape-validated — this finds the matching close.
    pub fn skip_value(&mut self) -> Result<(), ParseError> {
        let mut depth = 0usize;
        loop {
            let t = self
                .next_token()?
                .ok_or_else(|| self.err("unexpected end of input"))?;
            match t {
                Token::ObjOpen | Token::ArrOpen => {
                    depth += 1;
                    if depth > MAX_DEPTH {
                        return Err(self.err("nesting too deep"));
                    }
                }
                Token::ObjClose | Token::ArrClose => {
                    if depth == 0 {
                        return Err(self.err("unbalanced close"));
                    }
                    depth -= 1;
                }
                Token::Colon | Token::Comma if depth == 0 => {
                    return Err(self.err("expected a value"));
                }
                _ => {}
            }
            if depth == 0 {
                return Ok(());
            }
        }
    }
}

/// One parsed wire request line — exactly one of `tokens` / `text` is
/// set (enforced by [`parse_request_line`]). `text` borrows the line
/// buffer when the string needs no unescaping.
#[derive(Debug)]
pub struct WireRequest<'a> {
    pub id: u64,
    pub tokens: Option<Vec<u32>>,
    pub text: Option<Cow<'a, str>>,
}

/// Typed extractor for a request line: `{"id": N, "tokens": [..]}` or
/// `{"id": N, "text": "..."}` in one lexing pass, no tree. `id` and
/// every token must be plain non-negative decimal integers (`u64` /
/// `u32` — full precision, unlike the `f64` tree path). Unknown fields
/// are skipped; duplicate or conflicting body fields, a missing `id`,
/// and trailing bytes after the object are structured errors.
pub fn parse_request_line(line: &[u8]) -> Result<WireRequest<'_>, ParseError> {
    let mut lex = Lexer::new(line);
    let fail = |lex: &Lexer, msg: &str| ParseError {
        pos: lex.pos(),
        msg: msg.to_string(),
    };
    match lex.next_token()? {
        Some(Token::ObjOpen) => {}
        _ => return Err(fail(&lex, "request line must be a JSON object")),
    }
    let mut id: Option<u64> = None;
    let mut tokens: Option<Vec<u32>> = None;
    let mut text: Option<Cow<'_, str>> = None;
    let mut first = true;
    loop {
        let key = match lex.next_token()? {
            Some(Token::ObjClose) if first => break,
            Some(Token::Str(k)) => k,
            _ => return Err(fail(&lex, "expected a field name")),
        };
        first = false;
        match lex.next_token()? {
            Some(Token::Colon) => {}
            _ => return Err(fail(&lex, "expected ':'")),
        }
        match key.as_ref() {
            "id" => match lex.next_token()? {
                Some(Token::Num(raw)) => {
                    let v = raw.parse::<u64>().map_err(|_| {
                        fail(&lex, "\"id\" must be a non-negative integer")
                    })?;
                    id = Some(v);
                }
                _ => return Err(fail(&lex, "\"id\" must be a non-negative integer")),
            },
            "tokens" => {
                if tokens.is_some() {
                    return Err(fail(&lex, "duplicate \"tokens\" field"));
                }
                tokens = Some(parse_u32_array(&mut lex)?);
            }
            "text" => match lex.next_token()? {
                Some(Token::Str(s)) => {
                    if text.is_some() {
                        return Err(fail(&lex, "duplicate \"text\" field"));
                    }
                    text = Some(s);
                }
                _ => return Err(fail(&lex, "\"text\" must be a string")),
            },
            _ => lex.skip_value()?,
        }
        match lex.next_token()? {
            Some(Token::Comma) => {}
            Some(Token::ObjClose) => break,
            _ => return Err(fail(&lex, "expected ',' or '}'")),
        }
    }
    if lex.next_token()?.is_some() {
        return Err(fail(&lex, "trailing characters after request object"));
    }
    let id = id.ok_or_else(|| fail(&lex, "missing \"id\""))?;
    match (&tokens, &text) {
        (Some(_), Some(_)) => Err(fail(&lex, "request has both \"tokens\" and \"text\"")),
        (None, None) => Err(fail(&lex, "request needs \"tokens\" or \"text\"")),
        _ => Ok(WireRequest { id, tokens, text }),
    }
}

fn parse_u32_array(lex: &mut Lexer) -> Result<Vec<u32>, ParseError> {
    let fail = |lex: &Lexer<'_>, msg: &str| ParseError {
        pos: lex.pos(),
        msg: msg.to_string(),
    };
    match lex.next_token()? {
        Some(Token::ArrOpen) => {}
        _ => return Err(fail(lex, "\"tokens\" must be an array")),
    }
    let mut out = Vec::new();
    match lex.next_token()? {
        Some(Token::ArrClose) => return Ok(out),
        Some(Token::Num(raw)) => out.push(parse_token(lex, raw)?),
        _ => return Err(fail(lex, "tokens must be non-negative integers")),
    }
    loop {
        match lex.next_token()? {
            Some(Token::ArrClose) => return Ok(out),
            Some(Token::Comma) => match lex.next_token()? {
                Some(Token::Num(raw)) => out.push(parse_token(lex, raw)?),
                _ => return Err(fail(lex, "tokens must be non-negative integers")),
            },
            _ => return Err(fail(lex, "expected ',' or ']'")),
        }
    }
}

fn parse_token(lex: &Lexer<'_>, raw: &str) -> Result<u32, ParseError> {
    raw.parse::<u32>().map_err(|_| ParseError {
        pos: lex.pos(),
        msg: format!("token {raw:?} is not a u32"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens_of(b: &[u8]) -> Result<Vec<Token<'_>>, ParseError> {
        let mut lex = Lexer::new(b);
        let mut out = Vec::new();
        while let Some(t) = lex.next_token()? {
            out.push(t);
        }
        Ok(out)
    }

    #[test]
    fn escape_free_strings_borrow_the_input() {
        let mut lex = Lexer::new(br#""plain utf-8: \u0041""#);
        // has an escape: owned
        match lex.next_token().unwrap().unwrap() {
            Token::Str(Cow::Owned(s)) => assert_eq!(s, "plain utf-8: A"),
            other => panic!("expected owned string, got {other:?}"),
        }
        let mut lex = Lexer::new("\"héllo\"".as_bytes());
        match lex.next_token().unwrap().unwrap() {
            Token::Str(Cow::Borrowed(s)) => assert_eq!(s, "héllo"),
            other => panic!("expected borrowed string, got {other:?}"),
        }
    }

    #[test]
    fn lexer_shares_the_hardened_u_escape_machinery() {
        let mut lex = Lexer::new(br#""\uD83D\uDE00""#);
        match lex.next_token().unwrap().unwrap() {
            Token::Str(s) => assert_eq!(s.as_ref(), "😀"),
            other => panic!("{other:?}"),
        }
        assert!(tokens_of(br#""\uD83D""#).is_err()); // unpaired high
        assert!(tokens_of(br#""\u+fff""#).is_err()); // signed hex
        assert!(tokens_of(b"\"\\u000\xc3\xa9\"").is_err()); // multibyte in window
    }

    #[test]
    fn numbers_are_raw_validated_slices() {
        assert_eq!(
            tokens_of(b"-1.5e2 42").unwrap(),
            vec![Token::Num("-1.5e2"), Token::Num("42")]
        );
        assert!(tokens_of(b"-").is_err());
        assert!(tokens_of(b"1.2.3e").is_err());
    }

    #[test]
    fn skip_value_is_balanced_and_depth_bounded() {
        let mut lex = Lexer::new(br#"{"a":[1,{"b":null}],"x":2} 99"#);
        lex.skip_value().unwrap();
        assert_eq!(lex.next_token().unwrap(), Some(Token::Num("99")));
        assert_eq!(lex.next_token().unwrap(), None);
    }

    #[test]
    fn skip_value_rejects_deep_nesting() {
        let deep = b"[".repeat(100_000);
        let mut lex = Lexer::new(&deep);
        let e = lex.skip_value().unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
    }

    #[test]
    fn line_buf_reassembles_split_lines() {
        let mut buf = LineBuf::new();
        buf.feed(b"{\"id\":1,");
        assert!(buf.next_line().is_none());
        assert_eq!(buf.pending(), 8);
        buf.feed(b"\"tokens\":[2]}\r\nnext");
        assert_eq!(buf.next_line().unwrap(), b"{\"id\":1,\"tokens\":[2]}");
        assert!(buf.next_line().is_none());
        buf.feed(b"\n");
        assert_eq!(buf.next_line().unwrap(), b"next");
        assert_eq!(buf.pending(), 0);
    }

    #[test]
    fn request_extractor_tokens_and_text() {
        let w = parse_request_line(br#"{"id": 7, "tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(w.id, 7);
        assert_eq!(w.tokens.as_deref(), Some(&[1, 2, 3][..]));
        assert!(w.text.is_none());

        let w = parse_request_line(br#"{"text": "hi there", "id": 9}"#).unwrap();
        assert_eq!(w.id, 9);
        assert_eq!(w.text.as_deref(), Some("hi there"));

        // u64 ids keep full precision (the f64 tree path would round)
        let w = parse_request_line(br#"{"id": 18446744073709551615, "tokens": []}"#).unwrap();
        assert_eq!(w.id, u64::MAX);

        // unknown fields are skipped, whatever their shape
        let w = parse_request_line(
            br#"{"id": 1, "meta": {"a": [1, {"b": "x"}]}, "tokens": [5]}"#,
        )
        .unwrap();
        assert_eq!(w.tokens.as_deref(), Some(&[5][..]));
    }

    #[test]
    fn request_extractor_rejects_bad_shapes() {
        for bad in [
            &br#"{"tokens": [1]}"#[..],                       // missing id
            br#"{"id": 1}"#,                                  // no body
            br#"{"id": 1, "tokens": [1], "text": "x"}"#,      // both bodies
            br#"{"id": -3, "tokens": [1]}"#,                  // negative id
            br#"{"id": 1.5, "tokens": [1]}"#,                 // fractional id
            br#"{"id": 1, "tokens": [1, -2]}"#,               // negative token
            br#"{"id": 1, "tokens": [4294967296]}"#,          // token > u32
            br#"{"id": 1, "tokens": [1.5]}"#,                 // fractional token
            br#"{"id": 1, "tokens": [1]} trailing"#,          // trailing bytes
            br#"[1, 2]"#,                                     // not an object
            br#"{"id": 1, "tokens": [1]"#,                    // truncated
        ] {
            assert!(
                parse_request_line(bad).is_err(),
                "accepted {:?}",
                String::from_utf8_lossy(bad)
            );
        }
    }
}
