//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs binaries with `harness = false`; each bench builds a
//! [`BenchSuite`], registers closures, and gets warmup + repeated timing
//! with median/mean/p90 reporting and optional JSON output under
//! `results/`.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
    /// Extra per-row metrics (e.g. `h2d_bytes_per_iter`) attached via
    /// [`BenchSuite::annotate`]; printed and written to the JSON output.
    pub extras: Vec<(String, f64)>,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Human-readable byte count for bench annotations.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct BenchSuite {
    pub title: String,
    pub warmup: Duration,
    pub target_time: Duration,
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
}

/// Millisecond budget override from the environment (used by
/// `scripts/bench_smoke.sh` to shrink every bench to a smoke run).
fn env_ms(var: &str) -> Option<Duration> {
    std::env::var(var)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
}

/// Worker-thread override for thread-sweep benches: `SMALLTALK_BENCH_THREADS`
/// caps the "parallel" side of a 1-vs-N sweep (`scripts/bench_smoke.sh`
/// exports it so the sweep is reproducible across machines).
pub fn env_threads() -> Option<usize> {
    std::env::var("SMALLTALK_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // Keep budgets modest: XLA-backed benches have multi-ms iterations.
        BenchSuite {
            title: title.to_string(),
            warmup: env_ms("SMALLTALK_BENCH_WARMUP_MS").unwrap_or(Duration::from_millis(200)),
            target_time: env_ms("SMALLTALK_BENCH_TARGET_MS").unwrap_or(Duration::from_secs(1)),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Set the per-bench budget. `SMALLTALK_BENCH_WARMUP_MS` /
    /// `SMALLTALK_BENCH_TARGET_MS` win over the programmatic budget so the
    /// smoke script can cap every suite uniformly.
    pub fn with_budget(mut self, warmup: Duration, target: Duration) -> Self {
        self.warmup = env_ms("SMALLTALK_BENCH_WARMUP_MS").unwrap_or(warmup);
        self.target_time = env_ms("SMALLTALK_BENCH_TARGET_MS").unwrap_or(target);
        self
    }

    /// Attach an extra metric to the most recent bench row (no-op before
    /// the first row). Byte-flavored keys are pretty-printed.
    pub fn annotate(&mut self, key: &str, value: f64) {
        let Some(last) = self.results.last_mut() else {
            return;
        };
        let shown = if key.contains("bytes") {
            format!("{} ({value:.0})", fmt_bytes(value))
        } else {
            format!("{value:.2}")
        };
        println!("      {key:<40} {shown}");
        last.extras.push((key.to_string(), value));
    }

    /// Time `f` repeatedly; returns (and records) the aggregate result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure.
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.target_time && samples.len() < self.max_iters {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        if samples.is_empty() {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            median_ns: samples[n / 2],
            p90_ns: samples[(n * 9 / 10).min(n - 1)],
            min_ns: samples[0],
            extras: Vec::new(),
        };
        println!(
            "  {:<44} {:>12} median {:>12} mean {:>12} p90  ({} iters)",
            result.name,
            fmt_ns(result.median_ns),
            fmt_ns(result.mean_ns),
            fmt_ns(result.p90_ns),
            result.iters
        );
        self.results.push(result.clone());
        result
    }

    pub fn header(&self) {
        println!("\n=== bench: {} ===", self.title);
    }

    /// Write results as JSON under `results/bench_<title>.json`.
    pub fn write_json(&self) -> std::io::Result<()> {
        use crate::util::json::Json;
        std::fs::create_dir_all("results")?;
        let arr = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("name", Json::str(r.name.clone())),
                        ("iters", Json::num(r.iters as f64)),
                        ("mean_ns", Json::num(r.mean_ns)),
                        ("median_ns", Json::num(r.median_ns)),
                        ("p90_ns", Json::num(r.p90_ns)),
                        ("min_ns", Json::num(r.min_ns)),
                    ];
                    for (k, v) in &r.extras {
                        fields.push((k.as_str(), Json::num(*v)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        let path = format!(
            "results/bench_{}.json",
            self.title.replace([' ', '/'], "_").to_lowercase()
        );
        std::fs::write(path, arr.to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut suite = BenchSuite::new("test").with_budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let r = suite.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 1);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn throughput_inverts_time() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p90_ns: 1e9,
            min_ns: 1e9,
            extras: Vec::new(),
        };
        assert!((r.throughput(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn annotate_attaches_to_last_row() {
        let mut suite = BenchSuite::new("annot").with_budget(
            Duration::from_millis(1),
            Duration::from_millis(5),
        );
        suite.annotate("h2d_bytes_per_iter", 1.0); // before any row: no-op
        assert!(suite.results.is_empty());
        suite.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        suite.annotate("h2d_bytes_per_iter", 4096.0);
        suite.annotate("uploads_avoided_per_iter", 3.0);
        let extras = &suite.results.last().unwrap().extras;
        assert_eq!(extras.len(), 2);
        assert_eq!(extras[0], ("h2d_bytes_per_iter".to_string(), 4096.0));
    }

    #[test]
    fn fmt_bytes_ranges() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert!(fmt_bytes(4096.0).ends_with("KiB"));
        assert!(fmt_bytes(5e6).ends_with("MiB"));
        assert!(fmt_bytes(5e9).ends_with("GiB"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
