//! Deterministic pseudo-random number generation.
//!
//! The offline registry has no `rand` crate, so we implement the small set
//! of primitives the repo needs: SplitMix64 for seeding and xoshiro256++
//! for the main stream. Every stochastic component (corpus generation,
//! assignment shuffles, property tests) takes an explicit seed so runs are
//! reproducible end to end.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per worker / per domain).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The full generator state — everything a resumed stream needs. A
    /// generator rebuilt with [`Rng::from_state`] continues the exact
    /// sequence (checkpoint/resume of data streams relies on this).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a captured [`Rng::state`] position.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli draw: `true` with probability `p`. `p <= 0` never fires,
    /// `p >= 1` always fires; exactly one stream draw either way so a
    /// replayed plan consumes the same number of states.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform u64 in `[lo, hi)` (convenience over [`Rng::below`]).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn chance_extremes_and_rate() {
        let mut r = Rng::new(17);
        for _ in 0..50 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn chance_consumes_one_draw_regardless_of_p() {
        // a replayed FaultPlan must consume identical stream positions no
        // matter which branches fire
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        a.chance(0.0);
        b.chance(1.0);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Rng::new(29);
        for _ in 0..500 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v), "v={v}");
        }
        assert_eq!(r.range_u64(7, 8), 7);
    }

    #[test]
    fn state_roundtrip_continues_exactly() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let expect: Vec<u64> = (0..20).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let got: Vec<u64> = (0..20).map(|_| b.next_u64()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
